"""Regenerate EXPERIMENTS.md tables from results/dryrun JSONs."""

import io
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.report import dryrun_table, enrich, load_records, table


def main():
    recs = [enrich(r) for r in load_records("results/dryrun", "singlepod")]
    mp = load_records("results/dryrun", "multipod")
    roofline = table(recs)
    dry = dryrun_table(recs)
    mp_line = (
        f"Multi-pod (2,16,16): **{len(mp)}/40 cells compiled** "
        "(scan lowering; compile-proof of the pod axis). Per-cell JSON in "
        "results/dryrun/multipod__*.json.\n")

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace(
        "<!-- DRYRUN_TABLE -->",
        mp_line + "\nSingle-pod detail (16,16):\n\n" + dry)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated:", len(recs), "singlepod,", len(mp),
          "multipod cells")


if __name__ == "__main__":
    main()
