#!/usr/bin/env bash
# Tier-1 gate: fail fast on the quick suite, then run the full tier-1
# command from ROADMAP.md.  Usage: scripts/run_tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (fast inner loop: -m 'not slow') =="
python -m pytest -x -q -m "not slow" "$@"

echo "== tier-1 (full suite) =="
python -m pytest -x -q "$@"

echo "== eval suite smoke (2 synthetic datasets, per-dataset + combined) =="
EVALSUITE_TMP="$(mktemp -d)"
python -m repro.launch.evalsuite --smoke \
  --data-root "$EVALSUITE_TMP/data" --out-dir "$EVALSUITE_TMP/results" \
  --n-queries 8 --n-docs 48
test -s "$EVALSUITE_TMP/results/evalsuite.json"
rm -rf "$EVALSUITE_TMP"

echo "== serve smoke (continuous-batching frontend, warm pass + steady state) =="
SERVE_TMP="$(mktemp -d)"
python -m repro.launch.serve --smoke --data-dir "$SERVE_TMP/data" \
  --n-requests 4 --batch 3 --concurrency 2 --workers 1 \
  --max-batch 8 --max-wait-ms 2
rm -rf "$SERVE_TMP"

echo "== chaos smoke (resilient cluster: worker killed at the first steady-state round, every request still resolves) =="
CHAOS_TMP="$(mktemp -d)"
python -m repro.launch.serve --smoke --data-dir "$CHAOS_TMP/data" \
  --workers 2 --resilient --chaos crash --score-impl numpy \
  --n-requests 4 --batch 3 --concurrency 2 \
  --max-batch 8 --max-wait-ms 2 --round-deadline-s 1
rm -rf "$CHAOS_TMP"

echo "== mutation smoke (live corpus: adds/re-caches/tombstones + compaction between micro-batches) =="
MUT_TMP="$(mktemp -d)"
python -m repro.launch.serve --smoke --mutate --data-dir "$MUT_TMP/data" \
  --workers 2 --score-impl numpy \
  --n-requests 4 --batch 3 --concurrency 2 \
  --max-batch 8 --max-wait-ms 2
rm -rf "$MUT_TMP"

echo "== ivf smoke (cluster-pruned serving: build/persist index, serve with --nprobe) =="
IVF_TMP="$(mktemp -d)"
python -m repro.launch.serve --smoke --data-dir "$IVF_TMP/data" \
  --index-impl ivf --nclusters 8 --nprobe 2 \
  --n-requests 4 --batch 3 --concurrency 2 --workers 1 \
  --max-batch 8 --max-wait-ms 2
rm -rf "$IVF_TMP"

# Optional perf gate: re-run the JSON-recording benches and compare
# against the committed results/*.json baselines (relative metrics,
# tolerance for container noise).  Off by default — timing on shared CI
# boxes is advisory; flip on with RUN_BENCH_CHECK=1.
if [[ "${RUN_BENCH_CHECK:-0}" == "1" ]]; then
  echo "== bench regression check (results/*.json baselines) =="
  python benchmarks/run.py --check ${BENCH_CHECK_TOL:+--tol "$BENCH_CHECK_TOL"}
fi
