#!/usr/bin/env bash
# Tier-1 gate: fail fast on the quick suite, then run the full tier-1
# command from ROADMAP.md.  Usage: scripts/run_tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (fast inner loop: -m 'not slow') =="
python -m pytest -x -q -m "not slow" "$@"

echo "== tier-1 (full suite) =="
python -m pytest -x -q "$@"
