"""Deterministic hashing tokenizer (offline stand-in for HF tokenizers).

Splits on whitespace/punctuation; each token maps to a stable
blake2-hashed id.  No vocabulary files, fully reproducible, adequate for
the framework's data-path and training mechanics (the encoder never sees
raw text anyway).

The batch path (``batch_encode_ids`` / ``batch_encode``) hashes each
*unique* token of the batch exactly once via ``np.unique`` and maps ids
back through the inverse index — corpus text repeats tokens heavily, so
the per-occurrence dict lookup + blake2 call of the scalar path is the
wrong loop to be in for bulk encoding.
"""

from __future__ import annotations

import hashlib
import re

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9]+|[^\sa-z0-9]")


class HashTokenizer:
    pad_id = 0
    bos_id = 1
    eos_id = 2
    n_special = 3

    def __init__(self, vocab_size: int = 50304, lowercase: bool = True):
        assert vocab_size > self.n_special
        self.vocab_size = vocab_size
        self.lowercase = lowercase
        self._cache: dict[str, int] = {}

    def _token_id(self, tok: str) -> int:
        tid = self._cache.get(tok)
        if tid is None:
            h = hashlib.blake2b(tok.encode(), digest_size=8).digest()
            tid = self.n_special + int.from_bytes(h, "little") % (
                self.vocab_size - self.n_special)
            if len(self._cache) < 1_000_000:
                self._cache[tok] = tid
        return tid

    def encode(self, text: str, max_len: int | None = None,
               append_eos: bool = False) -> list[int]:
        if self.lowercase:
            text = text.lower()
        ids = [self._token_id(t) for t in _TOKEN_RE.findall(text)]
        if append_eos:
            ids.append(self.eos_id)
        if max_len is not None:
            ids = ids[:max_len]
            # truncation may leave nothing to overwrite (max_len == 0 or
            # an empty text): only re-pin the eos on a non-empty tail
            if append_eos and ids and ids[-1] != self.eos_id:
                ids[-1] = self.eos_id
        return ids

    def batch_encode_ids(self, texts: list[str],
                         max_len: int | None = None,
                         append_eos: bool = False) -> list[list[int]]:
        """Tokenize a batch; hash each unique token once (``np.unique``).

        Returns exactly ``[self.encode(t, max_len, append_eos) for t in
        texts]`` — the scalar path is the semantic reference — but the
        token -> id mapping runs over the batch's unique tokens only.
        """
        if not texts:
            return []
        if self.lowercase:
            texts = [t.lower() for t in texts]
        rows = [_TOKEN_RE.findall(t) for t in texts]
        flat = [t for row in rows for t in row]
        if flat:
            uniq, inverse = np.unique(np.asarray(flat, dtype=object),
                                      return_inverse=True)
            uniq_ids = np.fromiter((self._token_id(t) for t in uniq),
                                   np.int64, count=len(uniq))
            flat_ids = uniq_ids[inverse]
        else:
            flat_ids = np.empty(0, np.int64)
        out: list[list[int]] = []
        pos = 0
        for row in rows:
            ids = flat_ids[pos: pos + len(row)].tolist()
            pos += len(row)
            if append_eos:
                ids.append(self.eos_id)
            if max_len is not None:
                ids = ids[:max_len]
                if append_eos and ids and ids[-1] != self.eos_id:
                    ids[-1] = self.eos_id
            out.append(ids)
        return out

    def batch_encode(self, texts: list[str], max_len: int,
                     append_eos: bool = False,
                     pad_to_multiple: int = 1):
        """Returns (tokens (B, L) int32, mask (B, L) int32)."""
        enc = self.batch_encode_ids(texts, max_len, append_eos)
        longest = max((len(e) for e in enc), default=1)
        longest = max(longest, 1)
        if pad_to_multiple > 1:
            longest = -(-longest // pad_to_multiple) * pad_to_multiple
        longest = min(longest, max_len) if max_len else longest
        return pad_token_rows(enc, longest, self.pad_id)


def pad_token_rows(rows: list[list[int]], length: int, pad_id: int = 0,
                   n_rows: int | None = None):
    """Stack ragged id rows into ((B, L) tokens, (B, L) mask) int32.

    ``n_rows`` > len(rows) appends all-pad rows (mask 0) — the encode
    pipeline's fixed-batch-dim ragged tail.  Rows longer than ``length``
    are truncated.
    """
    b = len(rows) if n_rows is None else n_rows
    length = max(length, 1)
    toks = np.full((b, length), pad_id, np.int32)
    mask = np.zeros((b, length), np.int32)
    for i, e in enumerate(rows):
        e = e[:length]
        toks[i, : len(e)] = e
        mask[i, : len(e)] = 1
    return toks, mask
