"""Deterministic hashing tokenizer (offline stand-in for HF tokenizers).

Splits on whitespace/punctuation; each token maps to a stable
blake2-hashed id.  No vocabulary files, fully reproducible, adequate for
the framework's data-path and training mechanics (the encoder never sees
raw text anyway).
"""

from __future__ import annotations

import hashlib
import re

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9]+|[^\sa-z0-9]")


class HashTokenizer:
    pad_id = 0
    bos_id = 1
    eos_id = 2
    n_special = 3

    def __init__(self, vocab_size: int = 50304, lowercase: bool = True):
        assert vocab_size > self.n_special
        self.vocab_size = vocab_size
        self.lowercase = lowercase
        self._cache: dict[str, int] = {}

    def _token_id(self, tok: str) -> int:
        tid = self._cache.get(tok)
        if tid is None:
            h = hashlib.blake2b(tok.encode(), digest_size=8).digest()
            tid = self.n_special + int.from_bytes(h, "little") % (
                self.vocab_size - self.n_special)
            if len(self._cache) < 1_000_000:
                self._cache[tok] = tid
        return tid

    def encode(self, text: str, max_len: int | None = None,
               append_eos: bool = False) -> list[int]:
        if self.lowercase:
            text = text.lower()
        ids = [self._token_id(t) for t in _TOKEN_RE.findall(text)]
        if append_eos:
            ids.append(self.eos_id)
        if max_len is not None:
            ids = ids[:max_len]
            if append_eos and (not ids or ids[-1] != self.eos_id):
                ids[-1] = self.eos_id
        return ids

    def batch_encode(self, texts: list[str], max_len: int,
                     append_eos: bool = False,
                     pad_to_multiple: int = 1):
        """Returns (tokens (B, L) int32, mask (B, L) int32)."""
        enc = [self.encode(t, max_len, append_eos) for t in texts]
        longest = max((len(e) for e in enc), default=1)
        longest = max(longest, 1)
        if pad_to_multiple > 1:
            longest = -(-longest // pad_to_multiple) * pad_to_multiple
        longest = min(longest, max_len) if max_len else longest
        toks = np.full((len(enc), longest), self.pad_id, np.int32)
        mask = np.zeros((len(enc), longest), np.int32)
        for i, e in enumerate(enc):
            e = e[:longest]
            toks[i, : len(e)] = e
            mask[i, : len(e)] = 1
        return toks, mask
