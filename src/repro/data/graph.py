"""Graph substrate: CSR adjacency + the *real* neighbor sampler
(GraphSAGE minibatch training, spec: "minibatch_lg needs a real neighbor
sampler").

Sampling produces fixed-fanout dense index tensors — (B,), (B,f1),
(B,f1,f2) — TPU-friendly (no ragged shapes): degree-deficient nodes
sample with replacement; isolated nodes self-loop.
"""

from __future__ import annotations

import numpy as np


class CSRGraph:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 n_nodes: int):
        self.indptr = indptr
        self.indices = indices
        self.n_nodes = n_nodes

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray,
                   n_nodes: int) -> "CSRGraph":
        order = np.argsort(dst, kind="stable")
        dst_sorted = dst[order]
        src_sorted = src[order]
        counts = np.bincount(dst_sorted, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(indptr, src_sorted.astype(np.int32), n_nodes)

    def degree(self, nodes: np.ndarray) -> np.ndarray:
        return self.indptr[nodes + 1] - self.indptr[nodes]

    def neighbors(self, node: int) -> np.ndarray:
        return self.indices[self.indptr[node]: self.indptr[node + 1]]


class NeighborSampler:
    """Uniform fixed-fanout sampler (GraphSAGE §3.1)."""

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...],
                 seed: int = 0):
        self.graph = graph
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def _sample_level(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """nodes (N,) -> neighbor ids (N, fanout)."""
        g = self.graph
        deg = g.degree(nodes)
        out = np.empty((len(nodes), fanout), np.int32)
        offs = self.rng.integers(0, 1 << 31, size=(len(nodes), fanout))
        for i, (node, d) in enumerate(zip(nodes, deg)):
            if d == 0:
                out[i] = node                       # isolated: self-loop
            else:
                lo = g.indptr[node]
                out[i] = g.indices[lo + offs[i] % d]
        return out

    def sample(self, batch_nodes: np.ndarray):
        """-> (level0 (B,), level1 (B,f1), level2 (B,f1,f2), ...)."""
        levels = [np.asarray(batch_nodes, np.int32)]
        frontier = levels[0]
        for fanout in self.fanouts:
            nxt = self._sample_level(frontier.reshape(-1), fanout)
            levels.append(nxt.reshape(frontier.shape + (fanout,)))
            frontier = levels[-1]
        return levels

    def sample_block(self, x: np.ndarray, batch_nodes: np.ndarray):
        """Gathered features for a 2-hop block: (feats0, feats1, feats2)."""
        l0, l1, l2 = self.sample(batch_nodes)
        return x[l0], x[l1], x[l2]

    def positive_pairs(self, batch_nodes: np.ndarray) -> np.ndarray:
        """Co-occurrence positives: one random neighbor per node
        (the unsupervised GraphSAGE objective's positive sample)."""
        pos = self._sample_level(np.asarray(batch_nodes, np.int32),
                                 1)[:, 0]
        return pos


def make_random_graph(n_nodes: int, avg_degree: int, seed: int = 0,
                      n_communities: int = 8):
    """Community-structured random graph (tests/examples): nodes in the
    same community connect preferentially, so GraphSAGE embeddings carry
    a learnable retrieval signal."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_communities, n_nodes)
    n_edges = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    # 80% of edges stay within the community
    same = rng.random(n_edges) < 0.8
    candidates = rng.integers(0, n_nodes, (n_edges, 8))
    match = comm[candidates] == comm[src][:, None]
    pick = np.argmax(match, axis=1)
    intra = candidates[np.arange(n_edges), pick].astype(np.int32)
    dst = np.where(same & match.any(1), intra,
                   rng.integers(0, n_nodes, n_edges)).astype(np.int32)
    keep = src != dst
    return src[keep], dst[keep], comm
