"""Synthetic retrieval data generators (offline stand-ins for MS MARCO).

Generates topic-structured corpora where each query shares rare "topic
tokens" with its relevant documents, so a trained bi-encoder can actually
learn the retrieval signal (used by examples, tests, and benchmarks).
"""

from __future__ import annotations

import json
import os

import numpy as np

_WORDS = [
    "alpha", "bravo", "charlie", "delta", "echo", "foxtrot", "golf",
    "hotel", "india", "juliet", "kilo", "lima", "mike", "november",
    "oscar", "papa", "quebec", "romeo", "sierra", "tango", "uniform",
    "victor", "whiskey", "xray", "yankee", "zulu",
]


def _text(rng: np.random.Generator, topic: int, n_words: int,
          n_topics: int) -> str:
    topic_tok = f"topic{topic}"
    fillers = rng.choice(_WORDS, size=n_words)
    pos = rng.integers(0, n_words, size=max(1, n_words // 6))
    words = list(fillers)
    for p in pos:
        words[p] = topic_tok
    return " ".join(words)


def make_retrieval_dataset(out_dir: str, n_queries: int = 64,
                           n_docs: int = 512, n_topics: int = 32,
                           doc_len: int = 30, query_len: int = 6,
                           graded: bool = False, seed: int = 0,
                           id_prefix: str = ""):
    """Writes corpus.jsonl, queries.jsonl, qrels/train.tsv (+ dev split).

    ``id_prefix`` namespaces every query/doc id (multi-dataset eval
    suites need disjoint id spaces across datasets).
    Returns (queries dict, corpus dict, qrels dict) for convenience.
    """
    rng = np.random.default_rng(seed)
    os.makedirs(os.path.join(out_dir, "qrels"), exist_ok=True)

    doc_topics = rng.integers(0, n_topics, size=n_docs)
    corpus = {}
    with open(os.path.join(out_dir, "corpus.jsonl"), "w") as f:
        for i in range(n_docs):
            did = f"{id_prefix}doc{i}"
            text = _text(rng, int(doc_topics[i]), doc_len, n_topics)
            corpus[did] = text
            f.write(json.dumps({"_id": did, "text": text}) + "\n")

    queries, qrels = {}, {}
    q_topics = rng.integers(0, n_topics, size=n_queries)
    with open(os.path.join(out_dir, "queries.jsonl"), "w") as f, \
            open(os.path.join(out_dir, "qrels", "train.tsv"), "w") as qf:
        for i in range(n_queries):
            qid = f"{id_prefix}q{i}"
            topic = int(q_topics[i])
            text = _text(rng, topic, query_len, n_topics)
            queries[qid] = text
            f.write(json.dumps({"_id": qid, "text": text}) + "\n")
            rel_docs = np.nonzero(doc_topics == topic)[0]
            qrels[qid] = {}
            for j, d in enumerate(rel_docs[:4]):
                grade = (3 - min(j, 2)) if graded else 1
                qrels[qid][f"{id_prefix}doc{d}"] = float(grade)
                qf.write(f"{qid}\t{id_prefix}doc{d}\t{grade}\n")
    return queries, corpus, qrels


def make_synthetic_multilevel(out_dir: str, queries: dict, corpus_size: int,
                              n_topics: int = 32, seed: int = 1):
    """Extra synthetic passages with graded labels (SyCL-style source)."""
    rng = np.random.default_rng(seed)
    path = os.path.join(out_dir, "synthetic.jsonl")
    qrel_path = os.path.join(out_dir, "qrels", "synthetic.tsv")
    with open(path, "w") as f, open(qrel_path, "w") as qf:
        for qi, (qid, qtext) in enumerate(queries.items()):
            topic = next((t for t in qtext.split() if t.startswith("topic")),
                         "topic0")
            for level in (3, 2, 1, 0):
                did = f"syn_{qid}_{level}"
                words = [topic] * (level + 1) + list(
                    rng.choice(_WORDS, size=20 - level))
                rng.shuffle(words)
                f.write(json.dumps(
                    {"_id": did, "text": " ".join(words)}) + "\n")
                qf.write(f"{qid}\t{did}\t{level}\n")
    return path, qrel_path
