"""Memory-mapped, ID-indexed record tables (the paper's Arrow-table role).

A table directory holds:
  ids.npy      int64 hashed ids, insertion order        (mmap'd)
  sortidx.npy  argsort(ids) permutation                 (mmap'd)
  offsets.npy  int64 (n+1,) byte offsets into payload   (mmap'd)
  payload.bin  concatenated UTF-8 JSON rows             (mmap'd)
  meta.json    fingerprint + row count

Design property the paper relies on (Table 1): resident memory is
O(touched rows), not O(dataset) — only the pages of rows actually read are
faulted in.  Lookups are O(log n) via searchsorted on the mmap'd id index.
Builds are atomic (tmp dir + os.replace) and fingerprinted so rebuilds are
skipped when the source is unchanged (Table 4: TTFS ~ 0 after first run).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Iterable, Iterator

import numpy as np


_HASH_MASK = 0x7FFFFFFFFFFFFFFF


def stable_id_hash(raw_id: str | int) -> int:
    """Stable 63-bit id hash (strings and ints share the space)."""
    if isinstance(raw_id, (int, np.integer)):
        return int(raw_id) & _HASH_MASK
    h = hashlib.blake2b(str(raw_id).encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") & _HASH_MASK


def stable_id_hash_array(ids) -> np.ndarray:
    """Vectorized ``stable_id_hash`` over a sequence -> int64 (n,).

    Integer ids mask in one numpy op; string ids hash in a single pass
    (blake2b is per-element by nature, but callers hash each id set once
    and reuse the array instead of re-looping per search call).
    """
    if isinstance(ids, np.ndarray) and ids.dtype.kind in "iu":
        return ids.astype(np.int64) & _HASH_MASK
    if len(ids) and all(isinstance(i, (int, np.integer)) for i in ids):
        try:
            return np.asarray(ids, np.int64) & _HASH_MASK
        except OverflowError:     # ints beyond int64: mask in Python like
            pass                  # stable_id_hash does
        return np.fromiter((int(i) & _HASH_MASK for i in ids), np.int64,
                           count=len(ids))
    return np.fromiter((stable_id_hash(i) for i in ids), np.int64,
                       count=len(ids))


def file_fingerprint(path: str, extra: str = "") -> str:
    st = os.stat(path)
    key = f"{os.path.abspath(path)}:{st.st_size}:{st.st_mtime_ns}:{extra}"
    return hashlib.blake2b(key.encode(), digest_size=16).hexdigest()


def config_fingerprint(obj: Any) -> str:
    return hashlib.blake2b(repr(obj).encode(), digest_size=16).hexdigest()


def atomic_write_dir(final_dir: str):
    """Context manager: build into a tmp dir, atomically move into place."""

    class _Ctx:
        def __enter__(self):
            os.makedirs(os.path.dirname(final_dir) or ".", exist_ok=True)
            self.tmp = tempfile.mkdtemp(
                dir=os.path.dirname(final_dir) or ".",
                prefix=".tmp_" + os.path.basename(final_dir))
            return self.tmp

        def __exit__(self, exc_type, *a):
            if exc_type is not None:
                shutil.rmtree(self.tmp, ignore_errors=True)
                return False
            if os.path.exists(final_dir):
                shutil.rmtree(self.tmp, ignore_errors=True)
            else:
                os.replace(self.tmp, final_dir)
            return False

    return _Ctx()


class MMapTable:
    """ID-indexed mmap'd record store."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, "meta.json")) as f:
            self.meta = json.load(f)
        self._ids = np.load(os.path.join(path, "ids.npy"), mmap_mode="r")
        self._sort = np.load(os.path.join(path, "sortidx.npy"), mmap_mode="r")
        self._offsets = np.load(
            os.path.join(path, "offsets.npy"), mmap_mode="r")
        self._payload = np.memmap(
            os.path.join(path, "payload.bin"), dtype=np.uint8, mode="r")
        self._sorted_ids = None     # materialized lazily for fast lookup

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, records: Iterable[dict], path: str,
              fingerprint: str = "", id_key: str = "_id") -> "MMapTable":
        with atomic_write_dir(path) as tmp:
            ids: list[int] = []
            offsets = [0]
            with open(os.path.join(tmp, "payload.bin"), "wb") as payload:
                for rec in records:
                    raw = rec.get(id_key, len(ids))
                    rec = dict(rec)
                    rec[id_key] = raw if isinstance(raw, str) else int(raw)
                    ids.append(stable_id_hash(raw))
                    blob = json.dumps(rec, ensure_ascii=False).encode()
                    payload.write(blob)
                    offsets.append(offsets[-1] + len(blob))
            ids_arr = np.asarray(ids, np.int64)
            sortidx = np.argsort(ids_arr, kind="stable")
            sorted_ids = ids_arr[sortidx]
            dup = np.nonzero(sorted_ids[1:] == sorted_ids[:-1])[0]
            if dup.size:
                raise ValueError(
                    f"id hash collision/duplicate ids ({dup.size}) "
                    f"building {path}")
            np.save(os.path.join(tmp, "ids.npy"), ids_arr)
            np.save(os.path.join(tmp, "sortidx.npy"),
                    sortidx.astype(np.int64))
            np.save(os.path.join(tmp, "offsets.npy"),
                    np.asarray(offsets, np.int64))
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"n": len(ids_arr), "fingerprint": fingerprint}, f)
        return cls(path)

    @classmethod
    def build_cached(cls, records_fn, cache_dir: str,
                     fingerprint: str) -> "MMapTable":
        """Reuse the table if the fingerprint matches (paper: TTFS)."""
        path = os.path.join(cache_dir, fingerprint)
        meta = os.path.join(path, "meta.json")
        if os.path.exists(meta):
            try:
                with open(meta) as f:
                    if json.load(f).get("fingerprint") == fingerprint:
                        return cls(path)
            except (json.JSONDecodeError, OSError):
                shutil.rmtree(path, ignore_errors=True)
        return cls.build(records_fn(), path, fingerprint)

    # -- access ---------------------------------------------------------------
    def __len__(self) -> int:
        return int(self.meta["n"])

    @property
    def id_hashes(self) -> np.ndarray:
        return self._ids

    def row(self, i: int) -> dict:
        lo, hi = int(self._offsets[i]), int(self._offsets[i + 1])
        return json.loads(bytes(self._payload[lo:hi]).decode())

    def _ensure_sorted(self):
        if self._sorted_ids is None:
            self._sorted_ids = np.asarray(self._ids)[np.asarray(self._sort)]

    def index_of(self, raw_or_hash) -> int:
        h = (raw_or_hash if isinstance(raw_or_hash, (int, np.integer))
             else stable_id_hash(raw_or_hash))
        self._ensure_sorted()
        pos = int(np.searchsorted(self._sorted_ids, h))
        if pos >= len(self._sorted_ids) or self._sorted_ids[pos] != h:
            raise KeyError(raw_or_hash)
        return int(self._sort[pos])

    def indices_of(self, hashes: np.ndarray) -> np.ndarray:
        self._ensure_sorted()
        pos = np.searchsorted(self._sorted_ids, hashes)
        pos = np.clip(pos, 0, len(self._sorted_ids) - 1)
        ok = self._sorted_ids[pos] == hashes
        if not ok.all():
            missing = hashes[~ok][:5]
            raise KeyError(f"{(~ok).sum()} ids not in table, e.g. {missing}")
        return np.asarray(self._sort)[pos]

    def get(self, raw_or_hash) -> dict:
        return self.row(self.index_of(raw_or_hash))

    def __contains__(self, raw_or_hash) -> bool:
        try:
            self.index_of(raw_or_hash)
            return True
        except KeyError:
            return False

    def iter_rows(self) -> Iterator[dict]:
        for i in range(len(self)):
            yield self.row(i)

    def advise_dontneed(self, lo_row: int, hi_row: int) -> None:
        """Advise the payload pages of rows ``[lo_row, hi_row)`` away.

        Streaming consumers (``views.TableView.open_slice``) call this
        after a chunk is consumed so a full scan's resident set stays
        flat instead of faulting the whole payload in.  Only pages
        fully inside the byte range are dropped (boundary pages are
        shared with neighbouring rows); clean file-backed pages re-fault
        on the next access, so this is purely a residency hint.
        Best effort: platforms without ``mmap.madvise`` no-op.
        """
        try:
            import mmap as _mmap
            mm = self._payload._mmap            # the backing mmap object
            page = _mmap.PAGESIZE
            start = int(self._offsets[max(lo_row, 0)])
            end = int(self._offsets[min(hi_row, len(self))])
            start = -(-start // page) * page    # round up
            end = (end // page) * page          # round down
            if end > start:
                mm.madvise(_mmap.MADV_DONTNEED, start, end - start)
        except (AttributeError, ValueError, OSError):
            pass
