"""Lazy dataset-view algebra (paper §3.2): load, filter, select,
transform and combine retrieval datasets on the fly, with no
materialized copies.

A :class:`DatasetView` is an ordered, id-indexed collection of record
dicts that is *never* resident as a whole: rows materialize per access
(``row(i)``) or per chunk (``open_slice``), so resident memory stays
O(touched rows) through arbitrary compositions — the paper's 2.6x
memory-reduction mechanism extended from single tables to whole
dataset expressions.

Combinators (all lazy, all composable)::

    v = TableView(table)                      # leaf over an mmap table
    v = v.filter(lambda r: len(r["text"]) > 8)
    v = v.map(lambda r: {**r, "text": r["text"].lower()})
    v = v.select(["doc3", "doc1"])            # id (or position) subset
    v = ConcatView(v, other)                  # or  v + other
    v = InterleaveView(a, b, c)               # round-robin combine

Index discipline: a view may hold O(n) *int64 index/id arrays* (like
``MaterializedQRel``'s grouped qrel arrays) but never O(n) row
payloads.  ``FilterView`` therefore streams its parent once, chunk by
chunk, to build its kept-position index the first time a length, id or
row is requested — rows evaluated by the predicate are dropped
immediately.

Streaming contract: ``open_slice(lo, hi, chunk_size)`` yields ordered
``(offset, rows)`` chunks, mirroring the embedding chunk-source
contract of ``ShardedSearchDriver`` one layer below — the evaluator
zips the two so a search over ``ConcatView(a, b)`` scores per chunk
and the combined corpus never exists on disk or in RAM.  After each
chunk is consumed the view ``evict``s it: mmap-backed leaves advise
the touched payload pages away, so even a full scan's resident set
stays flat.
"""

from __future__ import annotations

from typing import Callable, Iterator, Sequence

import numpy as np

from repro.data.table import MMapTable, stable_id_hash, stable_id_hash_array


def row_text(rec: dict) -> str:
    """Canonical text of a record (title-prefixed, like ``doc_text``)."""
    title = rec.get("title", "")
    return f"{title} {rec.get('text', '')}".strip() if title \
        else str(rec.get("text", ""))


class ViewTexts(Sequence):
    """Lazy ``Sequence[str]`` adapter over a view's row texts.

    Slices materialize only the requested span (the encode pipeline
    pulls window-sized slices), so handing this to
    ``PipelineChunkSource`` keeps the O(touched rows) property.
    """

    def __init__(self, view: "DatasetView"):
        self.view = view

    def __len__(self) -> int:
        return len(self.view)

    def __getitem__(self, i):
        if isinstance(i, slice):
            lo, hi, step = i.indices(len(self.view))
            if step != 1:
                return [self.view.text(j) for j in range(lo, hi, step)]
            return [row_text(r) for r in self.view.rows(lo, hi)]
        return self.view.text(i)

    def __iter__(self) -> Iterator[str]:
        for lo in range(0, len(self.view), 1024):
            yield from self[lo: lo + 1024]


class DatasetView:
    """Base class: ordered, id-indexed, lazily materialized records.

    Subclasses implement ``__len__``, ``row(i)`` and ``_hashes()``;
    everything else (chunked streaming, id lookup, combinators, text
    adapters) is shared.
    """

    # -- required surface -----------------------------------------------------
    def __len__(self) -> int:
        raise NotImplementedError

    def row(self, i: int) -> dict:
        raise NotImplementedError

    def _hashes(self) -> np.ndarray:
        raise NotImplementedError

    # -- ids ------------------------------------------------------------------
    @property
    def id_hashes(self) -> np.ndarray:
        """int64 (n,) stable id hashes in view order (cached)."""
        h = getattr(self, "_id_hashes", None)
        if h is None:
            h = np.asarray(self._hashes(), np.int64)
            self._id_hashes = h
        return h

    def _ensure_sorted(self):
        if getattr(self, "_sorted_ids", None) is None:
            self._sort = np.argsort(self.id_hashes, kind="stable")
            self._sorted_ids = self.id_hashes[self._sort]

    def index_of(self, raw_or_hash) -> int:
        """View position of an id (raw or hashed) — O(log n)."""
        h = (int(raw_or_hash) & 0x7FFFFFFFFFFFFFFF
             if isinstance(raw_or_hash, (int, np.integer))
             else stable_id_hash(raw_or_hash))
        self._ensure_sorted()
        pos = int(np.searchsorted(self._sorted_ids, h))
        if pos >= len(self._sorted_ids) or self._sorted_ids[pos] != h:
            raise KeyError(raw_or_hash)
        return int(self._sort[pos])

    def get(self, raw_or_hash) -> dict:
        return self.row(self.index_of(raw_or_hash))

    def __contains__(self, raw_or_hash) -> bool:
        try:
            self.index_of(raw_or_hash)
            return True
        except KeyError:
            return False

    def raw_id(self, i: int):
        return self.row(i).get("_id", int(self.id_hashes[i]))

    def raw_ids(self) -> list:
        """All raw ids (materializes ids only, not row payloads)."""
        out = []
        for lo in range(0, len(self), 1024):
            out.extend(r.get("_id") for r in self.rows(
                lo, min(lo + 1024, len(self))))
        return out

    # -- rows -----------------------------------------------------------------
    def rows(self, lo: int, hi: int) -> list[dict]:
        """Materialize one bounded span (combinators may specialize)."""
        return [self.row(i) for i in range(lo, hi)]

    def text(self, i: int) -> str:
        return row_text(self.row(i))

    def texts(self) -> ViewTexts:
        return ViewTexts(self)

    def iter_rows(self) -> Iterator[dict]:
        for off, chunk in self.open_slice(0, len(self), 1024):
            yield from chunk

    def open_slice(self, lo: int, hi: int, chunk_size: int):
        """Yield ordered ``(offset, rows)`` chunks over ``[lo, hi)``.

        Each chunk holds exactly ``chunk_size`` rows (the tail may be
        ragged); after the consumer resumes, the previous chunk's
        source pages are advised away (``evict``) so a full streaming
        scan keeps a flat resident set.
        """
        hi = min(hi, len(self))
        for off in range(lo, hi, max(chunk_size, 1)):
            end = min(off + chunk_size, hi)
            yield off, self.rows(off, end)
            self.evict(off, end)

    def evict(self, lo: int, hi: int) -> None:
        """Hint that rows ``[lo, hi)`` were consumed (best effort)."""

    # -- combinators ----------------------------------------------------------
    def filter(self, fn: Callable[[dict], bool]) -> "FilterView":
        return FilterView(self, fn)

    def map(self, fn: Callable[[dict], dict], *,
            rekey: bool = False) -> "MapView":
        return MapView(self, fn, rekey=rekey)

    def select(self, sel) -> "SelectView":
        return SelectView(self, sel)

    def concat(self, *others: "DatasetView") -> "ConcatView":
        return ConcatView(self, *others)

    def __add__(self, other: "DatasetView") -> "ConcatView":
        return ConcatView(self, other)

    def interleave(self, *others: "DatasetView") -> "InterleaveView":
        return InterleaveView(self, *others)


# -- leaves -------------------------------------------------------------------


class TableView(DatasetView):
    """Leaf over an :class:`MMapTable` — rows stay on disk until read."""

    def __init__(self, table: MMapTable):
        self.table = table

    def __len__(self) -> int:
        return len(self.table)

    def row(self, i: int) -> dict:
        return self.table.row(i)

    def _hashes(self) -> np.ndarray:
        return np.asarray(self.table.id_hashes, np.int64)

    def evict(self, lo: int, hi: int) -> None:
        self.table.advise_dontneed(lo, hi)


class DictView(DatasetView):
    """Leaf over an in-memory ``{raw_id: text}`` mapping (the legacy
    evaluator corpus format).  Texts are read from the dict *live* so
    callers that mutate values see fresh rows."""

    def __init__(self, mapping: dict):
        self._d = mapping
        self._keys = list(mapping.keys())

    def __len__(self) -> int:
        return len(self._keys)

    def row(self, i: int) -> dict:
        key = self._keys[i]
        return {"_id": key, "text": self._d[key]}

    def text(self, i: int) -> str:
        return str(self._d[self._keys[i]])

    def rows(self, lo: int, hi: int) -> list[dict]:
        return [{"_id": k, "text": self._d[k]}
                for k in self._keys[lo:hi]]

    def raw_id(self, i: int):
        return self._keys[i]

    def raw_ids(self) -> list:
        return list(self._keys)

    def _hashes(self) -> np.ndarray:
        return stable_id_hash_array(self._keys)


class RecordsView(DatasetView):
    """Leaf over an in-memory record list (tests, synthetic sources)."""

    def __init__(self, records: Sequence[dict], id_key: str = "_id"):
        self._recs = list(records)
        self._id_key = id_key

    def __len__(self) -> int:
        return len(self._recs)

    def row(self, i: int) -> dict:
        return self._recs[i]

    def rows(self, lo: int, hi: int) -> list[dict]:
        return list(self._recs[lo:hi])

    def _hashes(self) -> np.ndarray:
        return stable_id_hash_array(
            [r.get(self._id_key, i) for i, r in enumerate(self._recs)])


# -- combinators --------------------------------------------------------------


class FilterView(DatasetView):
    """Rows of ``parent`` where ``fn(row)`` is truthy, in parent order.

    The kept-position index (int64, O(n_kept)) builds lazily on first
    use by streaming the parent chunk by chunk — candidate rows are
    evaluated and dropped, never retained.
    """

    def __init__(self, parent: DatasetView, fn: Callable[[dict], bool]):
        self.parent = parent
        self.fn = fn
        self._idx: np.ndarray | None = None

    def _index(self) -> np.ndarray:
        if self._idx is None:
            kept: list[int] = []
            for off, chunk in self.parent.open_slice(
                    0, len(self.parent), 1024):
                kept.extend(off + j for j, r in enumerate(chunk)
                            if self.fn(r))
            self._idx = np.asarray(kept, np.int64)
        return self._idx

    def __len__(self) -> int:
        return len(self._index())

    def row(self, i: int) -> dict:
        return self.parent.row(int(self._index()[i]))

    def rows(self, lo: int, hi: int) -> list[dict]:
        idx = self._index()[lo:hi]
        return [self.parent.row(int(i)) for i in idx]

    def _hashes(self) -> np.ndarray:
        return np.asarray(self.parent.id_hashes)[self._index()]

    def evict(self, lo: int, hi: int) -> None:
        idx = self._index()[lo:hi]
        if len(idx):
            self.parent.evict(int(idx[0]), int(idx[-1]) + 1)


class MapView(DatasetView):
    """``fn(row)`` applied on every read (on-the-fly transform).

    By default ``fn`` must preserve ``_id`` (ids are answered from the
    parent without materializing rows).  Pass ``rekey=True`` for
    id-rewriting transforms (e.g. namespacing ``_id`` per source
    before a concat): ids are then recomputed by streaming the view
    once, rows still never retained.
    """

    def __init__(self, parent: DatasetView, fn: Callable[[dict], dict],
                 *, rekey: bool = False):
        self.parent = parent
        self.fn = fn
        self.rekey = rekey

    def __len__(self) -> int:
        return len(self.parent)

    def row(self, i: int) -> dict:
        return self.fn(self.parent.row(i))

    def rows(self, lo: int, hi: int) -> list[dict]:
        return [self.fn(r) for r in self.parent.rows(lo, hi)]

    def _hashes(self) -> np.ndarray:
        if not self.rekey:
            return np.asarray(self.parent.id_hashes)
        out = np.empty(len(self), np.int64)
        for off, chunk in self.parent.open_slice(0, len(self), 1024):
            for j, r in enumerate(chunk):
                out[off + j] = stable_id_hash(self.fn(r).get("_id", off + j))
        return out

    def evict(self, lo: int, hi: int) -> None:
        self.parent.evict(lo, hi)


class SelectView(DatasetView):
    """Subset/reorder of ``parent`` by positions or (raw/hashed) ids."""

    def __init__(self, parent: DatasetView, sel):
        self.parent = parent
        if isinstance(sel, np.ndarray) and sel.dtype.kind == "b":
            if len(sel) != len(parent):
                raise IndexError(
                    f"boolean mask length {len(sel)} != view length "
                    f"{len(parent)}")
            idx = np.nonzero(sel)[0].astype(np.int64)
        elif isinstance(sel, np.ndarray) and sel.dtype.kind in "iu":
            idx = sel.astype(np.int64)
        elif len(sel) and all(isinstance(s, (int, np.integer))
                              and not isinstance(s, bool) for s in sel):
            idx = np.asarray(sel, np.int64)
        else:                                   # raw ids -> positions
            idx = np.asarray([parent.index_of(s) for s in sel], np.int64)
        n = len(parent)
        if len(idx) and (idx.min() < -n or idx.max() >= n):
            raise IndexError(
                f"select positions outside [-{n}, {n})")
        self._idx = np.where(idx < 0, idx + n, idx)

    def __len__(self) -> int:
        return len(self._idx)

    def row(self, i: int) -> dict:
        return self.parent.row(int(self._idx[i]))

    def rows(self, lo: int, hi: int) -> list[dict]:
        return [self.parent.row(int(i)) for i in self._idx[lo:hi]]

    def _hashes(self) -> np.ndarray:
        return np.asarray(self.parent.id_hashes)[self._idx]

    def evict(self, lo: int, hi: int) -> None:
        idx = self._idx[lo:hi]
        if len(idx):
            self.parent.evict(int(idx.min()), int(idx.max()) + 1)


class _MultiView(DatasetView):
    """Shared machinery for multi-parent combinators: a lazily built
    ``(child, child_pos)`` mapping per view position."""

    def __init__(self, *parents: DatasetView):
        if not parents:
            raise ValueError("need at least one view")
        self.parents = list(parents)
        self._child: np.ndarray | None = None       # (n,) parent index
        self._pos: np.ndarray | None = None         # (n,) position in parent

    def _build(self) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def _mapping(self):
        if self._child is None:
            self._child, self._pos = self._build()
        return self._child, self._pos

    def __len__(self) -> int:
        return sum(len(p) for p in self.parents)

    def row(self, i: int) -> dict:
        child, pos = self._mapping()
        return self.parents[int(child[i])].row(int(pos[i]))

    def rows(self, lo: int, hi: int) -> list[dict]:
        child, pos = self._mapping()
        return [self.parents[int(c)].row(int(p))
                for c, p in zip(child[lo:hi], pos[lo:hi])]

    def _hashes(self) -> np.ndarray:
        child, pos = self._mapping()
        out = np.empty(len(child), np.int64)
        for j, p in enumerate(self.parents):
            m = child == j
            out[m] = np.asarray(p.id_hashes)[pos[m]]
        return out

    def evict(self, lo: int, hi: int) -> None:
        child, pos = self._mapping()
        c, p = child[lo:hi], pos[lo:hi]
        for j, parent in enumerate(self.parents):
            pj = p[c == j]
            if len(pj):
                parent.evict(int(pj.min()), int(pj.max()) + 1)


class ConcatView(_MultiView):
    """Parents back to back: ``a[0..] b[0..] ...`` — the combined-corpus
    view (union eval without a union corpus)."""

    @property
    def _offsets(self) -> np.ndarray:
        # lazy: len() of a FilterView parent forces its index scan, so
        # building a concat must stay free until first access
        off = getattr(self, "_offsets_", None)
        if off is None:
            off = np.cumsum([0] + [len(p) for p in self.parents])
            self._offsets_ = off
        return off

    def _build(self):
        lens = [len(p) for p in self.parents]
        child = np.repeat(np.arange(len(lens)), lens).astype(np.int64)
        pos = np.concatenate(
            [np.arange(n, dtype=np.int64) for n in lens]) if lens \
            else np.empty(0, np.int64)
        return child, pos

    def row(self, i: int) -> dict:
        # direct offset arithmetic (no mapping arrays needed)
        if i < 0:
            i += len(self)
        j = int(np.searchsorted(self._offsets, i, side="right")) - 1
        return self.parents[j].row(i - int(self._offsets[j]))

    def rows(self, lo: int, hi: int) -> list[dict]:
        out: list[dict] = []
        for j, p in enumerate(self.parents):
            a = max(lo, int(self._offsets[j]))
            b = min(hi, int(self._offsets[j + 1]))
            if a < b:
                out.extend(p.rows(a - int(self._offsets[j]),
                                  b - int(self._offsets[j])))
        return out

    def _hashes(self) -> np.ndarray:
        if not self.parents:
            return np.empty(0, np.int64)
        return np.concatenate(
            [np.asarray(p.id_hashes, np.int64) for p in self.parents])

    def evict(self, lo: int, hi: int) -> None:
        for j, p in enumerate(self.parents):
            a = max(lo, int(self._offsets[j]))
            b = min(hi, int(self._offsets[j + 1]))
            if a < b:
                p.evict(a - int(self._offsets[j]),
                        b - int(self._offsets[j]))


class InterleaveView(_MultiView):
    """Round-robin combine: position ``i`` of every live parent before
    position ``i+1`` of any (parents that run out drop from the
    rotation) — the training-mixture combinator."""

    def _build(self):
        lens = [len(p) for p in self.parents]
        k = len(lens)
        child = np.repeat(np.arange(k), lens).astype(np.int64)
        pos = np.concatenate(
            [np.arange(n, dtype=np.int64) for n in lens]) if lens \
            else np.empty(0, np.int64)
        # round-robin order == sort by (parent position, parent index)
        order = np.argsort(pos * k + child, kind="stable")
        return child[order], pos[order]


def as_view(obj) -> DatasetView:
    """Coerce common corpus/query containers to a view.

    Accepts an existing view (returned as-is), an ``{id: text}`` dict
    (the legacy evaluator format), an :class:`MMapTable`, or a record
    list.
    """
    if isinstance(obj, DatasetView):
        return obj
    if isinstance(obj, dict):
        return DictView(obj)
    if isinstance(obj, MMapTable):
        return TableView(obj)
    if isinstance(obj, (list, tuple)) and (
            not obj or isinstance(obj[0], dict)):
        return RecordsView(obj)
    raise TypeError(
        f"cannot view {type(obj).__name__}; expected DatasetView, dict, "
        f"MMapTable, or record list")
