"""File loaders + registry (paper §3.2.3: ``@register_loader``)."""

from __future__ import annotations

import json
from typing import Callable, Iterator

import numpy as np

from repro.data.table import stable_id_hash

LOADER_REGISTRY: dict[str, Callable] = {}


def register_loader(name: str):
    def deco(fn):
        LOADER_REGISTRY[name] = fn
        return fn
    return deco


def _sniff(path: str) -> str:
    if path.endswith((".jsonl", ".json")):
        return "jsonl"
    return "tsv"


# -- record loaders (queries / corpus) ---------------------------------------

@register_loader("records_jsonl")
def load_records_jsonl(path: str) -> Iterator[dict]:
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)


@register_loader("records_tsv")
def load_records_tsv(path: str) -> Iterator[dict]:
    with open(path) as f:
        for line in f:
            parts = line.rstrip("\n").split("\t")
            if not parts or not parts[0]:
                continue
            rec = {"_id": parts[0], "text": parts[1] if len(parts) > 1 else ""}
            if len(parts) > 2:
                rec["title"] = parts[2]
            yield rec


def load_records(path: str, loader: str | None = None) -> Iterator[dict]:
    name = loader or ("records_" + _sniff(path))
    return LOADER_REGISTRY[name](path)


# -- qrel loaders -------------------------------------------------------------

@register_loader("qrels_tsv")
def load_qrels_tsv(path: str):
    """TSV: ``qid\tdid\tscore`` or TREC ``qid\t0\tdid\tscore``."""
    qids, dids, scores = [], [], []
    with open(path) as f:
        for line in f:
            parts = line.rstrip("\n").split("\t")
            if len(parts) < 2 or parts[0] in ("query-id", "qid"):
                continue
            if len(parts) >= 4:
                q, d, s = parts[0], parts[2], parts[3]
            elif len(parts) == 3:
                q, d, s = parts
            else:
                q, d, s = parts[0], parts[1], 1
            qids.append(stable_id_hash(q))
            dids.append(stable_id_hash(d))
            scores.append(float(s))
    return (np.asarray(qids, np.int64), np.asarray(dids, np.int64),
            np.asarray(scores, np.float32))


@register_loader("qrels_jsonl")
def load_qrels_jsonl(path: str):
    qids, dids, scores = [], [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            qids.append(stable_id_hash(rec["query_id"]))
            dids.append(stable_id_hash(rec["doc_id"]))
            scores.append(float(rec.get("score", 1)))
    return (np.asarray(qids, np.int64), np.asarray(dids, np.int64),
            np.asarray(scores, np.float32))


def load_qrels(path: str, loader: str | None = None):
    name = loader or ("qrels_" + _sniff(path))
    return LOADER_REGISTRY[name](path)
