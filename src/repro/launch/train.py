"""End-to-end training driver.

Single script, three scales — exactly the paper's "same script, any
number of nodes" posture:
  * CPU/dev:      python -m repro.launch.train --arch trove-base --smoke
  * single pod:   launched under a TPU runtime; mesh (16,16)
  * multi-pod:    --multi-pod; mesh (2,16,16); jax.distributed handles
                  process bootstrap (one process per host)

Builds the synthetic-or-real retrieval dataset via MaterializedQRel, a
BiEncoderRetriever on the selected --arch backbone, and runs
RetrievalTrainer (grad accumulation, async checkpoints, fault tolerance).
"""

from __future__ import annotations

import os


def main(argv=None):
    import jax

    from repro.core.collator import RetrievalCollator
    from repro.core.config import (DataArguments, MaterializedQRelConfig,
                                   ModelArguments,
                                   RetrievalTrainingArguments, parse_cli)
    from repro.core.datasets import BinaryDataset
    from repro.core.metrics import IRMetrics
    from repro.configs import get_arch
    from repro.data.synthetic import make_retrieval_dataset
    from repro.data.tokenizer import HashTokenizer
    from repro.models.encoder import DefaultEncoder
    from repro.models.retriever import BiEncoderRetriever
    from repro.training.trainer import RetrievalTrainer

    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="trove-base")
    ap.add_argument("--data-dir", default="/tmp/trove_data")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + synthetic data (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "pod", "multipod"])
    args, rest = ap.parse_known_args(argv)

    train_args, model_args, data_args = parse_cli(
        RetrievalTrainingArguments, ModelArguments, DataArguments,
        argv=rest)

    arch = get_arch(args.arch)
    if args.smoke:
        arch = arch.reduced()
        import dataclasses
        arch = arch.variant(dtype=jax.numpy.float32) \
            if hasattr(arch, "variant") else arch
    assert arch.family == "lm", "train.py drives LM retrieval encoders"

    if not os.path.exists(os.path.join(args.data_dir, "queries.jsonl")):
        make_retrieval_dataset(args.data_dir, n_queries=256, n_docs=2048,
                               n_topics=64)

    mesh = None
    if args.mesh == "pod" or args.multi_pod or args.mesh == "multipod":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(
            multi_pod=args.multi_pod or args.mesh == "multipod")

    tok = HashTokenizer(arch.cfg.vocab_size)
    data_args.vocab_size = arch.cfg.vocab_size
    retriever = BiEncoderRetriever.from_model_args(
        model_args, arch.cfg, encoder=DefaultEncoder(arch.cfg))
    collator = RetrievalCollator(data_args, tok)
    pos = MaterializedQRelConfig(
        min_score=1,
        qrel_path=os.path.join(args.data_dir, "qrels", "train.tsv"),
        query_path=os.path.join(args.data_dir, "queries.jsonl"),
        corpus_path=os.path.join(args.data_dir, "corpus.jsonl"))
    dataset = BinaryDataset(
        data_args, retriever.format_query, retriever.format_passage,
        pos, pos, cache_root=os.path.join(args.data_dir, "cache"))

    trainer = RetrievalTrainer(
        retriever, train_args, collator, dataset, mesh=mesh,
        dev_dataset=None, compute_metrics=IRMetrics())
    state = trainer.train()
    for rec in trainer.logs:
        print(rec)
    print(f"done at step {int(state['step'])}; "
          f"checkpoints in {train_args.output_dir}/checkpoints")


if __name__ == "__main__":
    main()
