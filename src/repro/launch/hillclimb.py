import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: compile named variants of a cell and diff the
roofline terms against the paper-faithful baseline.

  python -m repro.launch.hillclimb --cell gemma-7b:train_4k \
      --variants baseline,inline_mask --out results/hillclimb
"""

import argparse
import json
import time

import jax

from repro.configs import get_arch
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh

# named config transforms per family (LM variants use LMArch.variant)
LM_VARIANTS = {
    "baseline": {},
    "inline_mask": dict(inline_mask=True),
    "dus_cache": dict(dus_cache_update=True),
    "inline_mask+dus": dict(inline_mask=True, dus_cache_update=True),
    "no_sp_acts": dict(seq_shard_acts=False),
    "cap1.0": dict(capacity_factor=1.0),
    "chunk2048": dict(attn_chunk=2048),
    "chunk1024": dict(attn_chunk=1024),
    "chunk8192": dict(attn_chunk=8192),
    "no_remat": dict(remat=False),
    "moe_shardmap": dict(moe_impl="shardmap"),
    "moe_shardmap+inline_mask": dict(moe_impl="shardmap",
                                     inline_mask=True),
    "inline_mask+chunk2048": dict(inline_mask=True, attn_chunk=2048),
}

RECSYS_VARIANTS = {
    "baseline": ({}, {}),
    "psum_lookup": (dict(embedding_impl="psum"), {}),
    # spread retrieval candidates over the (otherwise idle) model axis:
    # the gathered-rows psum shrinks TP-fold and compute spreads TP-fold
    "cand_full_shard": ({}, {"candidates": ("pod", "data", "model")}),
    "psum+cand_shard": (dict(embedding_impl="psum"),
                        {"candidates": ("pod", "data", "model")}),
    # bf16-wire psum lookup + MLP resharded over the model axis
    "psum_bf16+mlp_shard": (dict(embedding_impl="psum",
                                 batch_full_shard=True), {}),
    "mlp_shard": (dict(batch_full_shard=True), {}),
    # serving-mode answer: replicate the table (fits HBM), rows never
    # cross the wire; candidates can then shard over EVERY axis
    "replicated_table": ({}, {"embed_rows": ()}),
    "repl_table+full_shard": (dict(batch_full_shard=True),
                              {"embed_rows": (),
                               "candidates": ("pod", "data", "model")}),
}


def variant_arch(arch, name: str):
    if name == "baseline":
        return arch
    if arch.family == "lm":
        return arch.variant(**LM_VARIANTS[name])
    if arch.family == "recsys":
        import dataclasses

        from repro.configs.base import RecSysArch
        cfg_kw, rules = RECSYS_VARIANTS[name]
        cfg = dataclasses.replace(arch.cfg, **cfg_kw)
        return RecSysArch(cfg, shapes=arch.shapes, rule_overrides=rules)
    raise KeyError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()

    arch_name, shape = args.cell.split(":")
    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rows = []
    for vname in args.variants.split(","):
        arch = variant_arch(get_arch(arch_name), vname)
        t0 = time.monotonic()
        try:
            rec = run_cell(arch_name, shape, args.multi_pod, mesh=mesh,
                           arch=arch)
        except Exception as e:                       # noqa: BLE001
            print(f"[FAIL] {vname}: {type(e).__name__}: {str(e)[:300]}",
                  flush=True)
            continue
        rec["variant"] = vname
        # re-lower for the breakdown (run_cell doesn't retain the HLO)
        path = os.path.join(
            args.out, f"{arch_name}__{shape}__{vname}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        r = rec["roofline"]
        c = rec["collectives"]
        rows.append((vname, r, c, rec))
        print(f"[ok] {vname:18s} compile={rec['compile_s']:6.1f}s "
              f"flops/dev={r['hlo_flops_per_device']:.3e} "
              f"c={r['compute_s']*1e3:9.2f}ms "
              f"m={r['memory_s']*1e3:9.2f}ms "
              f"n={r['collective_s']*1e3:9.2f}ms "
              f"coll(AG/AR/A2A)GB="
              f"{c['all-gather']/1e9:.1f}/{c['all-reduce']/1e9:.1f}/"
              f"{c['all-to-all']/1e9:.1f} "
              f"mem/dev={rec['memory']['model']['total_bytes']/1e9:.2f}GB",
              flush=True)
    if len(rows) > 1:
        base = rows[0][1]
        print("\ndeltas vs", rows[0][0])
        for vname, r, c, _ in rows[1:]:
            for term in ("compute_s", "memory_s", "collective_s"):
                if base[term] > 0:
                    d = (r[term] - base[term]) / base[term] * 100
                    print(f"  {vname:18s} {term:13s} {d:+7.1f}%")


if __name__ == "__main__":
    main()
