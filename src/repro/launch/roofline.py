"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_operand_bytes_per_device / ICI_BW

``cost_analysis()`` supplies per-device FLOPs and bytes; collective bytes
are parsed from the post-SPMD HLO text (sum of operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
MODEL_FLOPS (6*N*D train / 2*N*D inference, N = active params) gives the
useful-compute ratio that catches remat/dispatch waste.
"""

from __future__ import annotations

import re

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction definition: "  %name = <result types> <opcode>(...)" — the
# result types may be a tuple "(f32[..], s32[..])"
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([^=]*?)\s+"
                     r"([\w\-]+)\(")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _types_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum *operand* bytes per collective kind from (post-SPMD) HLO text.

    Modern HLO printing references operands by name without inline types,
    so a first pass builds a name -> result-type symbol table; collective
    operand names resolve against it (fallback: the collective's own
    result type — exact for all-reduce, upper bound for all-gather).
    """
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_types, kind, operand_str = m.groups()
        total = 0
        for op in operand_str.split(","):
            op = op.strip().lstrip("%")
            if _SHAPE_RE.search(op):          # inline-typed operand
                total += _types_bytes(op)
            elif op in shapes:
                total += _types_bytes(shapes[op])
        if total == 0:                        # fallback: result type
            total = _types_bytes(result_types)
        out[kind] += total
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def collective_breakdown(hlo_text: str, top: int = 8):
    """(kind, operand-shape, count, total-bytes) for the largest collective
    op groups — the §Perf diagnosis view."""
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    groups: dict[tuple, list] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_types, kind, operand_str = m.groups()
        ops = []
        total = 0
        for op in operand_str.split(","):
            op = op.strip().lstrip("%")
            t = op if _SHAPE_RE.search(op) else shapes.get(op, "")
            ops.append(t.strip())
            total += _types_bytes(t)
        if total == 0:
            total = _types_bytes(result_types)
            ops = [result_types.strip()]
        key = (kind, ops[0])
        rec = groups.setdefault(key, [0, 0])
        rec[0] += 1
        rec[1] += total
    out = sorted(((k[0], k[1], c, b) for (k, (c, b)) in groups.items()),
                 key=lambda t: -t[3])
    return out[:top]


def normalize_cost(cost) -> dict:
    """Version-tolerant ``compiled.cost_analysis()`` result -> flat dict.

    Newer JAX returns the properties dict directly; older releases return
    a one-element list of per-computation dicts (summed here)."""
    if isinstance(cost, (list, tuple)):
        merged: dict = {}
        for c in cost:
            for k, v in (c or {}).items():
                merged[k] = merged.get(k, 0.0) + v
        return merged
    return dict(cost or {})


def roofline_terms(cost: dict, coll_bytes: int) -> dict[str, float]:
    cost = normalize_cost(cost)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, collective_s)
    terms.update({
        "dominant": dom,
        "step_lower_bound_s": bound,
        "roofline_fraction": compute_s / bound if bound > 0 else 0.0,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": float(coll_bytes),
    })
    return terms


# -- MODEL_FLOPS (useful compute) ------------------------------------------

def lm_model_flops(arch, shape_name: str) -> float:
    """6*N_active*D for train, 2*N_active*D for inference (global)."""
    cfg = arch.cfg
    spec = arch.shapes[shape_name]
    n_active = cfg.active_param_count()
    b, s = spec["global_batch"], spec["seq_len"]
    if spec["kind"] == "train":
        tokens = 2 * b * s                     # query + passage towers
        return 6.0 * n_active * tokens
    if spec["kind"] == "encode":
        return 2.0 * n_active * b * s
    # decode: 1 token/seq; attention reads dominate but count param-flops
    kv_flops = (2.0 * b * s * cfg.n_layers
                * cfg.n_kv_heads * cfg.head_dim * 2)
    return 2.0 * n_active * b + kv_flops


def gnn_model_flops(arch, shape_name: str) -> float:
    spec = arch.shapes[shape_name]
    cfg = arch.shape_cfg(shape_name)
    d0, dh = cfg.d_feat, cfg.d_hidden
    per_node = 2 * (d0 * dh * 2 + dh * dh * 2)       # 2 layers, self+neigh
    if spec["mode"] == "full":
        n = spec["n_nodes"]
        e = spec["n_edges"]
        msgs = 2 * e * (d0 + dh)                      # gather+reduce adds
        return 3.0 * (n * per_node + msgs)            # fwd+bwd
    if spec["mode"] == "minibatch":
        b = spec["batch_nodes"]
        f1, f2 = spec["fanouts"]
        nodes = 2 * b * (1 + f1 + f1 * f2)            # anchor+positive trees
        return 3.0 * nodes * per_node
    g, n = spec["n_graphs"], spec["n_nodes"]
    return 3.0 * 2 * g * n * per_node


def recsys_model_flops(arch, shape_name: str) -> float:
    spec = arch.shapes[shape_name]
    cfg = arch.cfg
    d = cfg.embed_dim
    f = cfg.n_fields
    mlp_in = {"deepfm": f * d, "wide_deep": f * d,
              "autoint": f * cfg.n_heads * cfg.d_attn,
              "bst": (cfg.seq_len + 1 + cfg.n_profile_fields) * d}[cfg.kind]
    dims = (mlp_in,) + tuple(cfg.mlp_dims) + (1,)
    mlp = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    inter = 0
    if cfg.kind == "autoint":
        d_in = d
        for _ in range(cfg.n_attn_layers):
            dh = cfg.n_heads * cfg.d_attn
            inter += 2 * f * d_in * dh * 4 + 2 * f * f * dh * 2
            d_in = dh
        mlp = 2 * f * d_in * 1
    if cfg.kind == "bst":
        s = cfg.seq_len + 1
        inter = 2 * s * d * d * 4 + 2 * s * s * d * 2 + \
            2 * s * d * cfg.bst_d_ff * 2
    if cfg.kind == "deepfm":
        inter = 2 * f * d * 2
    per_ex = mlp + inter + f * d                      # + embedding reads
    b = (spec["n_candidates"] if spec["kind"] == "retrieval"
         else spec["batch"])
    mult = 3.0 if spec["kind"] == "train" else 1.0
    return mult * per_ex * b


def model_flops(arch, shape_name: str) -> float:
    return {"lm": lm_model_flops, "gnn": gnn_model_flops,
            "recsys": recsys_model_flops}[arch.family](arch, shape_name)


# -- analytic HBM-traffic model ------------------------------------------------
# XLA:CPU cost_analysis "bytes accessed" is fusion-blind (every elementwise
# op counts operand+result traffic), overstating TPU HBM bytes by ~10-30x.
# These closed forms estimate per-device HBM traffic under TPU fusion:
# weights stream once per pass, activations r/w at layer boundaries, the
# attention score matrix r/w unless a flash kernel is used.

def _mesh_dp_tp(mesh_shape: dict) -> tuple[int, int]:
    dp = int(np.prod([mesh_shape.get(a, 1) for a in ("pod", "data")]))
    return dp, mesh_shape.get("model", 1)


def lm_analytic_bytes(arch, shape_name: str, mesh_shape: dict,
                      flash_attn: bool = False) -> float:
    cfg = arch.cfg
    spec = arch.shapes[shape_name]
    dp, tp = _mesh_dp_tp(mesh_shape)
    b, s = spec["global_batch"], spec["seq_len"]
    b_loc = max(1, b // dp)
    bpe = 2
    p_total = cfg.param_count()
    p_shard = p_total / (dp * tp)          # FSDP x TP resident shard

    if spec["kind"] == "serve":
        # decode: read the full resident param shard + the cache shard once
        cache = (cfg.n_layers * b * s * cfg.n_kv_heads * cfg.head_dim
                 * 2 * bpe) / (dp * tp if b == 1 or
                               cfg.n_kv_heads % tp else dp * tp)
        if cfg.moe:
            # only active experts' weights are gathered per token
            active = cfg.active_param_count()
            p_read = (active / tp) * bpe * max(1, b_loc)
        else:
            p_read = p_total / tp * bpe    # weights stream once (all-gathered)
        return p_read + cache

    passes = 3.0 if spec["kind"] == "train" else 1.0
    # weights stream through each device once per pass (FSDP all-gather)
    w_traffic = passes * (p_total / tp) * bpe
    if spec["kind"] == "train":
        w_traffic += p_shard * (4 + 4) * 2      # grads + opt r/w fp32
    # activation boundaries: ~6 r/w of (B,S,d) per layer per pass
    act = passes * cfg.n_layers * 6 * b_loc * s * cfg.d_model * bpe / (
        tp if cfg.seq_shard_acts else 1)
    # attention scores: r/w of (B,*,Sq,Skv) fp32 per layer unless flash
    scores = 0.0
    if not flash_attn and s > 1:
        if cfg.seq_shard_attn:
            rows = s // tp
            heads = cfg.n_kv_heads * (cfg.n_heads // cfg.n_kv_heads)
        else:
            hs = tp if cfg.n_kv_heads % tp == 0 else 1
            rows = s
            heads = (cfg.n_kv_heads // hs) * (cfg.n_heads // cfg.n_kv_heads)
        scores = passes * cfg.n_layers * 4 * b_loc * heads * rows * s * 4
    # MoE expert weights: all local experts stream per pass
    moe = 0.0
    if cfg.moe:
        e_shard = tp if cfg.n_experts % tp == 0 else 1
        f_shard = 1 if cfg.n_experts % tp == 0 else (
            tp if cfg.moe_d_ff % tp == 0 else 1)
        moe = passes * cfg.n_moe_layers * (
            cfg.n_experts // e_shard) * 3 * cfg.d_model * (
            cfg.moe_d_ff // f_shard) * bpe / dp   # FSDP share of experts
    return w_traffic + act + scores + moe


def gnn_analytic_bytes(arch, shape_name: str, mesh_shape: dict) -> float:
    spec = arch.shapes[shape_name]
    cfg = arch.shape_cfg(shape_name)
    dp, _ = _mesh_dp_tp(mesh_shape)
    if spec["mode"] == "full":
        n, e = spec["n_nodes"], spec["n_edges"]
        per = (n * (cfg.d_feat + 4 * cfg.d_hidden)
               + 2 * e * (cfg.d_feat + cfg.d_hidden)) * 4
        return 3.0 * per / dp
    if spec["mode"] == "minibatch":
        b = spec["batch_nodes"]
        f1, f2 = spec["fanouts"]
        nodes = 2 * b * (1 + f1 + f1 * f2)
        return 3.0 * 4 * nodes * max(cfg.d_feat, cfg.d_hidden) * 4 / dp
    g, n = spec["n_graphs"], spec["n_nodes"]
    return 3.0 * 4 * 2 * g * n * max(cfg.d_feat, cfg.d_hidden) * 4 / dp


def recsys_analytic_bytes(arch, shape_name: str, mesh_shape: dict) -> float:
    spec = arch.shapes[shape_name]
    cfg = arch.cfg
    dp, tp = _mesh_dp_tp(mesh_shape)
    b = (spec["n_candidates"] if spec["kind"] == "retrieval"
         else spec["batch"])
    b_loc = max(1, b // dp)
    rows = b_loc * cfg.n_fields * cfg.embed_dim * 4       # gathered rows
    mlp_params = sum(a * bb for a, bb in zip(
        ((cfg.n_fields * cfg.embed_dim,) + tuple(cfg.mlp_dims)),
        (tuple(cfg.mlp_dims) + (1,)))) * 4
    act = b_loc * (cfg.n_fields * cfg.embed_dim
                   + sum(cfg.mlp_dims) + 1) * 4 * 2
    passes = 3.0 if spec["kind"] == "train" else 1.0
    table_grad = 0.0
    if spec["kind"] == "train":
        # dense scatter-add gradient + adamw update over the table shard
        table_grad = (cfg.total_vocab // tp) * cfg.embed_dim * 4 * 4
    return passes * (rows + mlp_params + act) + table_grad


def analytic_bytes(arch, shape_name: str, mesh_shape: dict,
                   flash_attn: bool = False) -> float:
    if arch.family == "lm":
        return lm_analytic_bytes(arch, shape_name, mesh_shape, flash_attn)
    if arch.family == "gnn":
        return gnn_analytic_bytes(arch, shape_name, mesh_shape)
    return recsys_analytic_bytes(arch, shape_name, mesh_shape)
