"""Multi-dataset retrieval eval suite (paper §3.2 + §3.5 combined).

Evaluates one retriever over N datasets — per-dataset metrics AND a
combined pass where every query set is scored against the lazily
concatenated union of all corpora (``ConcatView``): the union is never
built on disk or in RAM.  Writes nDCG/MRR/recall tables (JSON +
markdown) into ``--out-dir``.

  # two synthetic datasets, tiny encoder, tables under results/
  python -m repro.launch.evalsuite --smoke --out-dir results

  # your own BEIR-style dataset dirs (queries.jsonl, corpus.jsonl,
  # qrels/train.tsv each), 4 simulated workers
  python -m repro.launch.evalsuite --data-dirs /d/fiqa,/d/scifact \
      --workers 4 --out-dir results

Multi-node story (zero code changes): each scenario runs through
``RetrievalEvaluator`` -> ``ShardedSearchDriver``, so ``--workers N``
simulates N nodes in-process and a real ``jax.distributed`` launch
shards every pass (including the combined one) across processes.
"""

from __future__ import annotations

import argparse
import os
import time


def build_scenarios(data_dirs, cache_root: str) -> dict[str, dict]:
    """BEIR-style dataset dirs -> named (queries, corpus, qrels) views.

    Each dataset loads through :class:`MaterializedQRel` (mmap tables,
    grouped qrels), so queries/corpus are lazy ``TableView``s and qrels
    come hash-keyed from the grouped arrays — no full-dataset dicts.
    """
    from repro.core.config import MaterializedQRelConfig
    from repro.core.materialized_qrel import MaterializedQRel

    scenarios: dict[str, dict] = {}
    for d in data_dirs:
        name = os.path.basename(os.path.normpath(d))
        m = MaterializedQRel(MaterializedQRelConfig(
            qrel_path=os.path.join(d, "qrels", "train.tsv"),
            query_path=os.path.join(d, "queries.jsonl"),
            corpus_path=os.path.join(d, "corpus.jsonl")), cache_root)
        scenarios[name] = {"queries": m.queries_view(),
                           "corpus": m.corpus_view(),
                           "qrels": m.qrels_dict()}
    return scenarios


def make_synthetic_suite(root: str, n_datasets: int = 2,
                         n_queries: int = 16, n_docs: int = 96,
                         n_topics: int = 8) -> list[str]:
    """N synthetic datasets with disjoint id spaces (``d{i}-`` prefixes)."""
    from repro.data.synthetic import make_retrieval_dataset

    dirs = []
    for i in range(n_datasets):
        d = os.path.join(root, f"d{i}")
        if not os.path.exists(os.path.join(d, "queries.jsonl")):
            make_retrieval_dataset(
                d, n_queries=n_queries, n_docs=n_docs, n_topics=n_topics,
                seed=100 + i, id_prefix=f"d{i}-")
        dirs.append(d)
    return dirs


def main(argv=None):
    import jax

    from repro.configs import get_arch
    from repro.core.collator import RetrievalCollator
    from repro.core.config import DataArguments, EvaluationArguments
    from repro.core.embedding_cache import EmbeddingCache
    from repro.core.evaluator import RetrievalEvaluator, format_metrics_table
    from repro.data.tokenizer import HashTokenizer
    from repro.models.encoder import DefaultEncoder
    from repro.models.retriever import BiEncoderRetriever

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="trove-base")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced arch + synthetic datasets (fast CI path)")
    ap.add_argument("--data-dirs", default=None,
                    help="comma-separated BEIR-style dataset dirs; default: "
                         "generate --datasets synthetic ones under "
                         "--data-root")
    ap.add_argument("--data-root", default="/tmp/trove_evalsuite")
    ap.add_argument("--datasets", type=int, default=2)
    ap.add_argument("--n-queries", type=int, default=16)
    ap.add_argument("--n-docs", type=int, default=96)
    ap.add_argument("--out-dir", default="results")
    ap.add_argument("--suite-name", default="evalsuite")
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--workers", type=int, default=1,
                    help="N>1 = simulate N sharded workers in-process")
    ap.add_argument("--score-impl", default="jax",
                    choices=("numpy", "jax", "pallas_fused"))
    ap.add_argument("--no-cache", action="store_true",
                    help="skip the shared embedding cache (online regime)")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.smoke:
        arch = arch.reduced().variant(dtype=jax.numpy.float32)
    if args.data_dirs:
        data_dirs = args.data_dirs.split(",")
    else:
        data_dirs = make_synthetic_suite(
            args.data_root, args.datasets, n_queries=args.n_queries,
            n_docs=args.n_docs)
    scenarios = build_scenarios(
        data_dirs, os.path.join(args.data_root, "cache"))

    tok = HashTokenizer(arch.cfg.vocab_size)
    retriever = BiEncoderRetriever(DefaultEncoder(arch.cfg), "infonce")
    collator = RetrievalCollator(
        DataArguments(vocab_size=arch.cfg.vocab_size), tok)
    params = retriever.init_params(jax.random.key(0))
    eval_args = EvaluationArguments(topk=args.topk,
                                    score_impl=args.score_impl)
    cache = (None if args.no_cache else EmbeddingCache(
        os.path.join(args.data_root, "emb_cache"), dim=arch.cfg.d_model))

    t0 = time.monotonic()
    if args.workers > 1:
        from repro.launch.distributed import SimulatedCluster
        cluster = SimulatedCluster(args.workers)
        evs = [RetrievalEvaluator(eval_args, retriever, collator, params,
                                  process_index=rank,
                                  process_count=args.workers,
                                  gather=cluster.gather,
                                  sharder=cluster.sharder)
               for rank in range(args.workers)]
        results = cluster.run(lambda rank: evs[rank].evaluate_suite(
            scenarios, cache=cache, out_dir=args.out_dir,
            suite_name=args.suite_name))[0]
        label = f"{args.workers} simulated workers"
    else:
        ev = RetrievalEvaluator(eval_args, retriever, collator, params)
        results = ev.evaluate_suite(scenarios, cache=cache,
                                    out_dir=args.out_dir,
                                    suite_name=args.suite_name)
        label = f"{ev.process_count} process(es)"
    dt = time.monotonic() - t0

    print(format_metrics_table(results), end="")
    sizes = ", ".join(f"{n}: {len(sc['qrels'])}q/"
                      f"{len(sc['corpus'])}d"
                      for n, sc in scenarios.items())
    print(f"evalsuite: {len(scenarios)} datasets ({sizes}) on {label} "
          f"in {dt:.1f}s -> "
          f"{os.path.join(args.out_dir, args.suite_name)}.{{json,md}}")
    return results


if __name__ == "__main__":
    main()
