"""Roofline report generator: dry-run JSONs -> EXPERIMENTS.md tables.

Post-processes the per-cell records (no recompilation): recomputes the
memory term with the fusion-aware analytic traffic model (roofline.py)
alongside the raw XLA number, identifies the dominant term, and emits the
§Dry-run + §Roofline markdown tables.

  PYTHONPATH=src python -m repro.launch.report --dir results/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_arch
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   analytic_bytes)


def load_records(dir_: str, tag: str = "singlepod") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, f"{tag}__*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def enrich(rec: dict, flash_attn: bool = False) -> dict:
    arch = get_arch(rec["arch"])
    mem_model_bytes = analytic_bytes(arch, rec["shape"], rec["mesh"],
                                     flash_attn)
    r = rec["roofline"]
    compute_s = r["compute_s"]
    mem_s = mem_model_bytes / HBM_BW
    coll_s = r["collective_s"]
    bound = max(compute_s, mem_s, coll_s)
    dom = {compute_s: "compute", mem_s: "memory",
           coll_s: "collective"}[bound]
    rec["roofline_model"] = {
        "compute_s": compute_s,
        "memory_s_model": mem_s,
        "memory_s_xla": r["memory_s"],
        "collective_s": coll_s,
        "analytic_bytes_per_device": mem_model_bytes,
        "dominant": dom,
        "step_lower_bound_s": bound,
        "roofline_fraction": compute_s / bound if bound else 0.0,
    }
    return rec


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | kind | mem/dev GB | compute ms | memory ms "
           "(model / xla) | collective ms | dominant | roofline frac | "
           "useful ratio |\n|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for rec in recs:
        rm = rec["roofline_model"]
        mm = rec["memory"]["model"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} "
            f"| {mm['total_bytes'] / 1e9:.2f}"
            f"{'' if mm['fits_16GB'] else ' (!)'} "
            f"| {rm['compute_s'] * 1e3:.2f} "
            f"| {rm['memory_s_model'] * 1e3:.2f} / "
            f"{rm['memory_s_xla'] * 1e3:.0f} "
            f"| {rm['collective_s'] * 1e3:.2f} "
            f"| {rm['dominant']} "
            f"| {rm['roofline_fraction'] * 100:.1f}% "
            f"| {rec.get('useful_compute_ratio') and rec['useful_compute_ratio']:.2f} |")
    return hdr + "\n".join(rows)


def dryrun_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | compile s | params+state GB/dev | act GB/dev "
           "| HLO GFLOPs/dev | coll GB/dev (AG/AR/RS/A2A/CP) | #coll |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for rec in recs:
        mm = rec["memory"]["model"]
        c = rec["collectives"]
        per = "/".join(
            f"{c.get(k, 0) / 1e9:.2f}" for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute"))
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['compile_s']:.0f} "
            f"| {(mm['state_and_args_bytes'] + mm['grad_transient_bytes']) / 1e9:.2f} "
            f"| {mm['activation_bytes'] / 1e9:.2f} "
            f"| {rec['roofline']['hlo_flops_per_device'] / 1e9:.0f} "
            f"| {per} | {c['count']} |")
    return hdr + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="singlepod")
    ap.add_argument("--flash-attn", action="store_true")
    args = ap.parse_args()
    recs = [enrich(r, args.flash_attn)
            for r in load_records(args.dir, args.tag)]
    print("## Roofline table\n")
    print(table(recs))
    print("\n## Dry-run detail\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
