"""Multi-node launch utilities for the sharded search driver.

Two ways to get a W-worker cluster:

  * **real nodes** — ``init_distributed()`` wraps
    ``jax.distributed.initialize`` (env-driven: coordinator address,
    process count/id) and returns this process's ``(rank, world_size)``;
    the evaluator then uses ``ProcessAllGather`` automatically.  Zero
    code changes versus single node: the same script, launched once per
    node.
  * **simulated** — :class:`SimulatedCluster` runs W *real*
    ``ShardedSearchDriver`` / ``RetrievalEvaluator`` instances inside one
    process (worker threads), wired to a shared ``FairSharder`` and a
    deterministic :class:`InMemoryAllGather`.  Used by the equivalence
    tests, ``benchmarks/bench_multinode.py``, and
    ``launch/serve.py --workers N``.

Determinism: ``InMemoryAllGather.merge`` always folds rank states in
rank order (exactly like ``ProcessAllGather``), so the merged ranking is
independent of thread scheduling and every worker returns an identical
result.
"""

from __future__ import annotations

import threading
from typing import Callable, Sequence

from repro.core.fair_sharding import FairSharder, ShardAborted
from repro.core.result_heap import FastResultHeapq


def init_distributed(coordinator_address: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> tuple[int, int]:
    """Initialize ``jax.distributed`` when a multi-process launch is
    requested; return ``(process_index, process_count)``.

    With all arguments ``None`` this is env-driven
    (``JAX_COORDINATOR_ADDRESS`` etc. / cloud auto-detection) and a
    no-op single-process fallback otherwise, so the same script runs
    unchanged on one node or many.
    """
    import jax

    if num_processes is not None and num_processes > 1:
        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes, process_id=process_id)
    return jax.process_index(), jax.process_count()


class InMemoryAllGather:
    """Deterministic in-process stand-in for ``ProcessAllGather``.

    W worker threads each contribute their local (Q, k) state; a barrier
    guarantees all states are present; every worker then merges them
    **in rank order** and returns an identical merged heap.  A second
    barrier prevents a fast worker from starting the next round while a
    slow one is still reading this round's states.
    """

    def __init__(self, world_size: int):
        self.world_size = world_size
        self._states: dict[int, tuple] = {}
        self._barrier = threading.Barrier(world_size)

    def abort(self) -> None:
        """Break the barrier so sibling workers fail fast instead of
        deadlocking when one worker dies mid-round."""
        self._barrier.abort()

    def merge(self, heap: FastResultHeapq,
              worker_index: int) -> FastResultHeapq:
        vals, ids = heap.finalize()
        self._states[worker_index] = (vals, ids)
        self._barrier.wait()                 # all W states are visible
        merged = FastResultHeapq(vals.shape[0], heap.k, impl=heap.impl)
        for rank in range(self.world_size):
            merged.merge_arrays(*self._states[rank])
        self._barrier.wait()                 # all read before round reuse
        return merged


class SimulatedCluster:
    """W real driver/evaluator instances in one process.

    Construct once, hand ``gather`` and ``sharder`` to W drivers (or
    evaluators with ``process_index=rank, process_count=W``), then
    ``run(worker_fn)`` executes ``worker_fn(rank)`` on W threads and
    returns all ranks' results.  Because :class:`InMemoryAllGather`
    merges in rank order, all results are identical.

    ``resilient=True`` swaps the barrier gather for a
    :class:`~repro.core.faults.ResilientAllGather` wired to a shared
    :class:`~repro.core.faults.WorkerHealth` board: a worker raising
    (e.g. an injected crash) no longer aborts its siblings — the
    cluster marks it dead (health board + sharder + gather wake-up),
    survivors recover its shard inside the round, and subsequent
    ``run`` calls skip the dead rank entirely.  Each live worker runs
    under a heartbeat (the training stack's
    ``fault_tolerance.Heartbeat``) feeding the health board, so
    staleness-based failure detection sees real liveness signals.
    ``run`` then returns the **first live rank's** result (all live
    ranks are identical) in every slot that died, so callers indexing
    ``outs[rank]`` keep working.
    """

    def __init__(self, world_size: int, resilient: bool = False,
                 stale_after_s: float | None = None):
        self.world_size = world_size
        self.resilient = resilient
        self.sharder = FairSharder(world_size)
        if resilient:
            from repro.core.faults import ResilientAllGather, WorkerHealth
            self.health = WorkerHealth(world_size,
                                       stale_after_s=stale_after_s)
            self.gather = ResilientAllGather(world_size,
                                             health=self.health,
                                             sharder=self.sharder)
        else:
            self.health = None
            self.gather = InMemoryAllGather(world_size)

    def run(self, worker_fn: Callable[[int], object]) -> list:
        results: list = [None] * self.world_size
        errors: list = [None] * self.world_size
        dead_before = (set() if self.health is None else self.health.dead)

        def target(rank: int) -> None:
            try:
                if self.health is not None:
                    with self.health.heartbeat(rank):
                        results[rank] = worker_fn(rank)
                else:
                    results[rank] = worker_fn(rank)
            except BaseException as exc:     # noqa: BLE001 — re-raised below
                errors[rank] = exc
                if self.resilient:
                    # degrade, don't collapse: mark the rank dead so the
                    # sharder stops waiting for its reports and the
                    # gather reassigns its in-flight shard to survivors
                    self.sharder.mark_dead(rank)
                    self.gather.notify_death(rank)
                else:
                    self.gather.abort()
                    # siblings may equally be blocked waiting for this
                    # rank's round report (pipelined acquire_bounds)
                    self.sharder.abort(exc)

        threads = [threading.Thread(target=target, args=(rank,),
                                    name=f"sim-worker-{rank}")
                   for rank in range(self.world_size)
                   if rank not in dead_before]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if self.resilient:
            live = [rank for rank in range(self.world_size)
                    if rank not in dead_before and errors[rank] is None]
            if not live:
                for exc in errors:
                    if exc is not None:
                        raise exc
                raise ShardAborted(
                    f"no live worker left of {self.world_size}")
            for rank in range(self.world_size):
                if rank in dead_before or errors[rank] is not None:
                    results[rank] = results[live[0]]
            return results
        for exc in errors:
            if exc is not None and not isinstance(
                    exc, (threading.BrokenBarrierError, ShardAborted)):
                raise exc
        for exc in errors:                   # only barrier casualties left
            if exc is not None:
                raise exc
        return results


def simulated_search(world_size: int, make_evaluator,
                     queries: dict, corpus: dict, **search_kw) -> tuple:
    """One-call helper: build W evaluators via ``make_evaluator(rank,
    world, gather, sharder)``, run a full sharded search, and return
    rank 0's ``(q_hashes, ids, scores)`` (all ranks are identical)."""
    cluster = SimulatedCluster(world_size)
    evaluators = [make_evaluator(rank, world_size, cluster.gather,
                                 cluster.sharder)
                  for rank in range(world_size)]
    outs = cluster.run(
        lambda rank: evaluators[rank].search(queries, corpus, **search_kw))
    return outs[0]
