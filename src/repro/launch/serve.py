"""Retrieval serving driver: encode a corpus once (mmap embedding cache),
then answer batched query requests with FastResultHeapq top-k.

  python -m repro.launch.serve --data-dir /tmp/trove_data --topk 10

Multi-node story (zero code changes, paper §3.5): the same script serves
from W workers through ``ShardedSearchDriver``.  ``--workers N`` runs N
real driver instances in this process (``SimulatedCluster``); on a real
cluster, launch the script once per node under ``jax.distributed`` (see
``repro.launch.distributed.init_distributed``) and each process takes a
fair-sharded corpus slice automatically.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None):
    import jax
    import numpy as np

    from repro.core.collator import RetrievalCollator
    from repro.core.config import DataArguments, EvaluationArguments
    from repro.core.embedding_cache import EmbeddingCache
    from repro.core.evaluator import RetrievalEvaluator
    from repro.configs import get_arch
    from repro.data.synthetic import make_retrieval_dataset
    from repro.data.tokenizer import HashTokenizer
    from repro.models.encoder import DefaultEncoder
    from repro.models.retriever import BiEncoderRetriever
    from repro.training.checkpoint import (latest_checkpoint,
                                           restore_checkpoint)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="trove-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--data-dir", default="/tmp/trove_data")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = use jax process count (multi-node under "
                         "jax.distributed); 1 = force single-worker; "
                         "N>1 = simulate N workers in-process via "
                         "ShardedSearchDriver")
    ap.add_argument("--score-impl", default="jax",
                    choices=("numpy", "jax", "pallas_fused"))
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.smoke:
        arch = arch.reduced().variant(dtype=jax.numpy.float32)
    if not os.path.exists(os.path.join(args.data_dir, "queries.jsonl")):
        make_retrieval_dataset(args.data_dir, n_queries=64, n_docs=512,
                               n_topics=32)
    queries, corpus = {}, {}
    for line in open(os.path.join(args.data_dir, "queries.jsonl")):
        rec = json.loads(line)
        queries[rec["_id"]] = rec["text"]
    for line in open(os.path.join(args.data_dir, "corpus.jsonl")):
        rec = json.loads(line)
        corpus[rec["_id"]] = rec["text"]

    tok = HashTokenizer(arch.cfg.vocab_size)
    retriever = BiEncoderRetriever(DefaultEncoder(arch.cfg), "infonce")
    collator = RetrievalCollator(
        DataArguments(vocab_size=arch.cfg.vocab_size), tok)

    params = retriever.init_params(jax.random.key(0))
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            state = restore_checkpoint(
                path, {"step": np.zeros((), np.int32), "params": params,
                       "opt": {}, "rng": np.zeros(2, np.uint32)})
            params = state["params"]
            print(f"restored {path}")

    eval_args = EvaluationArguments(topk=args.topk,
                                    score_impl=args.score_impl)
    cache = EmbeddingCache(os.path.join(args.data_dir, "emb_cache"),
                           dim=arch.cfg.d_model)
    if args.workers > 1:
        # W real driver instances in this process, deterministic
        # in-memory all-gather — the same code path as W real nodes
        from repro.launch.distributed import SimulatedCluster
        cluster = SimulatedCluster(args.workers)
        evs = [RetrievalEvaluator(eval_args, retriever, collator, params,
                                  process_index=rank,
                                  process_count=args.workers,
                                  gather=cluster.gather,
                                  sharder=cluster.sharder)
               for rank in range(args.workers)]

        def answer(req):
            return cluster.run(
                lambda rank: evs[rank].search(req, corpus, cache=cache))[0]
        label = f"{args.workers} simulated workers"
    elif args.workers == 1:
        # forced single-worker baseline, even under jax.distributed
        ev = RetrievalEvaluator(eval_args, retriever, collator, params,
                                process_index=0, process_count=1)

        def answer(req):
            return ev.search(req, corpus, cache=cache)
        label = "1 worker (forced)"
    else:
        # jax process count: 1 standalone, or W under jax.distributed —
        # the evaluator picks the ProcessAllGather transport itself
        ev = RetrievalEvaluator(eval_args, retriever, collator, params)

        def answer(req):
            return ev.search(req, corpus, cache=cache)
        label = f"{ev.process_count} process(es)"

    # warm the corpus cache (the expensive pass, done once)
    t0 = time.monotonic()
    q_ids = list(queries)
    for i in range(args.n_requests):
        lo = (i * args.batch) % len(q_ids)
        req = {q: queries[q] for q in q_ids[lo: lo + args.batch]}
        qh, ids, scores = answer(req)
        dt = time.monotonic() - t0
        t0 = time.monotonic()
        print(f"request {i}: {len(req)} queries -> top-{args.topk} "
              f"in {dt*1e3:.1f} ms on {label} "
              f"(cache {len(cache)}/{len(corpus)} docs)")
    print("serving done")


if __name__ == "__main__":
    main()
