"""Retrieval serving driver: prepare a device-resident corpus once, then
answer concurrent query requests through the continuous-batching
:class:`~repro.core.serving.ServeFrontend` (micro-batch coalescing,
admission control, per-request demux).

  python -m repro.launch.serve --data-dir /tmp/trove_data --topk 10

Multi-node story (zero code changes, paper §3.5): the same script serves
from W workers through ``ShardedSearchDriver``.  ``--workers N`` runs N
real driver instances in this process (``SimulatedCluster``); on a real
cluster, launch the script once per node under ``jax.distributed`` (see
``repro.launch.distributed.init_distributed``) and each process takes a
fair-sharded corpus slice automatically.

Measurement discipline (this used to be wrong): corpus encode and XLA
compiles happen in an explicit, separately-reported warm pass *before*
the request loop, so the printed per-request latencies are steady-state.
Requests wrap around the query set so every request carries exactly
``--batch`` queries, and ``--concurrency C`` submits from C threads so
the frontend actually coalesces.  ``main`` returns the stats dict
(per-request latencies, p50/p99, QPS, frontend counters) for tests and
benchmarks.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time


def main(argv=None):
    import jax
    import numpy as np

    from repro.core.collator import RetrievalCollator
    from repro.core.config import DataArguments, EvaluationArguments
    from repro.core.embedding_cache import EmbeddingCache
    from repro.core.evaluator import RetrievalEvaluator
    from repro.core.serving import ServeFrontend, ServeOverloadError
    from repro.configs import get_arch
    from repro.data.synthetic import make_retrieval_dataset
    from repro.data.tokenizer import HashTokenizer
    from repro.models.encoder import DefaultEncoder
    from repro.models.retriever import BiEncoderRetriever
    from repro.training.checkpoint import (latest_checkpoint,
                                           restore_checkpoint)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="trove-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--data-dir", default="/tmp/trove_data")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8,
                    help="queries per request (requests wrap around the "
                         "query set so every request has exactly this many)")
    ap.add_argument("--concurrency", type=int, default=1,
                    help="concurrent submitter threads (frontend "
                         "coalesces their requests into micro-batches)")
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = use jax process count (multi-node under "
                         "jax.distributed); 1 = force single-worker; "
                         "N>1 = simulate N workers in-process via "
                         "ShardedSearchDriver")
    ap.add_argument("--score-impl", default="jax",
                    choices=("numpy", "jax", "pallas_fused"))
    ap.add_argument("--index-impl", default="flat",
                    choices=("flat", "ivf"),
                    help="flat = exhaustive scan (recall oracle); ivf = "
                         "cluster-pruned sublinear search (repro.index)")
    ap.add_argument("--nclusters", type=int, default=64,
                    help="IVF coarse-quantizer cluster count")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="clusters scanned per query batch (nprobe == "
                         "nclusters replays the flat ranking)")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="micro-batch flush size (coalesced queries)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="micro-batch flush deadline after first request")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission-control bound on pending requests")
    ap.add_argument("--resilient", action="store_true",
                    help="fault-tolerant cluster (workers > 1): a dead "
                         "or silent worker's shard is reassigned to "
                         "survivors instead of aborting the round")
    ap.add_argument("--chaos", default=None,
                    choices=("crash", "stall", "drop"),
                    help="inject one fault of this kind into worker 1 "
                         "at the first steady-state round (requires "
                         "--resilient and --workers > 1)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency bound: queued past it -> "
                         "degraded empty result; dispatched -> bounds "
                         "shard-recovery time")
    ap.add_argument("--round-deadline-s", type=float, default=5.0,
                    help="how long a round waits for a silent worker "
                         "before reassigning its shard (resilient only)")
    ap.add_argument("--mutate", action="store_true",
                    help="live-corpus mode: serve the embedding cache's "
                         "generation-versioned live set while a writer "
                         "thread adds/updates/deletes documents and runs "
                         "one online compaction — each micro-batch pins "
                         "the newest committed generation; in-flight "
                         "requests finish on their pinned snapshot")
    args = ap.parse_args(argv)
    if args.chaos and not (args.resilient and args.workers > 1):
        ap.error("--chaos requires --resilient and --workers > 1")

    arch = get_arch(args.arch)
    if args.smoke:
        arch = arch.reduced().variant(dtype=jax.numpy.float32)
    if not os.path.exists(os.path.join(args.data_dir, "queries.jsonl")):
        make_retrieval_dataset(args.data_dir, n_queries=64, n_docs=512,
                               n_topics=32)
    queries, corpus = {}, {}
    for line in open(os.path.join(args.data_dir, "queries.jsonl")):
        rec = json.loads(line)
        queries[rec["_id"]] = rec["text"]
    for line in open(os.path.join(args.data_dir, "corpus.jsonl")):
        rec = json.loads(line)
        corpus[rec["_id"]] = rec["text"]

    tok = HashTokenizer(arch.cfg.vocab_size)
    retriever = BiEncoderRetriever(DefaultEncoder(arch.cfg), "infonce")
    collator = RetrievalCollator(
        DataArguments(vocab_size=arch.cfg.vocab_size), tok)

    params = retriever.init_params(jax.random.key(0))
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            state = restore_checkpoint(
                path, {"step": np.zeros((), np.int32), "params": params,
                       "opt": {}, "rng": np.zeros(2, np.uint32)})
            params = state["params"]
            print(f"restored {path}")

    eval_args = EvaluationArguments(topk=args.topk,
                                    score_impl=args.score_impl,
                                    index_impl=args.index_impl,
                                    ivf_nclusters=args.nclusters,
                                    ivf_nprobe=args.nprobe,
                                    serve_max_batch=args.max_batch,
                                    serve_max_wait_ms=args.max_wait_ms,
                                    serve_max_queue=args.max_queue,
                                    round_deadline_s=args.round_deadline_s)
    cache = EmbeddingCache(os.path.join(args.data_dir, "emb_cache"),
                           dim=arch.cfg.d_model)

    # one micro-batch = one sharded round; the warm pass below issues
    # exactly len(warm_widths) micro-batches, so the first steady-state
    # round number is known ahead of time — that's where chaos strikes
    n_warm_rounds = 0
    b = 1
    while b < args.max_batch:
        n_warm_rounds += 1
        b *= 2
    n_warm_rounds += 1
    injector = None
    if args.chaos:
        from repro.core.faults import Fault, FaultInjector
        injector = FaultInjector([Fault(
            kind=args.chaos, worker=1, round=n_warm_rounds,
            phase="gather" if args.chaos == "drop" else "load",
            stall_s=2 * args.round_deadline_s)])

    # -- frontend construction (the expensive pass: corpus encode/cache
    # warm-up + driver setup happen here, once) ------------------------------
    t_prep = time.monotonic()
    if args.workers > 1:
        # W real driver instances in this process, deterministic
        # in-memory all-gather — the same code path as W real nodes
        from repro.launch.distributed import SimulatedCluster
        cluster = SimulatedCluster(args.workers, resilient=args.resilient)
        evs = [RetrievalEvaluator(eval_args, retriever, collator, params,
                                  process_index=rank,
                                  process_count=args.workers,
                                  gather=cluster.gather,
                                  sharder=cluster.sharder,
                                  fault_injector=injector)
               for rank in range(args.workers)]
        frontend = ServeFrontend.from_cluster(
            evs, cluster, corpus, [cache] * args.workers,
            live=args.mutate)
        mut_ev = evs[0]
        label = (f"{args.workers} simulated workers"
                 + (" (resilient)" if args.resilient else ""))
    elif args.workers == 1:
        # forced single-worker baseline, even under jax.distributed
        ev = RetrievalEvaluator(eval_args, retriever, collator, params,
                                process_index=0, process_count=1)
        frontend = ServeFrontend.from_evaluator(ev, corpus, cache,
                                                live=args.mutate)
        mut_ev = ev
        label = "1 worker (forced)"
    else:
        # jax process count: 1 standalone, or W under jax.distributed —
        # the evaluator picks the ProcessAllGather transport itself
        ev = RetrievalEvaluator(eval_args, retriever, collator, params)
        frontend = ServeFrontend.from_evaluator(ev, corpus, cache,
                                                live=args.mutate)
        mut_ev = ev
        label = f"{ev.process_count} process(es)"
    prep_s = time.monotonic() - t_prep

    # requests wrap around the query set: every request carries exactly
    # --batch queries (the old `q_ids[lo: lo + batch]` silently truncated
    # the last slice)
    q_ids = list(queries)
    requests = []
    for i in range(args.n_requests):
        texts = [queries[q_ids[(i * args.batch + j) % len(q_ids)]]
                 for j in range(args.batch)]
        assert len(texts) == args.batch, (len(texts), args.batch)
        requests.append(texts)

    # -- explicit warm pass (NOT part of the timed loop): compile the
    # scoring/merge path and every power-of-two encode batch rung a
    # coalesced micro-batch can hit (a micro-batch of Q queries pads to
    # the next rung <= max_batch), so the request loop below measures
    # steady-state serving latency only -------------------------------------
    t_warm = time.monotonic()
    all_texts = [queries[q] for q in q_ids]
    warm_widths, b = [], 1
    while b < args.max_batch:
        warm_widths.append(b)
        b *= 2
    warm_widths.append(args.max_batch)
    for w in warm_widths:
        frontend.search([all_texts[j % len(all_texts)] for j in range(w)])
    warm_s = time.monotonic() - t_warm
    print(f"prepared corpus ({len(corpus)} docs, cache {len(cache)} rows) "
          f"in {prep_s:.2f}s; warm pass {warm_s * 1e3:.1f} ms on {label}")

    # -- steady-state request loop ------------------------------------------
    latencies = [0.0] * args.n_requests

    def submit_one(i: int) -> None:
        t0 = time.monotonic()
        while True:
            try:
                fut = frontend.submit(requests[i],
                                      deadline_ms=args.deadline_ms)
                break
            except ServeOverloadError:
                time.sleep(0.001)      # accepted-or-retried, never dropped
        ids, scores = fut.result()
        assert ids.shape == (args.batch, args.topk), ids.shape
        latencies[i] = time.monotonic() - t0

    # -- live-corpus writer (--mutate): adds, updates, deletes, and one
    # online compaction run concurrently with the request loop; serving
    # swaps generations between micro-batches, never mid-request ---------------
    mut_thread = None
    mut_stats = {"adds": 0, "updates": 0, "deletes": 0, "compactions": 0}
    gen_start = cache.generation_key
    stop_mut = threading.Event()
    if args.mutate:
        doc_ids = list(corpus)

        def _mutate_loop() -> None:
            i = 0
            # at least two iterations, so every run exercises an add, an
            # update, a delete, and the online compaction even when the
            # request loop finishes first
            while i < 2 or not stop_mut.is_set():
                new_id = f"live-doc-{i}"
                emb = np.asarray(mut_ev._encode_texts(
                    [f"live document {i} arriving mid serve"], False))
                cache.cache_records([new_id], emb)
                mut_stats["adds"] += 1
                upd = doc_ids[i % len(doc_ids)]
                emb = np.asarray(mut_ev._encode_texts(
                    [corpus[upd] + f" revised {i}"], False))
                cache.cache_records([upd], emb)
                mut_stats["updates"] += 1
                if i % 2 == 1:
                    cache.delete_records([f"live-doc-{i - 1}"])
                    mut_stats["deletes"] += 1
                if i == 1:
                    # online compaction: pinned readers keep serving the
                    # retired epoch's files until their rounds drain
                    cache.compact()
                    mut_stats["compactions"] += 1
                i += 1
                stop_mut.wait(0.002)

        mut_thread = threading.Thread(target=_mutate_loop,
                                      name="serve-mutate", daemon=True)
        mut_thread.start()

    t_loop = time.monotonic()
    if args.concurrency > 1:
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(args.concurrency,
                                thread_name_prefix="serve-client") as pool:
            list(pool.map(submit_one, range(args.n_requests)))
    else:
        for i in range(args.n_requests):
            submit_one(i)
    loop_s = time.monotonic() - t_loop
    if mut_thread is not None:
        stop_mut.set()
        mut_thread.join()
    frontend.close()

    for i, lat in enumerate(latencies):
        print(f"request {i}: {args.batch} queries -> top-{args.topk} "
              f"in {lat * 1e3:.1f} ms on {label}")
    lat_ms = np.sort(np.asarray(latencies)) * 1e3
    p50 = float(np.percentile(lat_ms, 50))
    p99 = float(np.percentile(lat_ms, 99))
    qps = args.n_requests * args.batch / loop_s if loop_s > 0 else 0.0
    fs = frontend.stats
    print(f"steady state: p50 {p50:.1f} ms  p99 {p99:.1f} ms  "
          f"{qps:.1f} queries/s  ({fs['batches']} micro-batches, "
          f"largest {fs['max_batch_seen']} queries)")
    if args.chaos:
        # no-lost-request evidence: the fault really fired, and every
        # accepted request still resolved (submit_one asserts shape, so
        # reaching here means all futures completed)
        assert injector.fired, "chaos fault never fired"
        fault_str = ", ".join(f"{k}@r{r}" for k, r, *_ in
                              ((f.kind, f.round) for f in injector.faults))
        print(f"chaos: injected [{fault_str}] -> {len(injector.fired)} "
              f"fired, {args.n_requests}/{args.n_requests} requests "
              f"resolved, {fs['degraded']} degraded, "
              f"{fs['expired']} expired")
    if args.mutate:
        gen_end = cache.generation_key
        # the writer really ran: generations advanced and every request
        # above still resolved with full-shape results (submit_one
        # asserts), i.e. zero downtime across mutation + compaction
        assert gen_end != gen_start, (gen_start, gen_end)
        assert mut_stats["adds"] > 0, mut_stats
        print(f"mutation: {mut_stats['adds']} adds, "
              f"{mut_stats['updates']} updates, "
              f"{mut_stats['deletes']} deletes, "
              f"{mut_stats['compactions']} compaction(s); generation "
              f"{gen_start} -> {gen_end}, {cache.n_live} live rows, "
              f"{args.n_requests}/{args.n_requests} requests resolved")
    print("serving done")
    return {"label": label, "warm_s": warm_s, "prep_s": prep_s,
            "latencies_ms": [float(x) * 1e3 for x in latencies],
            "p50_ms": p50, "p99_ms": p99, "qps": qps,
            "frontend": dict(fs), "mutation": dict(mut_stats),
            "generation": list(cache.generation_key)}


if __name__ == "__main__":
    main()
