"""Retrieval serving driver: encode a corpus once (mmap embedding cache),
then answer batched query requests with FastResultHeapq top-k.

  python -m repro.launch.serve --data-dir /tmp/trove_data --topk 10
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None):
    import jax
    import numpy as np

    from repro.core.collator import RetrievalCollator
    from repro.core.config import DataArguments, EvaluationArguments
    from repro.core.embedding_cache import EmbeddingCache
    from repro.core.evaluator import RetrievalEvaluator
    from repro.configs import get_arch
    from repro.data.synthetic import make_retrieval_dataset
    from repro.data.tokenizer import HashTokenizer
    from repro.models.encoder import DefaultEncoder
    from repro.models.retriever import BiEncoderRetriever
    from repro.training.checkpoint import (latest_checkpoint,
                                           restore_checkpoint)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="trove-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--data-dir", default="/tmp/trove_data")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.smoke:
        arch = arch.reduced().variant(dtype=jax.numpy.float32)
    if not os.path.exists(os.path.join(args.data_dir, "queries.jsonl")):
        make_retrieval_dataset(args.data_dir, n_queries=64, n_docs=512,
                               n_topics=32)
    queries, corpus = {}, {}
    for line in open(os.path.join(args.data_dir, "queries.jsonl")):
        rec = json.loads(line)
        queries[rec["_id"]] = rec["text"]
    for line in open(os.path.join(args.data_dir, "corpus.jsonl")):
        rec = json.loads(line)
        corpus[rec["_id"]] = rec["text"]

    tok = HashTokenizer(arch.cfg.vocab_size)
    retriever = BiEncoderRetriever(DefaultEncoder(arch.cfg), "infonce")
    collator = RetrievalCollator(
        DataArguments(vocab_size=arch.cfg.vocab_size), tok)

    params = retriever.init_params(jax.random.key(0))
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            state = restore_checkpoint(
                path, {"step": np.zeros((), np.int32), "params": params,
                       "opt": {}, "rng": np.zeros(2, np.uint32)})
            params = state["params"]
            print(f"restored {path}")

    ev = RetrievalEvaluator(
        EvaluationArguments(topk=args.topk), retriever, collator, params)
    cache = EmbeddingCache(os.path.join(args.data_dir, "emb_cache"),
                           dim=arch.cfg.d_model)
    # warm the corpus cache (the expensive pass, done once)
    t0 = time.monotonic()
    q_ids = list(queries)
    for i in range(args.n_requests):
        lo = (i * args.batch) % len(q_ids)
        req = {q: queries[q] for q in q_ids[lo: lo + args.batch]}
        qh, ids, scores = ev.search(req, corpus, cache=cache)
        dt = time.monotonic() - t0
        t0 = time.monotonic()
        print(f"request {i}: {len(req)} queries -> top-{args.topk} "
              f"in {dt*1e3:.1f} ms "
              f"(cache {len(cache)}/{len(corpus)} docs)")
    print("serving done")


if __name__ == "__main__":
    main()
