"""Analytic per-device memory model for dry-run cells.

``memory_analysis()`` on the CPU-target partitioned module is structurally
pessimistic: XLA:CPU neither fuses the fp32 norm/softmax intermediates nor
schedules for memory the way XLA:TPU does, so its temp numbers overstate
TPU HBM by an order of magnitude (measured: qwen2 train_4k reports 128 GB
temp while every individual buffer is <1 GB and the analytic bound is
~6 GB).  This module derives the defensible per-device budget from exact
sharded shapes:

  state+args  — exact: ``NamedSharding.shard_shape`` over the cell's
                abstract args (params, optimizer state, batch, KV cache)
  activations — family-specific closed forms under the declared remat /
                sequence-sharding policy (documented per formula)
  transient   — gradient buffer (fp32 copy of params) for train cells;
                one layer's live intermediates (score chunk, FFN/MoE
                buffers) with a 3x scheduling-slack factor

Reported next to the XLA numbers in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

import numpy as np
import jax


def _leaf_bytes_sharded(leaf) -> int:
    shape = leaf.shape
    sh = getattr(leaf, "sharding", None)
    if sh is not None and hasattr(sh, "shard_shape") and shape:
        try:
            shape = sh.shard_shape(tuple(shape))
        except Exception:
            pass
    return int(np.prod(shape)) * leaf.dtype.itemsize if shape else \
        leaf.dtype.itemsize


def args_bytes_per_device(abstract_args) -> int:
    return sum(_leaf_bytes_sharded(l)
               for l in jax.tree.leaves(abstract_args))


def _lm_activation_bytes(arch, shape_name, mesh) -> int:
    cfg = arch.cfg
    spec = arch.shapes[shape_name]
    b, s = spec["global_batch"], spec["seq_len"]
    dp = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    tp = mesh.shape.get("model", 1)
    b_loc = max(1, b // dp)
    bpe = 2 if cfg.dtype == jax.numpy.bfloat16 else 4

    if spec["kind"] == "serve":
        # single token: qkv + logits; cache already counted in args
        return b_loc * cfg.vocab_size * 4 + b_loc * cfg.d_model * bpe * 8

    s_saved = s // tp if cfg.seq_shard_acts else s
    saved = cfg.n_layers * b_loc * s_saved * cfg.d_model * bpe

    # within-layer peak: attention scores (chunked / SP / head-sharded)
    chunk = cfg.attn_chunk if (cfg.attn_chunk and s > cfg.attn_chunk) else s
    if cfg.seq_shard_attn:
        sq_loc = max(1, chunk // tp)
        heads_shard = 1
    else:
        sq_loc = chunk
        heads_shard = tp if cfg.n_kv_heads % tp == 0 else 1
    scores = b_loc * (cfg.n_kv_heads // heads_shard) * \
        (cfg.n_heads // cfg.n_kv_heads) * sq_loc * s * 4
    ffn_shard = tp if cfg.d_ff % tp == 0 else 1
    ffn = b_loc * s * (cfg.d_ff // ffn_shard) * bpe * 2
    moe = 0
    if cfg.moe:
        e_shard = tp if cfg.n_experts % tp == 0 else 1
        cap = int(np.ceil(s * cfg.top_k / cfg.n_experts
                          * cfg.capacity_factor))
        moe = b_loc * (cfg.n_experts // e_shard) * cap * (
            cfg.d_model + cfg.moe_d_ff) * bpe
    peak_layer = max(scores + ffn, scores + moe)
    mult = 3 if spec["kind"] == "train" else 2   # bwd/live-slack factor
    return saved + mult * peak_layer


def _gnn_activation_bytes(arch, shape_name, mesh) -> int:
    spec = arch.shapes[shape_name]
    cfg = arch.shape_cfg(shape_name)
    dp = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    if spec["mode"] == "full":
        n, e = spec["n_nodes"], spec["n_edges"]
        per = (n * (cfg.d_feat + 2 * cfg.d_hidden)
               + e * (cfg.d_feat + cfg.d_hidden)) * 4
        return 3 * per // dp
    if spec["mode"] == "minibatch":
        b = spec["batch_nodes"]
        f1, f2 = spec["fanouts"]
        nodes = 2 * b * (1 + f1 + f1 * f2)
        return 3 * nodes * max(cfg.d_feat, cfg.d_hidden) * 4 // dp
    g, n = spec["n_graphs"], spec["n_nodes"]
    return 3 * 2 * g * n * max(cfg.d_feat, cfg.d_hidden) * 4 // dp


def _recsys_activation_bytes(arch, shape_name, mesh) -> int:
    spec = arch.shapes[shape_name]
    cfg = arch.cfg
    dp = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    b = (spec["n_candidates"] if spec["kind"] == "retrieval"
         else spec["batch"])
    width = max(cfg.n_fields * cfg.embed_dim,
                max(cfg.mlp_dims) if cfg.mlp_dims else 0,
                cfg.n_fields * cfg.n_heads * cfg.d_attn)
    mult = 3 if spec["kind"] == "train" else 1
    return mult * (b // dp) * width * 4 * 2


def activation_bytes(arch, shape_name, mesh) -> int:
    fam = {"lm": _lm_activation_bytes, "gnn": _gnn_activation_bytes,
           "recsys": _recsys_activation_bytes}[arch.family]
    return int(fam(arch, shape_name, mesh))


def grad_transient_bytes(cell, abstract_state) -> int:
    """fp32 gradient buffer for train cells (exists between bwd and opt)."""
    if cell.kind != "train":
        return 0
    params = abstract_state.get("params", {})
    total = 0
    for leaf in jax.tree.leaves(params):
        shape = leaf.shape
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "shard_shape") and shape:
            try:
                shape = sh.shard_shape(tuple(shape))
            except Exception:
                pass
        total += int(np.prod(shape)) * 4
    return total


def memory_model(arch, shape_name, mesh, cell) -> dict:
    if cell.kind == "train":
        state = cell.abstract_args[0]
        args_b = args_bytes_per_device(cell.abstract_args)
        grad_b = grad_transient_bytes(cell, state)
    else:
        args_b = args_bytes_per_device(cell.abstract_args)
        grad_b = 0
    act_b = activation_bytes(arch, shape_name, mesh)
    total = args_b + grad_b + act_b
    return {
        "state_and_args_bytes": int(args_b),
        "grad_transient_bytes": int(grad_b),
        "activation_bytes": int(act_b),
        "total_bytes": int(total),
        "fits_16GB": bool(total < 16e9),
    }
