"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod);
multi-pod: 2x16x16 = 512 chips.  The "pod" axis composes with "data" for
batch/corpus/FSDP sharding (see repro.sharding.partitioning.DEFAULT_RULES),
so adding pods scales data parallelism; "model" carries TP/EP.
"""

from __future__ import annotations

import jax

from repro.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
