import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first
# initialization.  512 host devices let make_mesh build the production
# meshes ((16,16) single-pod / (2,16,16) multi-pod) on this CPU container.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:  ``jax.jit(step).lower(*abstract_args).compile()`` must
succeed against the production mesh — proving the sharding config is
coherent (no mismatch, no compile-OOM, partitionable collectives) with
ZERO real allocation (inputs are ShapeDtypeStructs).  Records
``memory_analysis`` (fits-on-chip proof), ``cost_analysis`` (FLOPs/bytes)
and the parsed collective schedule for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import all_cells, get_arch
from repro.launch.memmodel import memory_model
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (collective_breakdown, collective_bytes,
                                   model_flops, normalize_cost,
                                   roofline_terms)


# archs whose unrolled-HLO compile is impractically slow on this 1-core
# container (llama4: 48 unrolled MoE layers > 30 min in XLA:CPU).  They
# lower with scan; since HloCostAnalysis counts while bodies once, their
# roofline compute term is substituted from MODEL_FLOPS x remat factor
# (flops_source="analytic" in the record).
FORCE_SCAN = {"llama4-maverick-400b-a17b"}
REMAT_RECOMPUTE_FACTOR = 4.0 / 3.0      # fwd + bwd + fwd-recompute / (fwd+bwd)


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             mesh=None, arch=None) -> dict:
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    arch = arch or get_arch(arch_name)
    force_scan = arch_name in FORCE_SCAN
    if (multi_pod or force_scan) and hasattr(arch, "variant"):
        # multi-pod pass proves the "pod" axis shards (compile success);
        # scan-over-layers keeps the HLO compact => fast 512-way compiles.
        # The single-pod pass stays unrolled for exact cost analysis.
        arch = arch.variant(scan_layers=True)
    cell = arch.build_cell(shape_name, mesh=mesh)
    t0 = time.monotonic()
    with mesh:
        lowered = jax.jit(cell.fn, **cell.jit_kwargs).lower(
            *cell.abstract_args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = normalize_cost(compiled.cost_analysis())
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    breakdown = [
        {"kind": k, "operand": o, "count": c, "bytes": b}
        for k, o, c, b in collective_breakdown(hlo_text)]
    n_dev = mesh.size
    terms = roofline_terms(cost, coll["total"])
    mf = model_flops(arch, shape_name)
    flops_source = "hlo"
    if force_scan and not multi_pod:
        # scan under-reports HLO flops (while bodies counted once):
        # substitute the analytic term, keep the raw HLO value alongside
        mult = (REMAT_RECOMPUTE_FACTOR
                if cell.kind == "train" else 1.0)
        analytic = mf * mult / n_dev
        terms["hlo_flops_per_device_raw_scan"] = \
            terms["hlo_flops_per_device"]
        terms["hlo_flops_per_device"] = analytic
        terms["compute_s"] = analytic / 197e12
        bound = max(terms["compute_s"], terms["memory_s"],
                    terms["collective_s"])
        terms["step_lower_bound_s"] = bound
        terms["roofline_fraction"] = (terms["compute_s"] / bound
                                      if bound else 0.0)
        flops_source = "analytic(model_flops x remat)"
    hlo_flops_global = terms["hlo_flops_per_device"] * n_dev
    rec = {
        "arch": arch_name, "shape": shape_name, "kind": cell.kind,
        "mesh": dict(mesh.shape), "n_devices": n_dev,
        "notes": cell.notes,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # xla_*: CPU-backend buffer assignment (pessimistic vs TPU — no
        # fusion/schedule parity; see memmodel.py).  memory_model: analytic
        # per-device budget from exact sharded shapes — the fit proof.
        "memory": {
            "xla_argument_bytes": mem.argument_size_in_bytes,
            "xla_output_bytes": mem.output_size_in_bytes,
            "xla_temp_bytes": mem.temp_size_in_bytes,
            "model": memory_model(arch, shape_name, mesh, cell),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")
                 if k in cost},
        "collectives": coll,
        "collective_breakdown": breakdown,
        "roofline": terms,
        "flops_source": flops_source,
        "model_flops_global": mf,
        "useful_compute_ratio": (
            mf / hlo_flops_global if hlo_flops_global else None),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = (all_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = ([False, True] if args.both_meshes
              else [args.multi_pod])
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        tag = "multipod" if multi_pod else "singlepod"
        for arch_name, shape_name in cells:
            path = os.path.join(args.out,
                                f"{tag}__{arch_name}__{shape_name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag} {arch_name} {shape_name}")
                continue
            t0 = time.monotonic()
            try:
                rec = run_cell(arch_name, shape_name, multi_pod, mesh=mesh)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                mm = rec["memory"]["model"]
                print(f"[ok]   {tag} {arch_name} {shape_name} "
                      f"compile={rec['compile_s']:.1f}s "
                      f"mem/dev={mm['total_bytes']/1e9:.2f}GB"
                      f"{'' if mm['fits_16GB'] else '(!)'} "
                      f"dom={r['dominant']} "
                      f"c={r['compute_s']*1e3:.2f}ms "
                      f"m={r['memory_s']*1e3:.2f}ms "
                      f"n={r['collective_s']*1e3:.2f}ms",
                      flush=True)
            except Exception as e:                      # noqa: BLE001
                failures.append((tag, arch_name, shape_name, str(e)))
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[FAIL] {tag} {arch_name} {shape_name}: "
                      f"{type(e).__name__}: {str(e)[:200]}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL CELLS COMPILED")


if __name__ == "__main__":
    main()
