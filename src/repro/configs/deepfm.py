"""deepfm: 39 sparse fields, embed_dim=10, MLP 400-400-400, FM
interaction [arXiv:1703.04247]."""
from repro.configs.base import RecSysArch
from repro.models.recsys import RecSysConfig

_VOCABS = ((2**24, 2**23, 2**22, 2**22) + (2**16,) * 10 + (2**12,) * 25)


def get_arch() -> RecSysArch:
    return RecSysArch(RecSysConfig(
        name="deepfm", kind="deepfm", vocab_sizes=_VOCABS, embed_dim=10,
        mlp_dims=(400, 400, 400)))
