"""llama4-maverick-400b-a17b: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + shared expert, interleaved
dense/MoE layers [hf:meta-llama/Llama-4; unverified].

~400B total / ~17B active parameters; requires FSDP ("fsdp" rule over
pod x data) + expert sharding over "model" + attn_chunk=1024 (§Perf:
the 4096-chunk baseline peaks at 21.7 GB/device; 1024 fits at 15.6 GB).
"""
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig


def get_arch() -> LMArch:
    return LMArch(LMConfig(
        name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
        vocab_size=202048, activation="swiglu", norm="rmsnorm", moe=True,
        n_experts=128, top_k=1, moe_every=2, n_shared_experts=1,
        moe_d_ff=8192, capacity_factor=1.25, pooling="last",
        dtype=jnp.bfloat16, attn_chunk=1024, remat=True,
        scan_layers=False, seq_shard_acts=True, seq_shard_attn=True))
