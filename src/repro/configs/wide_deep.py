"""wide-deep: 40 sparse fields, embed_dim=32, MLP 1024-512-256, wide
linear + deep concat interaction [arXiv:1606.07792]."""
from repro.configs.base import RecSysArch
from repro.models.recsys import RecSysConfig

_VOCABS = ((2**24, 2**23, 2**22, 2**22) + (2**16,) * 11 + (2**12,) * 25)


def get_arch() -> RecSysArch:
    return RecSysArch(RecSysConfig(
        name="wide-deep", kind="wide_deep", vocab_sizes=_VOCABS,
        embed_dim=32, mlp_dims=(1024, 512, 256)))
