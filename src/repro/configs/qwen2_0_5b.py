"""qwen2-0.5b: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151936, QKV bias
[arXiv:2407.10671; hf]."""
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig


def get_arch() -> LMArch:
    return LMArch(LMConfig(
        name="qwen2-0.5b", n_layers=24, d_model=896, n_heads=14,
        n_kv_heads=2, head_dim=64, d_ff=4864, vocab_size=151936,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=1000000.0, pooling="last", dtype=jnp.bfloat16,
        attn_chunk=4096, remat=True,
        scan_layers=False, seq_shard_acts=True, seq_shard_attn=True))
