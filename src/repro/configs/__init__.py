"""Architecture registry: ``--arch <id>`` -> Arch object (DESIGN.md §4)."""

from __future__ import annotations

import importlib

ARCH_MODULES = {
    "gemma-7b": "gemma_7b",
    "qwen2-0.5b": "qwen2_0_5b",
    "stablelm-3b": "stablelm_3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "graphsage-reddit": "graphsage_reddit",
    "bst": "bst",
    "autoint": "autoint",
    "deepfm": "deepfm",
    "wide-deep": "wide_deep",
    "trove-base": "trove_base",
}

ARCH_NAMES = [n for n in ARCH_MODULES if n != "trove-base"]


def get_arch(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.get_arch()


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch x shape) dry-run cells."""
    out = []
    for name in ARCH_NAMES:
        arch = get_arch(name)
        for shape in arch.shape_names():
            out.append((name, shape))
    return out
