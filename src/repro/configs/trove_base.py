"""trove-base: the paper's default retrieval encoder (BERT-base-like
bidirectional-free decoder, mean pooling) used by examples."""
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig


def get_arch() -> LMArch:
    return LMArch(LMConfig(
        name="trove-base", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=50304,
        activation="gelu", norm="layernorm", pooling="mean",
        dtype=jnp.bfloat16, remat=True), optimizer="adamw")
