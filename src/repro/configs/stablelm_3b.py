"""stablelm-3b: 32L d=2560 32H (GQA kv=32) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm-2-1_6b; unverified] — LayerNorm variant."""
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig


def get_arch() -> LMArch:
    return LMArch(LMConfig(
        name="stablelm-3b", n_layers=32, d_model=2560, n_heads=32,
        n_kv_heads=32, head_dim=80, d_ff=6912, vocab_size=50304,
        activation="swiglu", norm="layernorm", rope_theta=10000.0,
        pooling="last", dtype=jnp.bfloat16, attn_chunk=4096, remat=True,
        scan_layers=False, seq_shard_acts=True))
