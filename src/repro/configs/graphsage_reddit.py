"""graphsage-reddit: 2 layers, d_hidden=128, mean aggregator,
fanouts 25-10 [arXiv:1706.02216; paper]."""
from repro.configs.base import GNNArch
from repro.models.gnn import SAGEConfig


def get_arch() -> GNNArch:
    return GNNArch(SAGEConfig(
        name="graphsage-reddit", n_layers=2, d_feat=602, d_hidden=128,
        aggregator="mean", fanouts=(25, 10)))
