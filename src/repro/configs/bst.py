"""bst: Behavior Sequence Transformer (Alibaba) — embed_dim=32,
seq_len=20, 1 block, 8 heads, MLP 1024-512-256 [arXiv:1905.06874]."""
from repro.configs.base import RecSysArch
from repro.models.recsys import RecSysConfig

# item table 4.2M rows + 8 profile fields
_VOCABS = (4_194_304,) + (1024,) * 8


def get_arch() -> RecSysArch:
    return RecSysArch(RecSysConfig(
        name="bst", kind="bst", vocab_sizes=_VOCABS, embed_dim=32,
        mlp_dims=(1024, 512, 256), seq_len=20, n_profile_fields=8,
        bst_d_ff=64))
