"""Architecture cell construction: (arch x input-shape) -> lowerable step.

Every assigned architecture exposes the same surface:

  * ``abstract_params()`` / ``param_logical_axes()`` / ``axis_rules()``
  * ``shape_names()`` and ``build_cell(shape, mesh)`` -> :class:`Cell`
  * ``reduced()`` — a small same-family config for CPU smoke tests,
    with ``smoke_inputs(rng)`` producing real arrays.

A :class:`Cell` bundles the jit-able step function with sharding-annotated
``ShapeDtypeStruct`` arguments: ``jax.jit(cell.fn, **cell.jit_kwargs)
.lower(*cell.abstract_args)`` is exactly the multi-pod dry-run contract.

Step kinds per family (DESIGN.md §4):
  lm:     train (contrastive bi-encoder fwd+bwd+adafactor update),
          encode (corpus prefill), serve (1-token decode w/ KV cache)
  gnn:    train (unsupervised GraphSAGE InfoNCE, full/minibatch/batched)
  recsys: train (CTR BCE fwd+bwd+adamw), serve (scoring),
          retrieval (1 user x N candidates + top-k — FastResultHeapq)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.models.losses import BCELoss, InfoNCELoss
from repro.sharding.partitioning import AxisRules
from repro.training.optimizer import (OptimizerConfig, clip_by_global_norm,
                                      make_optimizer)


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                       # train | encode | serve | retrieval
    fn: Callable
    abstract_args: tuple
    jit_kwargs: dict
    notes: str = ""


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


class ShardCtx:
    """Resolves logical axes -> NamedSharding for one (mesh, rules)."""

    def __init__(self, mesh, rules: AxisRules):
        self.mesh = mesh
        self.rules = rules

    def shard(self, tree, axes_tree):
        if self.mesh is None:
            return tree

        def one(leaf, axes):
            spec = self.rules.spec_for(axes, leaf.shape, self.mesh)
            return _sds(leaf.shape, leaf.dtype,
                        NamedSharding(self.mesh, spec))

        return jax.tree.map(
            one, tree, axes_tree,
            is_leaf=lambda x: isinstance(x, (tuple, list)) and all(
                isinstance(e, (str, type(None))) for e in x))

    def ctx(self):
        return (self.mesh, self.rules) if self.mesh is not None else None


def make_train_cell(arch_name: str, shape_name: str, *,
                    loss_fn: Callable, abstract_params, param_axes,
                    batch_specs, batch_axes, rules: AxisRules, mesh,
                    optimizer: str = "adafactor", notes: str = "") -> Cell:
    """fwd + bwd + optimizer update — the full per-step training work."""
    opt_cfg = OptimizerConfig(name=optimizer, learning_rate=1e-3)
    opt_init, opt_update = make_optimizer(opt_cfg)
    sc = ShardCtx(mesh, rules)
    ctx = sc.ctx()

    def step(state, batch):
        def loss_of(params):
            out = loss_fn(params, batch, ctx)
            return out if not isinstance(out, tuple) else out[0]

        loss, grads = jax.value_and_grad(loss_of)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_opt = opt_update(
            grads, state["opt"], state["params"], state["step"])
        return {"step": state["step"] + 1, "params": new_params,
                "opt": new_opt}, {"loss": loss, "grad_norm": gnorm}

    abs_state = {
        "step": _sds((), jnp.int32,
                     NamedSharding(mesh, P()) if mesh else None),
        "params": abstract_params,
        "opt": jax.eval_shape(opt_init, abstract_params),
    }
    # shard params + mirror opt
    abs_state["params"] = sc.shard(abstract_params, param_axes)
    abs_state["opt"] = _opt_shardings(abs_state["opt"], abstract_params,
                                      param_axes, sc)
    abs_batch = sc.shard(batch_specs, batch_axes)
    return Cell(arch_name, shape_name, "train", step,
                (abs_state, abs_batch), {"donate_argnums": (0,)}, notes)


def _opt_shardings(abs_opt, abstract_params, param_axes, sc: ShardCtx):
    if sc.mesh is None:
        return abs_opt
    if "mu" in abs_opt:
        return {"mu": sc.shard(abstract_params, param_axes),
                "nu": sc.shard(abstract_params, param_axes)}

    def fac(p_leaf, axes, v_dict):
        axes = tuple(axes)
        out = {}
        for k, leaf in v_dict.items():
            if k == "v":
                a = axes
            elif k == "vr":
                a = axes[:-1]
            else:
                a = axes[:-2] + axes[-1:]
            spec = sc.rules.spec_for(a, leaf.shape, sc.mesh)
            out[k] = _sds(leaf.shape, leaf.dtype,
                          NamedSharding(sc.mesh, spec))
        return out

    is_v = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    return {"v": jax.tree.map(
        fac, abstract_params, param_axes, abs_opt["v"],
        is_leaf=lambda x: hasattr(x, "shape") or (
            isinstance(x, (tuple, list)) and all(
                isinstance(e, (str, type(None))) for e in x)) or is_v(x))}


def make_infer_cell(arch_name, shape_name, kind, fn, abstract_params,
                    param_axes, batch_specs, batch_axes, rules, mesh,
                    donate_batch=False, notes="") -> Cell:
    sc = ShardCtx(mesh, rules)
    abs_params = sc.shard(abstract_params, param_axes)
    abs_batch = sc.shard(batch_specs, batch_axes)
    jit_kwargs = {"donate_argnums": (1,)} if donate_batch else {}
    return Cell(arch_name, shape_name, kind, fn,
                (abs_params, abs_batch), jit_kwargs, notes)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="encode", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="serve", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="serve", seq_len=524288, global_batch=1),
}


class LMArch:
    family = "lm"

    def __init__(self, cfg: tfm.LMConfig, optimizer: str = "adafactor",
                 shapes: dict | None = None):
        self.cfg = cfg
        self.name = cfg.name
        self.optimizer = optimizer
        self.shapes = shapes or LM_SHAPES

    def shape_names(self):
        return list(self.shapes)

    def axis_rules(self):
        return tfm.LM_RULES

    def variant(self, **overrides) -> "LMArch":
        """Config-overridden copy (e.g. scan_layers=True for fast
        multi-pod compile checks, or §Perf hillclimb candidates)."""
        return LMArch(dataclasses.replace(self.cfg, **overrides),
                      optimizer=self.optimizer, shapes=self.shapes)

    def abstract_params(self):
        return tfm.abstract_params(self.cfg)

    def param_logical_axes(self):
        return tfm.param_logical_axes(self.cfg)

    # -- step functions ------------------------------------------------------
    def _contrastive_loss(self):
        loss = InfoNCELoss()
        cfg = self.cfg

        def fn(params, batch, ctx):
            q = tfm.encode(cfg, params, batch["query"]["tokens"],
                           batch["query"]["mask"], ctx)
            hidden, aux = tfm.forward_hidden(
                cfg, params, batch["passage"]["tokens"],
                batch["passage"]["mask"], ctx)
            p = tfm.pool(cfg, hidden, batch["passage"]["mask"])
            scores = jnp.einsum("qd,pd->qp", q, p) / 0.02
            labels = jnp.arange(q.shape[0], dtype=jnp.int32)
            return loss(scores, labels) + 0.01 * aux

        return fn

    def build_cell(self, shape_name: str, mesh=None) -> Cell:
        spec = self.shapes[shape_name]
        cfg = self.cfg
        rules = self.axis_rules()
        b, s = spec["global_batch"], spec["seq_len"]
        tok_specs = lambda bb, ss: {
            "tokens": _sds((bb, ss), jnp.int32),
            "mask": _sds((bb, ss), jnp.int32)}
        tok_axes = {"tokens": ("batch", None), "mask": ("batch", None)}

        if spec["kind"] == "train":
            batch = {"query": tok_specs(b, s), "passage": tok_specs(b, s)}
            axes = {"query": tok_axes, "passage": tok_axes}
            return make_train_cell(
                self.name, shape_name, loss_fn=self._contrastive_loss(),
                abstract_params=self.abstract_params(),
                param_axes=self.param_logical_axes(), batch_specs=batch,
                batch_axes=axes, rules=rules, mesh=mesh,
                optimizer=self.optimizer,
                notes="contrastive bi-encoder step (fwd+bwd+opt)")

        if spec["kind"] == "encode":
            def encode_fn(params, batch):
                ctx = (mesh, rules) if mesh is not None else None
                return tfm.encode(cfg, params, batch["tokens"],
                                  batch["mask"], ctx)
            return make_infer_cell(
                self.name, shape_name, "encode", encode_fn,
                self.abstract_params(), self.param_logical_axes(),
                tok_specs(b, s), tok_axes, rules, mesh,
                notes="corpus-encoding prefill")

        # serve: single-token decode against a full KV cache
        cache_specs = jax.eval_shape(
            lambda: tfm.init_cache(cfg, b, s))
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        cache_axes = tfm.cache_logical_axes(
            cfg, b, tp_divides_kv=(cfg.n_kv_heads % tp == 0))

        def serve_fn(params, cache, tokens):
            ctx = (mesh, rules) if mesh is not None else None
            return tfm.decode_step(cfg, params, cache, tokens, ctx)

        sc = ShardCtx(mesh, rules)
        abs_params = sc.shard(self.abstract_params(),
                              self.param_logical_axes())
        abs_cache = sc.shard(cache_specs, cache_axes)
        abs_tokens = sc.shard(_sds((b,), jnp.int32), ("batch",))
        return Cell(self.name, shape_name, "serve", serve_fn,
                    (abs_params, abs_cache, abs_tokens),
                    {"donate_argnums": (1,)},
                    notes=f"1-token decode, KV cache len {s}")

    # -- smoke -----------------------------------------------------------------
    def reduced(self) -> "LMArch":
        c = self.cfg
        small = dataclasses.replace(
            c, n_layers=2 if not c.moe or c.moe_every == 1 else 2,
            d_model=64, n_heads=4,
            n_kv_heads=2 if c.n_kv_heads < c.n_heads else 4,
            head_dim=16, d_ff=128, vocab_size=512,
            n_experts=min(c.n_experts, 8) if c.moe else 0,
            top_k=min(c.top_k, 2) if c.moe else 0,
            moe_d_ff=32 if c.moe else 0,
            dtype=jnp.float32, attn_chunk=0, remat=False)
        shapes = {
            "train_4k": dict(kind="train", seq_len=32, global_batch=4),
            "prefill_32k": dict(kind="encode", seq_len=64, global_batch=2),
            "decode_32k": dict(kind="serve", seq_len=64, global_batch=4),
            "long_500k": dict(kind="serve", seq_len=128, global_batch=1),
        }
        return LMArch(small, optimizer="adamw", shapes=shapes)

    def smoke_inputs(self, shape_name: str, rng: np.random.Generator):
        spec = self.shapes[shape_name]
        b, s = spec["global_batch"], spec["seq_len"]
        V = self.cfg.vocab_size
        toks = lambda bb, ss: {
            "tokens": jnp.asarray(rng.integers(3, V, (bb, ss)), jnp.int32),
            "mask": jnp.ones((bb, ss), jnp.int32)}
        if spec["kind"] == "train":
            return {"query": toks(b, s), "passage": toks(b, s)}
        if spec["kind"] == "encode":
            return toks(b, s)
        cache = tfm.init_cache(self.cfg, b, s)
        cache["len"] = jnp.asarray(s - 1, jnp.int32)
        return (cache, jnp.asarray(rng.integers(3, V, (b,)), jnp.int32))


# ---------------------------------------------------------------------------
# GNN family (GraphSAGE)
# ---------------------------------------------------------------------------

GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", mode="full", n_nodes=2708,
                          n_edges=10556, d_feat=1433, n_pairs=1024),
    "minibatch_lg": dict(kind="train", mode="minibatch", batch_nodes=1024,
                         fanouts=(15, 10), d_feat=602),
    "ogb_products": dict(kind="train", mode="full", n_nodes=2449029,
                         n_edges=61859140, d_feat=100, n_pairs=8192),
    "molecule": dict(kind="train", mode="batched", n_graphs=128,
                     n_nodes=30, n_edges=64, d_feat=64),
}


class GNNArch:
    family = "gnn"

    def __init__(self, cfg: gnn_lib.SAGEConfig, shapes=None, pad: int = 512):
        self.cfg = cfg
        self.name = cfg.name
        self.shapes = shapes or GNN_SHAPES
        self.pad = pad

    def shape_names(self):
        return list(self.shapes)

    def axis_rules(self):
        return AxisRules()

    def abstract_params(self):
        return gnn_lib.abstract_params(self.cfg)

    def param_logical_axes(self):
        return gnn_lib.param_logical_axes(self.cfg)

    def _loss(self, mode, cfg):
        loss = InfoNCELoss()

        def full(params, batch, ctx):
            z = gnn_lib.forward_full(cfg, params, batch["x"],
                                     batch["edge_src"], batch["edge_dst"])
            zq = jnp.take(z, batch["pairs"][:, 0], axis=0)
            zp = jnp.take(z, batch["pairs"][:, 1], axis=0)
            scores = jnp.einsum("qd,pd->qp", zq, zp) / 0.07
            return loss(scores, jnp.arange(zq.shape[0], dtype=jnp.int32))

        def minibatch(params, batch, ctx):
            za = gnn_lib.forward_minibatch(
                cfg, params, batch["a0"], batch["a1"], batch["a2"])
            zp = gnn_lib.forward_minibatch(
                cfg, params, batch["p0"], batch["p1"], batch["p2"])
            scores = jnp.einsum("qd,pd->qp", za, zp) / 0.07
            return loss(scores, jnp.arange(za.shape[0], dtype=jnp.int32))

        def batched(params, batch, ctx):
            za = gnn_lib.forward_batched_graphs(
                cfg, params, batch["ax"], batch["aedges"],
                batch["aemask"], batch["anmask"])
            zp = gnn_lib.forward_batched_graphs(
                cfg, params, batch["px"], batch["pedges"],
                batch["pemask"], batch["pnmask"])
            scores = jnp.einsum("qd,pd->qp", za, zp) / 0.07
            return loss(scores, jnp.arange(za.shape[0], dtype=jnp.int32))

        return {"full": full, "minibatch": minibatch,
                "batched": batched}[mode]

    def _batch_specs(self, spec):
        f32 = jnp.float32
        if spec["mode"] == "full":
            n = round_up(spec["n_nodes"], self.pad)
            e = round_up(spec["n_edges"], self.pad)
            p = spec["n_pairs"]
            batch = {"x": _sds((n, spec["d_feat"]), f32),
                     "edge_src": _sds((e,), jnp.int32),
                     "edge_dst": _sds((e,), jnp.int32),
                     "pairs": _sds((p, 2), jnp.int32)}
            axes = {"x": ("nodes", None), "edge_src": ("edges",),
                    "edge_dst": ("edges",), "pairs": ("batch", None)}
            note = (f"padded to nodes={n} edges={e} "
                    "(isolated-node padding by the loader)")
        elif spec["mode"] == "minibatch":
            b = spec["batch_nodes"]
            f1, f2 = spec["fanouts"]
            d = spec["d_feat"]
            tree = lambda: {
                "0": _sds((b, d), f32), "1": _sds((b, f1, d), f32),
                "2": _sds((b, f1, f2, d), f32)}
            batch = {f"{side}{k}": v for side in "ap"
                     for k, v in tree().items()}
            axes = {f"{side}{k}": ("batch",) + (None,) * (1 + int(k) )
                    for side in "ap" for k in "012"}
            note = f"fixed-fanout {f1}x{f2} sampled blocks (real sampler)"
        else:
            g, n, e, d = (spec["n_graphs"], spec["n_nodes"],
                          spec["n_edges"], spec["d_feat"])
            one = lambda p: {
                f"{p}x": _sds((g, n, d), f32),
                f"{p}edges": _sds((g, e, 2), jnp.int32),
                f"{p}emask": _sds((g, e), jnp.int32),
                f"{p}nmask": _sds((g, n), jnp.int32)}
            batch = {**one("a"), **one("p")}
            axes = {k: ("batch",) + (None,) * (len(v.shape) - 1)
                    for k, v in batch.items()}
            note = "batched small graphs, anchor+positive views"
        return batch, axes, note

    def shape_cfg(self, shape_name) -> gnn_lib.SAGEConfig:
        """Per-shape config: the input feature width is dataset-specific."""
        return dataclasses.replace(
            self.cfg, d_feat=self.shapes[shape_name]["d_feat"])

    def build_cell(self, shape_name, mesh=None) -> Cell:
        spec = self.shapes[shape_name]
        cfg_s = self.shape_cfg(shape_name)
        batch, axes, note = self._batch_specs(spec)
        return make_train_cell(
            self.name, shape_name, loss_fn=self._loss(spec["mode"], cfg_s),
            abstract_params=gnn_lib.abstract_params(cfg_s),
            param_axes=gnn_lib.param_logical_axes(cfg_s), batch_specs=batch,
            batch_axes=axes, rules=self.axis_rules(), mesh=mesh,
            optimizer="adamw", notes=note)

    def reduced(self) -> "GNNArch":
        small = dataclasses.replace(self.cfg, d_hidden=16)
        shapes = {
            "full_graph_sm": dict(kind="train", mode="full", n_nodes=64,
                                  n_edges=256, d_feat=12, n_pairs=16),
            "minibatch_lg": dict(kind="train", mode="minibatch",
                                 batch_nodes=8, fanouts=(3, 2), d_feat=12),
            "ogb_products": dict(kind="train", mode="full", n_nodes=128,
                                 n_edges=512, d_feat=12, n_pairs=32),
            "molecule": dict(kind="train", mode="batched", n_graphs=4,
                             n_nodes=6, n_edges=10, d_feat=12),
        }
        small = dataclasses.replace(small, d_feat=12)
        return GNNArch(small, shapes=shapes, pad=8)

    def smoke_inputs(self, shape_name, rng: np.random.Generator):
        spec = self.shapes[shape_name]
        batch, _, _ = self._batch_specs(spec)

        def rand(s):
            if s.dtype == jnp.int32:
                hi = 4
                if "edge" in getattr(s, "_name", "") or True:
                    hi = 4
                return jnp.asarray(rng.integers(0, hi, s.shape), jnp.int32)
            return jnp.asarray(rng.normal(size=s.shape), jnp.float32)

        out = {}
        for k, s in batch.items():
            if s.dtype == jnp.int32:
                if k in ("edge_src", "edge_dst"):
                    n = round_up(spec["n_nodes"], self.pad)
                    out[k] = jnp.asarray(
                        rng.integers(0, spec["n_nodes"], s.shape), jnp.int32)
                elif k == "pairs":
                    out[k] = jnp.asarray(
                        rng.integers(0, spec["n_nodes"], s.shape), jnp.int32)
                elif k.endswith("edges"):
                    out[k] = jnp.asarray(
                        rng.integers(0, spec["n_nodes"], s.shape), jnp.int32)
                elif k.endswith("mask"):
                    out[k] = jnp.ones(s.shape, jnp.int32)
                else:
                    out[k] = jnp.asarray(
                        rng.integers(0, 4, s.shape), jnp.int32)
            else:
                out[k] = jnp.asarray(
                    rng.normal(size=s.shape).astype(np.float32))
        return out


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000, topk=100),
}


class RecSysArch:
    family = "recsys"

    def __init__(self, cfg: recsys_lib.RecSysConfig, shapes=None,
                 rule_overrides: dict | None = None):
        self.cfg = cfg
        self.name = cfg.name
        self.shapes = shapes or RECSYS_SHAPES
        self.rule_overrides = rule_overrides or {}

    def shape_names(self):
        return list(self.shapes)

    def axis_rules(self):
        return AxisRules().with_overrides(**self.rule_overrides)

    def abstract_params(self):
        return recsys_lib.abstract_params(self.cfg)

    def param_logical_axes(self):
        return recsys_lib.param_logical_axes(self.cfg)

    def _batch_specs(self, spec):
        cfg = self.cfg
        b = spec["batch"]
        if spec["kind"] == "retrieval":
            n = spec["n_candidates"]
            if cfg.kind == "bst":
                batch = {"hist": _sds((1, cfg.seq_len), jnp.int32),
                         "profile": _sds((1, cfg.n_profile_fields),
                                         jnp.int32),
                         "cand_idx": _sds((n,), jnp.int32)}
                axes = {"hist": (None, None), "profile": (None, None),
                        "cand_idx": ("candidates",)}
            else:
                batch = {"user_idx": _sds((1, cfg.n_fields - 1), jnp.int32),
                         "cand_idx": _sds((n,), jnp.int32)}
                axes = {"user_idx": (None, None),
                        "cand_idx": ("candidates",)}
            return batch, axes
        if cfg.kind == "bst":
            batch = {"hist": _sds((b, cfg.seq_len), jnp.int32),
                     "target": _sds((b,), jnp.int32),
                     "profile": _sds((b, cfg.n_profile_fields), jnp.int32)}
            axes = {"hist": ("batch", None), "target": ("batch",),
                    "profile": ("batch", None)}
        else:
            batch = {"sparse_idx": _sds((b, cfg.n_fields), jnp.int32)}
            axes = {"sparse_idx": ("batch", None)}
        if spec["kind"] == "train":
            batch["labels"] = _sds((b,), jnp.float32)
            axes["labels"] = ("batch",)
        return batch, axes

    def build_cell(self, shape_name, mesh=None) -> Cell:
        spec = self.shapes[shape_name]
        cfg = self.cfg
        batch, axes = self._batch_specs(spec)
        rules = self.axis_rules()

        if spec["kind"] == "train":
            bce = BCELoss()

            def loss_fn(params, b, ctx):
                logits = recsys_lib.forward(cfg, params, b, mesh)
                return bce(logits, b["labels"])

            return make_train_cell(
                self.name, shape_name, loss_fn=loss_fn,
                abstract_params=self.abstract_params(),
                param_axes=self.param_logical_axes(), batch_specs=batch,
                batch_axes=axes, rules=rules, mesh=mesh,
                optimizer="adamw", notes="CTR BCE step (fwd+bwd+adamw)")

        if spec["kind"] == "serve":
            def serve_fn(params, b):
                return jax.nn.sigmoid(recsys_lib.forward(cfg, params, b,
                                                         mesh))
            return make_infer_cell(
                self.name, shape_name, "serve", serve_fn,
                self.abstract_params(), self.param_logical_axes(), batch,
                axes, rules, mesh, notes="online/bulk scoring")

        topk = spec["topk"]

        def retrieval_fn(params, b):
            scores = recsys_lib.retrieval_scores(cfg, params, b, mesh)
            vals, idx = jax.lax.top_k(scores, topk)
            return vals, jnp.take(b["cand_idx"], idx)

        return make_infer_cell(
            self.name, shape_name, "retrieval", retrieval_fn,
            self.abstract_params(), self.param_logical_axes(), batch, axes,
            rules, mesh,
            notes=f"1 user x {spec['n_candidates']} candidates, top-{topk}"
                  " (FastResultHeapq scenario)")

    def reduced(self) -> "RecSysArch":
        cfg = self.cfg
        n_small = max(4, min(cfg.n_fields, 6))
        small = dataclasses.replace(
            cfg, vocab_sizes=(64,) * n_small, embed_dim=8,
            mlp_dims=(32, 16), seq_len=min(cfg.seq_len, 6),
            n_profile_fields=min(cfg.n_profile_fields, 3),
            n_attn_layers=min(cfg.n_attn_layers, 2), d_attn=8)
        shapes = {
            "train_batch": dict(kind="train", batch=32),
            "serve_p99": dict(kind="serve", batch=8),
            "serve_bulk": dict(kind="serve", batch=64),
            "retrieval_cand": dict(kind="retrieval", batch=1,
                                   n_candidates=256, topk=8),
        }
        return RecSysArch(small, shapes=shapes)

    def smoke_inputs(self, shape_name, rng: np.random.Generator):
        spec = self.shapes[shape_name]
        batch, _ = self._batch_specs(spec)
        cfg = self.cfg
        offs = recsys_lib.field_offsets(cfg.vocab_sizes)
        sizes = np.asarray(cfg.vocab_sizes)

        def field_ids(n_rows, fields):
            cols = []
            for f in fields:
                cols.append(offs[f] + rng.integers(0, sizes[f], n_rows))
            return jnp.asarray(np.stack(cols, 1), jnp.int32)

        out = {}
        for k, s in batch.items():
            if k == "labels":
                out[k] = jnp.asarray(rng.integers(0, 2, s.shape), jnp.float32)
            elif k == "sparse_idx":
                out[k] = field_ids(s.shape[0], range(cfg.n_fields))
            elif k == "user_idx":
                out[k] = field_ids(1, range(1, cfg.n_fields))
            elif k in ("cand_idx", "target"):
                out[k] = jnp.asarray(
                    offs[0] + rng.integers(0, sizes[0], s.shape), jnp.int32)
            elif k == "hist":
                out[k] = jnp.asarray(
                    offs[0] + rng.integers(0, sizes[0], s.shape), jnp.int32)
            elif k == "profile":
                nf = s.shape[1]
                out[k] = field_ids(s.shape[0],
                                   range(1, 1 + nf))
            else:
                raise KeyError(k)
        return out
