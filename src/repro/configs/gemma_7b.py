"""gemma-7b: 28L d=3072 16H (GQA kv=16) d_ff=24576 vocab=256000, GeGLU,
head_dim=256 [arXiv:2403.08295; hf]."""
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig


def get_arch() -> LMArch:
    return LMArch(LMConfig(
        name="gemma-7b", n_layers=28, d_model=3072, n_heads=16,
        n_kv_heads=16, head_dim=256, d_ff=24576, vocab_size=256000,
        activation="geglu", norm="rmsnorm", rope_theta=10000.0,
        pooling="last", dtype=jnp.bfloat16, attn_chunk=4096, remat=True,
        scan_layers=False, seq_shard_acts=True))
