"""granite-moe-3b-a800m: 32L d=1536 24H (GQA kv=8) expert d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite; hf].

40 experts are not divisible by the 16-way model axis: the divisibility
guard shards each expert's FFN dim instead ("expert_ffn" -> model); see
DESIGN.md §Arch-applicability.
"""
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig


def get_arch() -> LMArch:
    return LMArch(LMConfig(
        name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
        n_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
        activation="swiglu", norm="rmsnorm", moe=True, n_experts=40,
        top_k=8, moe_every=1, moe_d_ff=512, capacity_factor=1.25,
        pooling="last", dtype=jnp.bfloat16, attn_chunk=4096, remat=True,
        scan_layers=False, seq_shard_acts=True, seq_shard_attn=True))
