"""autoint: 39 sparse fields, embed_dim=16, 3 self-attn layers,
2 heads, d_attn=32 [arXiv:1810.11921]."""
from repro.configs.base import RecSysArch
from repro.models.recsys import RecSysConfig

# criteo-like 39-field layout, ~33.6M total rows
_VOCABS = ((2**24, 2**23, 2**22, 2**22) + (2**16,) * 10 + (2**12,) * 25)


def get_arch() -> RecSysArch:
    return RecSysArch(RecSysConfig(
        name="autoint", kind="autoint", vocab_sizes=_VOCABS, embed_dim=16,
        n_attn_layers=3, n_heads=2, d_attn=32))
