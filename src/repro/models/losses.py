"""Retrieval loss registry (paper §3.3).

Losses subclass :class:`RetrievalLoss` and self-register under ``_alias``
(the paper's customization mechanism: ``--loss=ws`` etc.).  All losses
consume ``scores (Q, P)`` and ``labels``:

  * integer labels ``(Q,)``   — index of the positive (InfoNCE/binary data)
  * graded labels ``(Q, P)``  — multi-level relevance (MultiLevelDataset)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LOSS_REGISTRY: dict[str, type["RetrievalLoss"]] = {}


class RetrievalLoss:
    _alias: str = ""

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if cls._alias:
            LOSS_REGISTRY[cls._alias] = cls

    def __call__(self, scores: jax.Array, labels: jax.Array) -> jax.Array:
        raise NotImplementedError


def get_loss(alias_or_obj) -> RetrievalLoss:
    if isinstance(alias_or_obj, RetrievalLoss):
        return alias_or_obj
    if isinstance(alias_or_obj, str):
        return LOSS_REGISTRY[alias_or_obj]()
    if callable(alias_or_obj):          # arbitrary user callable
        return alias_or_obj
    raise TypeError(alias_or_obj)


def _graded_target(labels: jax.Array) -> jax.Array:
    """Normalize graded labels (Q,P) to a target distribution."""
    lab = labels.astype(jnp.float32)
    mask = lab >= 0                      # -1 == padding
    w = jnp.where(mask, lab, 0.0)
    z = jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    return w / z, mask


class InfoNCELoss(RetrievalLoss):
    """Softmax cross-entropy against the positive index (DPR/Karpukhin)."""

    _alias = "infonce"

    def __call__(self, scores, labels):
        if labels.ndim == 1:
            logz = jax.nn.logsumexp(scores, axis=-1)
            pos = jnp.take_along_axis(scores, labels[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - pos)
        # graded: treat every doc with max grade as positive (multi-positive CE)
        tgt, mask = _graded_target(labels)
        logp = jax.nn.log_softmax(
            jnp.where(mask, scores, -1e30), axis=-1)
        return -jnp.mean(jnp.sum(tgt * logp, axis=-1))


class KLDivergenceLoss(RetrievalLoss):
    """KL(target || softmax(scores)) for graded labels (distillation)."""

    _alias = "kl"

    def __call__(self, scores, labels):
        assert labels.ndim == 2, "KL loss needs graded (Q,P) labels"
        tgt, mask = _graded_target(labels)
        logp = jax.nn.log_softmax(jnp.where(mask, scores, -1e30), axis=-1)
        logt = jnp.log(jnp.clip(tgt, 1e-9))
        kl = jnp.sum(jnp.where(tgt > 0, tgt * (logt - logp), 0.0), axis=-1)
        return jnp.mean(kl)


class WassersteinLoss(RetrievalLoss):
    """1-D W1 between score distribution and label distribution (SyCL §4.1).

    Candidates are a discrete support; W1 = sum |CDF_p - CDF_q| over the
    label-sorted candidate axis.
    """

    _alias = "ws"

    def __call__(self, scores, labels):
        assert labels.ndim == 2
        tgt, mask = _graded_target(labels)
        order = jnp.argsort(-labels, axis=-1)
        p = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
        p_s = jnp.take_along_axis(p, order, axis=-1)
        q_s = jnp.take_along_axis(tgt, order, axis=-1)
        w1 = jnp.sum(jnp.abs(jnp.cumsum(p_s - q_s, axis=-1)), axis=-1)
        return jnp.mean(w1)


class ListNetLoss(RetrievalLoss):
    """Cross entropy between label softmax and score softmax."""

    _alias = "listnet"

    def __call__(self, scores, labels):
        assert labels.ndim == 2
        mask = labels >= 0
        tgt = jax.nn.softmax(
            jnp.where(mask, labels.astype(jnp.float32), -1e30), axis=-1)
        logp = jax.nn.log_softmax(jnp.where(mask, scores, -1e30), axis=-1)
        return -jnp.mean(jnp.sum(tgt * logp, axis=-1))


class BCELoss(RetrievalLoss):
    """Pointwise sigmoid BCE (recsys CTR training)."""

    _alias = "bce"

    def __call__(self, scores, labels):
        lab = labels.astype(jnp.float32)
        return jnp.mean(
            jnp.maximum(scores, 0) - scores * lab
            + jnp.log1p(jnp.exp(-jnp.abs(scores))))


def biencoder_scores(q_emb: jax.Array, p_emb: jax.Array,
                     temperature: float = 0.02) -> jax.Array:
    """Global in-batch similarity (Q, P_total).

    Written over the *global* batch: under pjit the all-gather of passage
    embeddings across ("pod","data") is inserted by SPMD — this is the
    paper's cross-device in-batch negatives with O(B·d) wire bytes.
    """
    return jnp.einsum("qd,pd->qp", q_emb, p_emb) / temperature
