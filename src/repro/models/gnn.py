"""GraphSAGE encoder (arXiv:1706.02216) for graph retrieval.

Three execution modes matching the assigned input shapes:
  * full-graph      — whole (N, F) feature matrix + edge list; message
                      passing via ``jax.ops.segment_sum`` (JAX has no CSR
                      SpMM; the scatter-based edge aggregation IS the system).
  * minibatch       — fixed-fanout dense tensors produced by the *real*
                      neighbor sampler in ``repro.data.graph`` (GraphSAGE's
                      sampled training regime; TPU-friendly: no ragged).
  * batched-graphs  — (G, n, F) small molecules, per-graph edge lists with
                      masks; graph embedding = masked mean pool.

The unsupervised GraphSAGE objective (positive co-occurrence pairs +
in-batch negatives) is literally a retrieval contrastive loss, so node
embeddings trained here plug straight into the Trove evaluator.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_feat: int = 64
    d_hidden: int = 128
    aggregator: str = "mean"          # mean | max
    fanouts: tuple[int, ...] = (25, 10)
    dtype: Any = jnp.float32
    normalize: bool = True


def abstract_params(cfg: SAGEConfig) -> Params:
    p: Params = {}
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        p[f"w_self_{i}"] = jax.ShapeDtypeStruct((d_in, cfg.d_hidden), cfg.dtype)
        p[f"w_neigh_{i}"] = jax.ShapeDtypeStruct((d_in, cfg.d_hidden), cfg.dtype)
        p[f"b_{i}"] = jax.ShapeDtypeStruct((cfg.d_hidden,), cfg.dtype)
        d_in = cfg.d_hidden
    return p


def param_logical_axes(cfg: SAGEConfig) -> Params:
    # GNN weights are tiny (<1 MB): replicate.
    return {k: (None,) * len(v.shape) for k, v in abstract_params(cfg).items()}


def init_params(cfg: SAGEConfig, rng: jax.Array) -> Params:
    ab = abstract_params(cfg)
    keys = jax.random.split(rng, len(ab))
    out = {}
    for key, (name, leaf) in zip(keys, sorted(ab.items())):
        if name.startswith("b_"):
            out[name] = jnp.zeros(leaf.shape, leaf.dtype)
        else:
            fan_in = leaf.shape[0]
            out[name] = (jax.random.normal(key, leaf.shape, jnp.float32)
                         / np.sqrt(fan_in)).astype(leaf.dtype)
    return out


def _agg(cfg: SAGEConfig, msgs: jax.Array, seg: jax.Array, n: int,
         counts: jax.Array | None = None) -> jax.Array:
    if cfg.aggregator == "max":
        return jax.ops.segment_max(msgs, seg, num_segments=n)
    s = jax.ops.segment_sum(msgs, seg, num_segments=n)
    if counts is None:
        counts = jax.ops.segment_sum(
            jnp.ones((msgs.shape[0],), msgs.dtype), seg, num_segments=n)
    return s / jnp.clip(counts, 1.0)[..., None]


def _maybe_norm(cfg: SAGEConfig, h: jax.Array) -> jax.Array:
    if not cfg.normalize:
        return h
    hf = h.astype(jnp.float32)
    return (hf / jnp.clip(jnp.linalg.norm(hf, axis=-1, keepdims=True), 1e-9)
            ).astype(h.dtype)


def forward_full(cfg: SAGEConfig, params: Params, x: jax.Array,
                 edge_src: jax.Array, edge_dst: jax.Array) -> jax.Array:
    """Full-batch message passing.  x (N,F); edges (E,) src->dst."""
    n = x.shape[0]
    h = x.astype(cfg.dtype)
    for i in range(cfg.n_layers):
        msgs = jnp.take(h, edge_src, axis=0)
        neigh = _agg(cfg, msgs, edge_dst, n)
        h = jax.nn.relu(h @ params[f"w_self_{i}"]
                        + neigh @ params[f"w_neigh_{i}"] + params[f"b_{i}"])
    return _maybe_norm(cfg, h)


def forward_minibatch(cfg: SAGEConfig, params: Params, feats0: jax.Array,
                      feats1: jax.Array, feats2: jax.Array) -> jax.Array:
    """Fixed-fanout 2-layer SAGE.

    feats0 (B,F) targets; feats1 (B,f1,F) 1-hop; feats2 (B,f1,f2,F) 2-hop.
    """
    assert cfg.n_layers == 2

    def layer(i, h_self, h_neigh_mean):
        return jax.nn.relu(
            h_self @ params[f"w_self_{i}"]
            + h_neigh_mean @ params[f"w_neigh_{i}"] + params[f"b_{i}"])

    reducer = (jnp.max if cfg.aggregator == "max" else jnp.mean)
    h1 = layer(0, feats1, reducer(feats2, axis=2))          # (B,f1,d)
    h0 = layer(0, feats0, reducer(feats1, axis=1))          # (B,d)
    z = layer(1, h0, reducer(h1, axis=1))                   # (B,d)
    return _maybe_norm(cfg, z)


def forward_batched_graphs(cfg: SAGEConfig, params: Params, x: jax.Array,
                           edges: jax.Array, edge_mask: jax.Array,
                           node_mask: jax.Array) -> jax.Array:
    """Batched small graphs.  x (G,n,F), edges (G,m,2), masks -> (G,d)."""
    g, n, _ = x.shape

    def one_graph(xg, eg, emg):
        h = xg.astype(cfg.dtype)
        src, dst = eg[:, 0], eg[:, 1]
        for i in range(cfg.n_layers):
            msgs = jnp.take(h, src, axis=0) * emg[:, None].astype(h.dtype)
            neigh = _agg(cfg, msgs, dst, n,
                         counts=jax.ops.segment_sum(
                             emg.astype(h.dtype), dst, num_segments=n))
            h = jax.nn.relu(h @ params[f"w_self_{i}"]
                            + neigh @ params[f"w_neigh_{i}"]
                            + params[f"b_{i}"])
        return h

    h = jax.vmap(one_graph)(x, edges, edge_mask)            # (G,n,d)
    w = node_mask.astype(h.dtype)[..., None]
    pooled = (h * w).sum(1) / jnp.clip(w.sum(1), 1.0)
    return _maybe_norm(cfg, pooled)
