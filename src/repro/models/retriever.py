"""Retriever = encoder + loss + retrieval logic (paper §3.3).

``BiEncoderRetriever`` implements the dual-encoder logic with cross-device
in-batch negatives: the loss is written over the *global* batch, so under
pjit the passage-embedding all-gather across ("pod","data") is inserted by
SPMD — no manual torch.distributed-style gather.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.config import ModelArguments
from repro.models.encoder import PretrainedEncoder, get_encoder
from repro.models.losses import biencoder_scores, get_loss

RETRIEVER_REGISTRY: dict[str, type["PretrainedRetriever"]] = {}


class PretrainedRetriever:
    _alias = ""

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if cls._alias:
            RETRIEVER_REGISTRY[cls._alias] = cls

    def __init__(self, encoder: PretrainedEncoder, loss, temperature=0.02,
                 aux_loss_weight: float = 0.0):
        self.encoder = encoder
        self.loss = get_loss(loss)
        self.temperature = temperature
        self.aux_loss_weight = aux_loss_weight

    @classmethod
    def from_model_args(cls, model_args: ModelArguments, encoder_cfg,
                        encoder: PretrainedEncoder | None = None):
        """Build retriever from argument objects (paper workflow).

        ``encoder`` may be any user object with the encoder duck-type
        (paper: arbitrary nn.Module as encoder)."""
        enc = encoder or get_encoder(model_args.encoder_class, encoder_cfg)
        return cls(enc, model_args.loss, model_args.temperature)

    # param plumbing delegates to the encoder
    def init_params(self, rng):
        return self.encoder.init_params(rng)

    def abstract_params(self):
        return self.encoder.abstract_params()

    def param_logical_axes(self):
        return self.encoder.param_logical_axes()

    def format_query(self, text):
        return self.encoder.format_query(text)

    def format_passage(self, text, title=""):
        return self.encoder.format_passage(text, title)

    def forward(self, params, batch, ctx=None):
        raise NotImplementedError


class BiEncoderRetriever(PretrainedRetriever):
    _alias = "biencoder"

    def encode_query(self, params, batch, ctx=None):
        return self.encoder.encode(params, batch, ctx)

    def encode_passage(self, params, batch, ctx=None):
        return self.encoder.encode(params, batch, ctx)

    def forward(self, params, batch, ctx=None):
        """batch: {"query": {...}, "passage": {...}, optional "labels"}.

        Passages are ordered [q0_docs..., q1_docs...] with ``group_size``
        docs per query; labels default to "first doc in group is positive".
        Returns (loss, metrics dict).
        """
        aux = None
        if self.aux_loss_weight and hasattr(self.encoder, "encode_with_aux"):
            q_emb, aux_q = self.encoder.encode_with_aux(
                params, batch["query"], ctx)
            p_emb, aux_p = self.encoder.encode_with_aux(
                params, batch["passage"], ctx)
            aux = aux_q + aux_p
        else:
            q_emb = self.encode_query(params, batch["query"], ctx)
            p_emb = self.encode_passage(params, batch["passage"], ctx)
        nq = q_emb.shape[0]
        group = p_emb.shape[0] // nq
        scores = biencoder_scores(q_emb, p_emb, self.temperature)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.arange(nq, dtype=jnp.int32) * group
        loss = self.loss(scores, labels)
        metrics = {"contrastive_loss": loss}
        if aux is not None:
            loss = loss + self.aux_loss_weight * aux
            metrics["moe_aux_loss"] = aux
        if labels.ndim == 1:
            acc = jnp.mean(
                (jnp.argmax(scores, -1) == labels).astype(jnp.float32))
            metrics["in_batch_accuracy"] = acc
        return loss, metrics


class GradedBiEncoderRetriever(BiEncoderRetriever):
    """Multi-level relevance training (MultiLevelDataset): each query sees
    only its own group of graded docs — the score matrix is masked to the
    group diagonal blocks and the graded loss (kl/ws/listnet) is applied."""

    _alias = "graded_biencoder"

    def forward(self, params, batch, ctx=None):
        q_emb = self.encode_query(params, batch["query"], ctx)
        p_emb = self.encode_passage(params, batch["passage"], ctx)
        nq = q_emb.shape[0]
        group = p_emb.shape[0] // nq
        p_grp = p_emb.reshape(nq, group, -1)
        scores = jnp.einsum("qd,qgd->qg", q_emb, p_grp) / self.temperature
        loss = self.loss(scores, batch["labels"])
        return loss, {"graded_loss": loss}


def make_train_loss_fn(retriever: PretrainedRetriever,
                       ctx=None) -> Callable[..., Any]:
    """(params, batch) -> (loss, metrics) — consumed by RetrievalTrainer."""

    def loss_fn(params, batch):
        return retriever.forward(params, batch, ctx)

    return loss_fn
