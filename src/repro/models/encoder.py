"""Encoder wrappers + registry (paper §3.3 / Appendix B).

An encoder wrapper bundles:
  * an ``encode(params, batch) -> (B, d) embeddings`` pure function,
  * input formatting callbacks (``format_query`` / ``format_passage``),
  * parameter construction + logical sharding axes.

Subclasses self-register under ``_alias`` so experiments swap encoders via
``--encoder_class=...`` without code changes; arbitrary user objects with
the same duck-type also work (paper: "users can use arbitrary nn.Module
objects as the encoder").
"""

from __future__ import annotations

from typing import Any

import jax

from repro.models import gnn, transformer
from repro.sharding.partitioning import AxisRules

ENCODER_REGISTRY: dict[str, type["PretrainedEncoder"]] = {}


class PretrainedEncoder:
    _alias = ""

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if cls._alias:
            ENCODER_REGISTRY[cls._alias] = cls

    # --- model surface -----------------------------------------------------
    def init_params(self, rng: jax.Array):
        raise NotImplementedError

    def abstract_params(self):
        raise NotImplementedError

    def param_logical_axes(self):
        raise NotImplementedError

    def axis_rules(self) -> AxisRules:
        return AxisRules()

    def encode(self, params, batch: dict[str, jax.Array], ctx=None):
        """batch -> (B, d) L2-normalized embeddings."""
        raise NotImplementedError

    # --- input formatting (paper Appendix B: instruction prompts etc.) -----
    def format_query(self, text: str) -> str:
        return text

    def format_passage(self, text: str, title: str = "") -> str:
        return f"{title} {text}".strip() if title else text


def get_encoder(alias: str, *args, **kw) -> PretrainedEncoder:
    return ENCODER_REGISTRY[alias](*args, **kw)


class DefaultEncoder(PretrainedEncoder):
    """LM-transformer encoder (dense or MoE backbone)."""

    _alias = "lm"

    def __init__(self, cfg: transformer.LMConfig):
        self.cfg = cfg

    def init_params(self, rng):
        return transformer.init_params(self.cfg, rng)

    def abstract_params(self):
        return transformer.abstract_params(self.cfg)

    def param_logical_axes(self):
        return transformer.param_logical_axes(self.cfg)

    def axis_rules(self):
        return transformer.LM_RULES

    def encode(self, params, batch, ctx=None):
        return transformer.encode(
            self.cfg, params, batch["tokens"], batch["mask"], ctx)

    def encode_with_aux(self, params, batch, ctx=None):
        """(embeddings, aux loss) — MoE backbones return the load-balance
        loss so the trainer can weight it in."""
        hidden, aux = transformer.forward_hidden(
            self.cfg, params, batch["tokens"], batch["mask"], ctx)
        return transformer.pool(self.cfg, hidden, batch["mask"]), aux


class EncoderWithInstruction(DefaultEncoder):
    """Paper Appendix B example: E5-Mistral-style instruction formatting."""

    _alias = "encoder_with_inst"

    instruction = "Given a web search query, retrieve relevant passages"

    def format_query(self, text: str) -> str:
        return f"Instruct: {self.instruction}\nQuery: {text}"


class MeanPoolEncoder(DefaultEncoder):
    """Paper Appendix B example: overriding the pooling method."""

    _alias = "encoder_mean_pool"

    def __init__(self, cfg: transformer.LMConfig):
        super().__init__(
            cfg if cfg.pooling == "mean"
            else cfg.__class__(**{**cfg.__dict__, "pooling": "mean"}))


class GNNEncoder(PretrainedEncoder):
    """GraphSAGE node/graph encoder for graph retrieval."""

    _alias = "gnn"

    def __init__(self, cfg: gnn.SAGEConfig):
        self.cfg = cfg

    def init_params(self, rng):
        return gnn.init_params(self.cfg, rng)

    def abstract_params(self):
        return gnn.abstract_params(self.cfg)

    def param_logical_axes(self):
        return gnn.param_logical_axes(self.cfg)

    def encode(self, params, batch, ctx=None):
        if "feats2" in batch:
            return gnn.forward_minibatch(
                self.cfg, params, batch["feats0"], batch["feats1"],
                batch["feats2"])
        if "node_mask" in batch:
            return gnn.forward_batched_graphs(
                self.cfg, params, batch["x"], batch["edges"],
                batch["edge_mask"], batch["node_mask"])
        return gnn.forward_full(
            self.cfg, params, batch["x"], batch["edge_src"],
            batch["edge_dst"])
