"""RecSys rankers: BST, AutoInt, DeepFM, Wide&Deep.

Substrate built here per spec (JAX has no native EmbeddingBag / CSR):
  * hashed mega-embedding-table: all sparse fields share one row-sharded
    (sum_vocab, dim) table, addressed by per-field offsets — the row dim
    carries the "embed_rows" logical axis (-> "model" mesh axis).
  * ``embedding_bag`` = ``jnp.take`` + ``jax.ops.segment_sum``.
  * two lookup impls:  "xla_gather" (baseline — SPMD decides the
    collective) and "psum" (shard_map: each shard gathers its local rows
    with OOB masking, then psums partials — O(B*F*D) wire bytes instead of
    an O(V*D) table all-gather).  The psum impl is the §Perf hillclimb for
    the collective-bound recsys cells.

``retrieval_step`` scores ONE user against N candidates as a batched
forward (no loop) and returns top-k — the paper's FastResultHeapq
scenario (Table 3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Embedding substrate
# ---------------------------------------------------------------------------

def field_offsets(vocab_sizes: Sequence[int]) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int64)


def embedding_lookup(table: jax.Array, idx: jax.Array,
                     impl: str = "xla_gather", mesh=None,
                     table_axis: str = "model") -> jax.Array:
    """(V,D) x (...,) int32 -> (..., D)."""
    if impl == "xla_gather" or mesh is None or table_axis not in mesh.shape:
        return jnp.take(table, idx, axis=0)
    return _lookup_psum(table, idx, mesh, table_axis)


def _lookup_psum(table: jax.Array, idx: jax.Array, mesh, axis: str,
                 wire_dtype=jnp.bfloat16):
    """shard_map lookup: local gather + psum of masked partials.

    Exactly one shard contributes a non-zero row per id, so the psum in
    ``wire_dtype`` (bf16) is exact up to one rounding of the stored value
    — 2x less wire than fp32."""
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[axis]
    rows = table.shape[0] // n_shards
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def local(table_shard, idx_rep):
        shard_id = jax.lax.axis_index(axis)
        local_idx = idx_rep - shard_id * rows
        ok = (local_idx >= 0) & (local_idx < rows)
        safe = jnp.clip(local_idx, 0, rows - 1)
        part = jnp.take(table_shard, safe, axis=0)
        part = part * ok[..., None].astype(part.dtype)
        # optimization_barrier keeps XLA from folding the converts back
        # into an fp32 all-reduce (bf16 stays on the wire)
        wire = jax.lax.optimization_barrier(part.astype(wire_dtype))
        out = jax.lax.psum(wire, axis)
        return out.astype(table_shard.dtype)

    # idx (..., ): batch-sharded on dim 0 when divisible; output gains a
    # trailing embedding dim
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes \
        else 1
    dim0 = tuple(data_axes) if (data_axes and idx.ndim
                                and idx.shape[0] % dp == 0) else None
    idx_spec = P(dim0, *((None,) * (idx.ndim - 1)))
    out_spec = P(dim0, *((None,) * idx.ndim))
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), idx_spec),
        out_specs=out_spec,
        check_rep=False,
    )(table, idx)


def embedding_bag(table: jax.Array, idx: jax.Array, bag_ids: jax.Array,
                  n_bags: int, mode: str = "sum") -> jax.Array:
    """EmbeddingBag: gather rows for flat multi-hot ids, reduce per bag."""
    rows = jnp.take(table, idx, axis=0)
    s = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "sum":
        return s
    counts = jax.ops.segment_sum(
        jnp.ones_like(idx, rows.dtype), bag_ids, num_segments=n_bags)
    return s / jnp.clip(counts, 1.0)[..., None]


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str = "deepfm"
    kind: str = "deepfm"              # deepfm | autoint | wide_deep | bst
    vocab_sizes: tuple[int, ...] = (1024,) * 8
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    # autoint
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    # bst
    seq_len: int = 20
    n_profile_fields: int = 8
    bst_d_ff: int = 64
    dtype: Any = jnp.float32
    embedding_impl: str = "xla_gather"
    batch_full_shard: bool = False    # §Perf: reshard gathered embeddings
                                      # over (pod,data,model) so the MLP
                                      # uses the otherwise idle TP axis

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))


def _mlp_shapes(dims: Sequence[int]) -> dict[str, tuple[int, ...]]:
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"mlp_w{i}"] = (a, b)
        out[f"mlp_b{i}"] = (b,)
    return out


def abstract_params(cfg: RecSysConfig) -> Params:
    v, d = cfg.total_vocab, cfg.embed_dim
    shapes: dict[str, tuple[int, ...]] = {"table": (v, d)}
    if cfg.kind == "deepfm":
        shapes["linear_table"] = (v, 1)
        shapes["bias"] = (1,)
        shapes.update(_mlp_shapes(
            (cfg.n_fields * d,) + cfg.mlp_dims + (1,)))
    elif cfg.kind == "wide_deep":
        shapes["wide_table"] = (v, 1)
        shapes["bias"] = (1,)
        shapes.update(_mlp_shapes(
            (cfg.n_fields * d,) + cfg.mlp_dims + (1,)))
    elif cfg.kind == "autoint":
        d_in = d
        for i in range(cfg.n_attn_layers):
            dh = cfg.n_heads * cfg.d_attn
            shapes[f"attn{i}_wq"] = (d_in, dh)
            shapes[f"attn{i}_wk"] = (d_in, dh)
            shapes[f"attn{i}_wv"] = (d_in, dh)
            shapes[f"attn{i}_wres"] = (d_in, dh)
            d_in = dh
        shapes["out_w"] = (cfg.n_fields * d_in, 1)
        shapes["out_b"] = (1,)
    elif cfg.kind == "bst":
        s = cfg.seq_len + 1
        shapes["pos_emb"] = (s, d)
        for nm in ("wq", "wk", "wv", "wo"):
            shapes[f"attn_{nm}"] = (d, d)
        shapes["attn_ln1"] = (d,)
        shapes["attn_ln2"] = (d,)
        shapes["ffn_w1"] = (d, cfg.bst_d_ff)
        shapes["ffn_w2"] = (cfg.bst_d_ff, d)
        flat = s * d + cfg.n_profile_fields * d
        shapes.update(_mlp_shapes((flat,) + cfg.mlp_dims + (1,)))
    else:
        raise ValueError(cfg.kind)
    return {k: jax.ShapeDtypeStruct(s, cfg.dtype) for k, s in shapes.items()}


def param_logical_axes(cfg: RecSysConfig) -> Params:
    ab = abstract_params(cfg)
    out = {}
    for k, leaf in ab.items():
        if k in ("table", "linear_table", "wide_table"):
            out[k] = ("embed_rows",) + (None,) * (len(leaf.shape) - 1)
        else:
            out[k] = (None,) * len(leaf.shape)
    return out


def init_params(cfg: RecSysConfig, rng: jax.Array) -> Params:
    ab = abstract_params(cfg)
    keys = jax.random.split(rng, len(ab))
    out = {}
    for key, (name, leaf) in zip(keys, sorted(ab.items())):
        if name.endswith(("_b", "bias")) or name.startswith(("attn_ln",)):
            base = (jnp.ones if name.startswith("attn_ln") else jnp.zeros)
            out[name] = base(leaf.shape, leaf.dtype)
        else:
            fan_in = leaf.shape[0] if len(leaf.shape) > 1 else 1
            out[name] = (jax.random.normal(key, leaf.shape, jnp.float32)
                         * (0.01 if "table" in name else 1 / np.sqrt(fan_in))
                         ).astype(leaf.dtype)
    return out


# ---------------------------------------------------------------------------
# Forward passes (logit per example)
# ---------------------------------------------------------------------------

def _mlp(params: Params, x: jax.Array, n: int) -> jax.Array:
    for i in range(n):
        x = x @ params[f"mlp_w{i}"] + params[f"mlp_b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def _n_mlp(cfg: RecSysConfig) -> int:
    return len(cfg.mlp_dims) + 1


def forward(cfg: RecSysConfig, params: Params, batch: dict[str, jax.Array],
            mesh=None) -> jax.Array:
    """Returns logits (B,)."""
    lookup = lambda tbl, idx: embedding_lookup(
        tbl, idx, cfg.embedding_impl, mesh)
    if cfg.kind == "bst":
        return _forward_bst(cfg, params, batch, lookup)
    idx = batch["sparse_idx"]                              # (B, F) global ids
    emb = lookup(params["table"], idx)                     # (B, F, D)
    emb = _maybe_full_shard(cfg, emb, mesh)
    b = idx.shape[0]
    if cfg.kind == "deepfm":
        lin = lookup(params["linear_table"], idx)[..., 0].sum(-1)
        sum_v = emb.sum(1)
        fm = 0.5 * ((sum_v * sum_v) - (emb * emb).sum(1)).sum(-1)
        deep = _mlp(params, emb.reshape(b, -1), _n_mlp(cfg))[:, 0]
        return lin + fm + deep + params["bias"][0]
    if cfg.kind == "wide_deep":
        wide = lookup(params["wide_table"], idx)[..., 0].sum(-1)
        deep = _mlp(params, emb.reshape(b, -1), _n_mlp(cfg))[:, 0]
        return wide + deep + params["bias"][0]
    if cfg.kind == "autoint":
        h = emb
        for i in range(cfg.n_attn_layers):
            q = h @ params[f"attn{i}_wq"]
            k = h @ params[f"attn{i}_wk"]
            v = h @ params[f"attn{i}_wv"]
            nh, da = cfg.n_heads, cfg.d_attn
            split = lambda t: t.reshape(b, -1, nh, da)
            scores = jnp.einsum("bfhd,bghd->bhfg", split(q), split(k))
            scores = scores / np.sqrt(da)
            attn = jax.nn.softmax(scores, -1)
            o = jnp.einsum("bhfg,bghd->bfhd", attn, split(v))
            o = o.reshape(b, h.shape[1], nh * da)
            h = jax.nn.relu(o + h @ params[f"attn{i}_wres"])
        return (h.reshape(b, -1) @ params["out_w"])[:, 0] + params["out_b"][0]
    raise ValueError(cfg.kind)


def _forward_bst(cfg: RecSysConfig, params: Params,
                 batch: dict[str, jax.Array], lookup) -> jax.Array:
    hist, target = batch["hist"], batch["target"]          # (B,S), (B,)
    profile = batch["profile"]                             # (B,P) global ids
    b, s = hist.shape
    seq = jnp.concatenate([hist, target[:, None]], axis=1)  # (B,S+1)
    e = lookup(params["table"], seq) + params["pos_emb"][None]
    # one transformer block (post-LN per BST paper)
    d = cfg.embed_dim
    nh = 8
    hd = d // nh
    q = (e @ params["attn_wq"]).reshape(b, s + 1, nh, hd)
    k = (e @ params["attn_wk"]).reshape(b, s + 1, nh, hd)
    v = (e @ params["attn_wv"]).reshape(b, s + 1, nh, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    o = o.reshape(b, s + 1, d) @ params["attn_wo"]
    h = _ln(e + o, params["attn_ln1"])
    f = jax.nn.relu(h @ params["ffn_w1"]) @ params["ffn_w2"]
    h = _ln(h + f, params["attn_ln2"])
    prof = lookup(params["table"], profile)                # (B,P,D)
    flat = jnp.concatenate([h.reshape(b, -1), prof.reshape(b, -1)], axis=-1)
    return _mlp(params, flat, _n_mlp(cfg))[:, 0]


def _maybe_full_shard(cfg: RecSysConfig, x: jax.Array, mesh):
    """§Perf: shard dim 0 over every mesh axis (bulk scoring/retrieval:
    the model axis would otherwise idle through the MLP)."""
    if not cfg.batch_full_shard or mesh is None:
        return x
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    if not axes or x.shape[0] % n:
        return x
    spec = P(axes, *((None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _ln(x, scale, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale


# ---------------------------------------------------------------------------
# Retrieval scoring: 1 user x N candidates (paper Table 3 scenario)
# ---------------------------------------------------------------------------

def retrieval_scores(cfg: RecSysConfig, params: Params,
                     batch: dict[str, jax.Array], mesh=None) -> jax.Array:
    """Batched-dot scoring of one user against (N,) candidate item ids.

    The candidate item id replaces field 0 (non-BST) / the target item
    (BST); user context is broadcast.  Returns scores (N,).
    """
    cands = batch["cand_idx"]                              # (N,)
    n = cands.shape[0]
    if cfg.kind == "bst":
        big = {
            "hist": jnp.broadcast_to(batch["hist"], (n, cfg.seq_len)),
            "target": cands,
            "profile": jnp.broadcast_to(
                batch["profile"], (n, batch["profile"].shape[-1])),
        }
        return forward(cfg, params, big, mesh)
    user = batch["user_idx"]                               # (1, F-1)
    idx = jnp.concatenate(
        [cands[:, None],
         jnp.broadcast_to(user, (n, user.shape[-1]))], axis=1)
    return forward(cfg, params, {"sparse_idx": idx}, mesh)
