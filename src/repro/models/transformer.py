"""LM transformer backbone for retrieval encoders (dense + MoE).

Design notes (see DESIGN.md §3-§5):
  * decoder-style (causal) transformer; retrieval embeddings via pooled
    hidden states (RepLLaMA-style last-token pooling by default).
  * weights stored 4D/stacked-over-layers so a single ``lax.scan`` runs the
    whole stack: compact HLO (fast 512-way SPMD compiles) and natural remat.
  * GQA attention with RoPE; GLU FFNs (GeGLU/SwiGLU); optional QKV biases.
  * MoE: token-choice top-k with per-row capacity, gather-based dispatch and
    combine (no one-hot einsum dispatch: dispatch FLOPs are O(tokens), not
    O(tokens x E x C)).  Interleaved dense/MoE stacks supported (Llama-4).
  * sharding: logical axes resolved by repro.sharding.partitioning.
    FSDP: the d_model dim of all weight matrices is sharded over the
    data-parallel axes ("pod","data"); TP: heads / ffn / experts over
    "model"; divisibility guard falls back to replication.
  * KV-cache decode for serving; cache seq dim shardable ("kv_seq") for
    long-context decode.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.partitioning import AxisRules

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 16
    d_ff: int = 128
    vocab_size: int = 1024
    activation: str = "swiglu"      # swiglu | geglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = True
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1              # 1: every layer MoE; 2: interleaved dense/MoE
    n_shared_experts: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    capacity_factor: float = 1.25
    # misc
    rope_theta: float = 10000.0
    max_seq_len: int = 8192
    pooling: str = "last"           # last | mean | first
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 0             # >0: chunked (memory-bounded) attention
    remat: bool = True
    logit_softcap: float = 0.0
    scan_layers: bool = True        # False: unrolled (exact HLO cost/roofline)
    seq_shard_attn: bool = False    # SP: shard scores' Sq dim over "model"
                                    # (for head counts not divisible by TP)
    seq_shard_acts: bool = False    # Megatron-SP: residual stream between
                                    # layers kept seq-sharded over "model"
                                    # (remat-saved activations shrink TP-fold)
    inline_mask: bool = False       # §Perf: build the causal mask inside the
                                    # attention fusion from 1-D position
                                    # vectors instead of materializing and
                                    # distributing a (B,S,S) bool tensor
    dus_cache_update: bool = False  # §Perf: decode writes the new K/V with
                                    # dynamic_update_slice instead of a
                                    # full-cache where-rewrite
    moe_impl: str = "pjit"          # §Perf: "shardmap" shards the capacity
                                    # dim over "model" with replicated expert
                                    # weights, combines locally and psums a
                                    # (B,S,d) partial — removes the
                                    # (B,E,cap,·) buffer all-reduces of the
                                    # per-expert-FFN TP sharding

    @property
    def n_dense_layers(self) -> int:
        if not self.moe:
            return self.n_layers
        if self.moe_every == 1:
            return 0
        return self.n_layers // 2

    @property
    def n_moe_layers(self) -> int:
        if not self.moe:
            return 0
        if self.moe_every == 1:
            return self.n_layers
        return self.n_layers - self.n_dense_layers

    def param_count(self) -> int:
        leaves = jax.tree.leaves(abstract_params(self))
        return int(sum(np.prod(l.shape) for l in leaves))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        total = self.param_count()
        if not self.moe:
            return total
        per_expert = 3 * self.d_model * self.moe_d_ff
        inactive = self.n_moe_layers * per_expert * (
            self.n_experts - self.top_k)
        return total - inactive


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: LMConfig) -> dict[str, tuple[int, ...]]:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    shapes = {
        "wq": (d, h, hd), "wk": (d, k, hd), "wv": (d, k, hd),
        "wo": (h, hd, d), "ln1": (d,), "ln2": (d,),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (h, hd), "bk": (k, hd), "bv": (k, hd)})
    if cfg.norm == "layernorm":
        shapes.update({"ln1_b": (d,), "ln2_b": (d,)})
    return shapes


def _dense_ffn_shapes(cfg: LMConfig) -> dict[str, tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation in ("swiglu", "geglu"):
        return {"wi_gate": (d, f), "wi_up": (d, f), "wo_ffn": (f, d)}
    return {"wi_up": (d, f), "wo_ffn": (f, d)}


def _moe_ffn_shapes(cfg: LMConfig) -> dict[str, tuple[int, ...]]:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    shapes = {
        "router": (d, e),
        "we_gate": (e, d, f), "we_up": (e, d, f), "we_down": (e, f, d),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        shapes.update({"ws_gate": (d, fs), "ws_up": (d, fs), "ws_down": (fs, d)})
    return shapes


_AXES = {
    # attention — d_model rows FSDP-sharded, heads TP-sharded
    "wq": ("fsdp", "heads", None), "wk": ("fsdp", "kv_heads", None),
    "wv": ("fsdp", "kv_heads", None), "wo": ("heads", None, "fsdp"),
    "bq": ("heads", None), "bk": ("kv_heads", None), "bv": ("kv_heads", None),
    "ln1": (None,), "ln2": (None,), "ln1_b": (None,), "ln2_b": (None,),
    # dense FFN
    "wi_gate": ("fsdp", "ffn"), "wi_up": ("fsdp", "ffn"),
    "wo_ffn": ("ffn", "fsdp"),
    # MoE
    "router": ("fsdp", None),
    "we_gate": ("experts", "fsdp", "expert_ffn"),
    "we_up": ("experts", "fsdp", "expert_ffn"),
    "we_down": ("experts", "expert_ffn", "fsdp"),
    "ws_gate": ("fsdp", "ffn"), "ws_up": ("fsdp", "ffn"),
    "ws_down": ("ffn", "fsdp"),
    # top level
    "embed": ("vocab", "embed"),
    "final_ln": (None,), "final_ln_b": (None,),
}

# FSDP rule: weight rows sharded over the data-parallel axes.
# seq_model: sequence-parallel attention dim (used when heads % TP != 0).
LM_RULES = AxisRules().with_overrides(fsdp=("pod", "data"),
                                      seq_model=("model",),
                                      kv_seq_full=("pod", "data", "model"))


def _block_shapes(cfg: LMConfig, kind: str) -> dict[str, tuple[int, ...]]:
    shapes = dict(_attn_shapes(cfg))
    shapes.update(_moe_ffn_shapes(cfg) if kind == "moe"
                  else _dense_ffn_shapes(cfg))
    return shapes


def _stack_layout(cfg: LMConfig) -> dict[str, int]:
    """Which stacked blocks exist and their depth."""
    layout: dict[str, int] = {}
    if cfg.n_dense_layers:
        layout["blocks"] = cfg.n_dense_layers
    if cfg.n_moe_layers:
        layout["moe_blocks"] = cfg.n_moe_layers
    return layout


def abstract_params(cfg: LMConfig) -> Params:
    p: Params = {
        "embed": jax.ShapeDtypeStruct(
            (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "final_ln": jax.ShapeDtypeStruct((cfg.d_model,), cfg.dtype),
    }
    if cfg.norm == "layernorm":
        p["final_ln_b"] = jax.ShapeDtypeStruct((cfg.d_model,), cfg.dtype)
    for stack, depth in _stack_layout(cfg).items():
        kind = "moe" if stack == "moe_blocks" else "dense"
        p[stack] = {
            k: jax.ShapeDtypeStruct((depth,) + shp, cfg.dtype)
            for k, shp in _block_shapes(cfg, kind).items()
        }
    return p


def param_logical_axes(cfg: LMConfig) -> Params:
    ab = abstract_params(cfg)

    def axes_for(path: tuple, leaf) -> tuple:
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        base = _AXES[key]
        if len(base) + 1 == len(leaf.shape):      # stacked over layers
            return ("layers",) + base
        return base

    return jax.tree_util.tree_map_with_path(axes_for, ab)


def init_params(cfg: LMConfig, rng: jax.Array) -> Params:
    ab = abstract_params(cfg)
    paths_leaves = jax.tree_util.tree_flatten_with_path(ab)[0]
    treedef = jax.tree.structure(ab)
    keys = jax.random.split(rng, len(paths_leaves))

    def init_leaf(key, path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name.startswith(("ln", "final_ln")) and not name.endswith("_b"):
            return jnp.ones(leaf.shape, leaf.dtype)        # norm scales
        if name.startswith("b") or name.endswith("_b"):
            return jnp.zeros(leaf.shape, leaf.dtype)       # biases
        scale = 0.02
        return (scale * jax.random.normal(key, leaf.shape, jnp.float32)
                ).astype(leaf.dtype)

    inited = [init_leaf(k, p, l) for k, (p, l) in zip(keys, paths_leaves)]
    return jax.tree.unflatten(treedef, inited)


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------

def _norm(x, scale, bias=None, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim), positions (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def _act(x, kind):
    if kind in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x)
    raise ValueError(kind)


def _attn_scores_softmax(q, k, v, mask, softcap=0.0, ctx=None, sp=False):
    """q: (B,Sq,H,hd)  k/v: (B,Skv,K,hd).

    ``mask`` is either a dense (B,Sq,Skv) bool tensor, or — §Perf inline
    variant — a ``(q_pos (B,Sq), kv_pos (B,Skv), kv_valid (B,Skv))`` tuple
    from which the causal mask is built inside the softmax fusion (no
    (B,S,S) tensor is materialized or distributed).

    The (B,K,G,Sq,Skv) score tensor is explicitly sharding-constrained:
    kv-heads over "model" when divisible, else the Sq dim (SP).  Relying
    on propagation from q is not enough — the batch-only-sharded mask in
    the ``where`` can win propagation and replicate the scores.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    group = h // kh
    score_axes = ("batch", "kv_heads", None,
                  "seq_model" if (sp and sq > 1) else None, None)
    qg = q.reshape(b, sq, kh, group, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = _constrain(scores, score_axes, ctx)
    scores = scores / np.sqrt(hd).astype(np.float32)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if isinstance(mask, tuple):
        q_pos, kv_pos, kv_valid = mask
        mask = (kv_pos[:, None, :] <= q_pos[:, :, None]) \
            & kv_valid[:, None, :].astype(bool)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = _constrain(probs, score_axes, ctx)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def _attention(cfg: LMConfig, q, k, v, mask, ctx=None):
    """Optionally query-chunked attention (bounded score-matrix memory)."""
    sq = q.shape[1]
    chunk = cfg.attn_chunk
    if not chunk or sq <= chunk or sq % chunk != 0:
        return _attn_scores_softmax(q, k, v, mask, cfg.logit_softcap, ctx,
                                    cfg.seq_shard_attn)

    nchunks = sq // chunk
    qs = q.reshape(q.shape[0], nchunks, chunk, *q.shape[2:]).swapaxes(0, 1)
    if isinstance(mask, tuple):
        q_pos, kv_pos, kv_valid = mask
        mchunks = q_pos.reshape(q_pos.shape[0], nchunks, chunk
                                ).swapaxes(0, 1)
        mk = lambda mc: (mc, kv_pos, kv_valid)
    else:
        mchunks = mask.reshape(mask.shape[0], nchunks, chunk,
                               mask.shape[-1]).swapaxes(0, 1)
        mk = lambda mc: mc

    def body(_, qc_maskc):
        qc, mc = qc_maskc
        return (), _attn_scores_softmax(qc, k, v, mk(mc),
                                        cfg.logit_softcap, ctx,
                                        cfg.seq_shard_attn)

    _, outs = jax.lax.scan(jax.checkpoint(body), (), (qs, mchunks))
    out = outs.swapaxes(0, 1).reshape(q.shape)
    return out


def _attn_block(cfg: LMConfig, lp: Params, x, positions, mask, ctx):
    h = _norm(x, lp["ln1"], lp.get("ln1_b"), cfg.norm)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    if cfg.seq_shard_attn and q.shape[1] > 1:
        # sequence parallelism: when the head count does not divide the TP
        # axis, shard the *query sequence* dim of attention over "model"
        # instead — the (B,*,Sq,Skv) score tensor shrinks TP-fold.
        q = _constrain(q, ("batch", "seq_model", "heads", None), ctx)
    else:
        q = _constrain(q, ("batch", None, "heads", None), ctx)
    k = _constrain(k, ("batch", None, "kv_heads", None), ctx)
    out = _attention(cfg, q, k, v, mask, ctx)
    out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
    return x + out


def _dense_ffn(cfg: LMConfig, lp: Params, x, ctx):
    h = _norm(x, lp["ln2"], lp.get("ln2_b"), cfg.norm)
    return x + _glu(cfg, h, lp["wi_gate"] if "wi_gate" in lp else None,
                    lp["wi_up"], lp["wo_ffn"], ctx)


def _glu(cfg, h, w_gate, w_up, w_down, ctx):
    up = jnp.einsum("bsd,df->bsf", h, w_up)
    if w_gate is not None:
        gate = _act(jnp.einsum("bsd,df->bsf", h, w_gate), cfg.activation)
        up = gate * up
    else:
        up = _act(up, cfg.activation)
    up = _constrain(up, ("batch", None, "ffn"), ctx)
    return jnp.einsum("bsf,fd->bsd", up, w_down)


def _constrain(x, logical_axes, ctx):
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.spec_for(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# MoE block: token-choice top-k, per-row capacity, gather dispatch/combine
# ---------------------------------------------------------------------------

def _moe_ffn(cfg: LMConfig, lp: Params, x, ctx):
    b, s, d = x.shape
    e, kk = cfg.n_experts, cfg.top_k
    cap = int(np.ceil(s * kk / e * cfg.capacity_factor))
    cap = max(cap, 1)

    h = _norm(x, lp["ln2"], lp.get("ln2_b"), cfg.norm)
    logits = jnp.einsum("bsd,de->bse", h, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, choice = jax.lax.top_k(probs, kk)                 # (b,s,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # auxiliary load-balance loss (Switch): mean fraction x mean prob
    density = jnp.mean(
        jax.nn.one_hot(choice[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(density * jnp.mean(probs, axis=(0, 1)))

    # slot ordering: (s, k) flattened, s-major -> stable positions
    e_flat = choice.reshape(b, s * kk)                        # (b, sk)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)       # (b, sk, e)
    pos = jnp.cumsum(onehot, axis=1) - onehot                 # rank within expert
    pos = jnp.take_along_axis(
        pos, e_flat[..., None], axis=-1)[..., 0]              # (b, sk)
    keep = pos < cap

    sentinel = e * cap
    slot = jnp.where(keep, e_flat * cap + pos, sentinel)      # (b, sk)

    # dispatch: scatter token indices into (e*cap) slots, then gather rows
    tok_idx = jnp.broadcast_to(
        jnp.arange(s * kk, dtype=jnp.int32) // kk, (b, s * kk))
    dest = jnp.full((b, e * cap + 1), s, dtype=jnp.int32)     # s == pad row
    brow = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s * kk))
    dest = dest.at[brow, slot].set(tok_idx)
    dest = dest[:, : e * cap]                                 # (b, e*cap)

    h_pad = jnp.concatenate([h, jnp.zeros((b, 1, d), h.dtype)], axis=1)
    xin = jnp.take_along_axis(
        h_pad, dest[..., None], axis=1).reshape(b, e, cap, d)
    xin = _constrain(xin, ("batch", "experts", None, None), ctx)

    gate_h = _act(jnp.einsum("becd,edf->becf", xin, lp["we_gate"]),
                  cfg.activation)
    up_h = jnp.einsum("becd,edf->becf", xin, lp["we_up"])
    hidden = gate_h * up_h
    hidden = _constrain(hidden, ("batch", "experts", None, "expert_ffn"), ctx)
    out = jnp.einsum("becf,efd->becd", hidden, lp["we_down"])
    out = _constrain(out, ("batch", "experts", None, None), ctx)

    # combine: gather each token's expert outputs back, weight by gates
    out_flat = out.reshape(b, e * cap, d)
    out_pad = jnp.concatenate(
        [out_flat, jnp.zeros((b, 1, d), out.dtype)], axis=1)
    back = jnp.take_along_axis(out_pad, slot[..., None], axis=1)  # (b, sk, d)
    back = back.reshape(b, s, kk, d)
    y = jnp.sum(back * gates[..., None].astype(back.dtype), axis=2)

    if cfg.n_shared_experts:
        y = y + _glu(cfg, h, lp["ws_gate"], lp["ws_up"], lp["ws_down"], ctx)
    return x + y.astype(x.dtype), aux


def _moe_ffn_shardmap(cfg: LMConfig, lp: Params, x, ctx):
    """§Perf MoE: capacity-dim sharding over "model" via shard_map.

    Expert weights are replicated over "model" (they are small when this
    path is chosen: E not divisible by TP); each rank gathers and computes
    only its cap/TP slice of the (B,E,cap,·) buffer, scatter-adds its
    slots' contributions into a local (B,S,d) partial, and a single psum
    of that partial replaces the per-layer capacity-buffer all-reduces.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, rules = ctx
    tp = mesh.shape.get("model", 1)
    b, s, d = x.shape
    e, kk = cfg.n_experts, cfg.top_k
    cap = int(np.ceil(s * kk / e * cfg.capacity_factor))
    cap = max(tp, -(-cap // tp) * tp)                    # pad to TP multiple

    h = _norm(x, lp["ln2"], lp.get("ln2_b"), cfg.norm)
    logits = jnp.einsum("bsd,de->bse", h, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, choice = jax.lax.top_k(probs, kk)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(
        jax.nn.one_hot(choice[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(density * jnp.mean(probs, axis=(0, 1)))

    e_flat = choice.reshape(b, s * kk)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(pos, e_flat[..., None], axis=-1)[..., 0]
    keep = pos < cap
    sentinel = e * cap
    slot = jnp.where(keep, e_flat * cap + pos, sentinel)
    tok_idx = jnp.broadcast_to(
        jnp.arange(s * kk, dtype=jnp.int32) // kk, (b, s * kk))
    brow = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s * kk))
    dest = jnp.full((b, e * cap + 1), s, dtype=jnp.int32)
    dest = dest.at[brow, slot].set(tok_idx)[:, : e * cap]   # (b, e*cap)
    gate_slot = jnp.zeros((b, e * cap + 1), jnp.float32)
    gate_slot = gate_slot.at[brow, slot].set(
        gates.reshape(b, s * kk))[:, : e * cap]             # (b, e*cap)

    dest3 = dest.reshape(b, e, cap)
    gate3 = gate_slot.reshape(b, e, cap)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = batch_axes if (batch_axes and b % int(
        np.prod([mesh.shape[a] for a in batch_axes])) == 0) else None

    def local_fn(h_loc, dest_loc, gate_loc, wg, wu, wd):
        bl, sl, _ = h_loc.shape
        cl = dest_loc.shape[-1]
        h_pad = jnp.concatenate(
            [h_loc, jnp.zeros((bl, 1, d), h_loc.dtype)], axis=1)
        flat = dest_loc.reshape(bl, e * cl)
        xin = jnp.take_along_axis(
            h_pad, flat[..., None], axis=1).reshape(bl, e, cl, d)
        gate_h = _act(jnp.einsum("becd,edf->becf", xin, wg),
                      cfg.activation)
        up_h = jnp.einsum("becd,edf->becf", xin, wu)
        out = jnp.einsum("becf,efd->becd", gate_h * up_h, wd)
        out = out * gate_loc[..., None].astype(out.dtype)
        # local combine: scatter-add this rank's slots into (b, s, d)
        br = jnp.broadcast_to(jnp.arange(bl)[:, None], (bl, e * cl))
        y = jnp.zeros((bl, sl + 1, d), jnp.float32)
        y = y.at[br, flat].add(out.reshape(bl, e * cl, d))
        # accumulate locally in fp32; cross-rank wire in the model dtype
        y = y[:, :sl].astype(h_loc.dtype)
        return jax.lax.psum(y, "model")

    y = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, "model"),
                  P(bspec, None, "model"), P(), P(), P()),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(h, dest3, gate3, lp["we_gate"], lp["we_up"], lp["we_down"])

    if cfg.n_shared_experts:
        y = y + _glu(cfg, h, lp["ws_gate"], lp["ws_up"], lp["ws_down"], ctx)
    return x + y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Full stack
# ---------------------------------------------------------------------------

def _scan_stack(cfg, stacked, body, x, positions, mask, ctx):
    def step(carry, lp):
        h, aux = carry
        h, a = body(lp, h)
        return (h, aux + a), None

    fn = jax.checkpoint(step) if cfg.remat else step
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)), stacked)
    return x, aux


def forward_hidden(cfg: LMConfig, params: Params, tokens, attn_mask,
                   ctx=None):
    """tokens (B,S) int32, attn_mask (B,S) {0,1} -> hidden (B,S,d), aux loss."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.name.startswith("gemma"):
        # keep the scale in the model dtype: an np.float32 scalar would
        # promote the whole residual stream to fp32 (2x HBM + wire)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    x = _constrain(x, ("batch", None, None), ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.inline_mask:
        # §Perf: causal mask built inside the attention fusion from 1-D
        # position vectors — no (B,S,S) bool tensor exists in HBM / on wire
        mask = (positions, positions, attn_mask)
    else:
        causal = jnp.tril(jnp.ones((s, s), bool))
        mask = causal[None] & attn_mask[:, None, :].astype(bool)

    def boundary(h):
        # Megatron-SP: keep the residual stream sequence-sharded between
        # layers so remat-saved activations are TP-fold smaller; each layer
        # re-gathers at its LayerNorm.
        if cfg.seq_shard_acts and h.shape[1] > 1:
            return _constrain(h, ("batch", "seq_model", None), ctx)
        return h

    def dense_body(lp, h):
        h = _attn_block(cfg, lp, h, positions, mask, ctx)
        h = _dense_ffn(cfg, lp, h, ctx)
        return boundary(h), jnp.float32(0.0)

    moe_fn = (_moe_ffn_shardmap
              if cfg.moe_impl == "shardmap" and ctx is not None
              else _moe_ffn)

    def moe_body(lp, h):
        h = _attn_block(cfg, lp, h, positions, mask, ctx)
        h, aux = moe_fn(cfg, lp, h, ctx)
        return boundary(h), aux

    aux_total = jnp.float32(0.0)
    if not cfg.scan_layers:
        # unrolled stack: exact XLA cost/memory analysis (HLO while-loop
        # bodies are counted once by HloCostAnalysis — scan under-reports)
        def layer_of(stack, i):
            return jax.tree.map(lambda a: a[i], params[stack])

        def run(body, lp, h):
            fn = jax.checkpoint(lambda l, hh: body(l, hh)) if cfg.remat \
                else body
            return fn(lp, h)

        for i in range(cfg.n_layers):
            if not cfg.moe:
                x, a = run(dense_body, layer_of("blocks", i), x)
            elif cfg.moe_every == 1:
                x, a = run(moe_body, layer_of("moe_blocks", i), x)
            elif i % 2 == 0:
                x, a = run(dense_body, layer_of("blocks", i // 2), x)
            else:
                x, a = run(moe_body, layer_of("moe_blocks", i // 2), x)
            aux_total += a
    elif not cfg.moe:
        x, aux = _scan_stack(cfg, params["blocks"], dense_body, x,
                             positions, mask, ctx)
        aux_total += aux
    elif cfg.moe_every == 1:
        x, aux = _scan_stack(cfg, params["moe_blocks"], moe_body, x,
                             positions, mask, ctx)
        aux_total += aux
    else:
        # interleaved: scan over (dense, moe) layer pairs
        def pair_body(carry, lps):
            h, aux = carry
            dlp, mlp = lps
            h, _ = dense_body(dlp, h)
            h, a = moe_body(mlp, h)
            return (h, aux + a), None

        fn = jax.checkpoint(pair_body) if cfg.remat else pair_body
        (x, aux_total), _ = jax.lax.scan(
            fn, (x, aux_total), (params["blocks"], params["moe_blocks"]))

    x = _norm(x, params["final_ln"], params.get("final_ln_b"), cfg.norm)
    return x, aux_total


def pool(cfg: LMConfig, hidden, attn_mask):
    maskf = attn_mask.astype(jnp.float32)[..., None]
    if cfg.pooling == "mean":
        emb = (hidden * maskf).sum(1) / jnp.clip(maskf.sum(1), 1e-6)
    elif cfg.pooling == "first":
        emb = hidden[:, 0]
    else:  # last non-pad token
        idx = jnp.clip(attn_mask.sum(-1).astype(jnp.int32) - 1, 0)
        emb = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)[:, 0]
    emb = emb.astype(jnp.float32)
    return emb / jnp.clip(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)


def encode(cfg: LMConfig, params: Params, tokens, attn_mask, ctx=None):
    """Retrieval embedding: (B,S) -> (B,d) L2-normalized (fp32)."""
    hidden, _ = forward_hidden(cfg, params, tokens, attn_mask, ctx)
    return pool(cfg, hidden, attn_mask)


def lm_logits(cfg: LMConfig, params: Params, hidden):
    return jnp.einsum("bsd,vd->bsv", hidden, params["embed"],
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# KV-cache decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv, cfg.dtype),
        "v": jnp.zeros(kv, cfg.dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes(cfg: LMConfig, batch: int,
                       tp_divides_kv: bool = True) -> Params:
    """KV-cache sharding (DESIGN.md §5):
      batch==1 (long-context): seq over the data axes (+ model when the
        kv-head count does not divide TP — flash-decoding both ways);
      batch>1: batch over data; kv-heads over model when divisible, else
        the cache seq dim takes the model axis."""
    if batch == 1:
        seq_axis = "kv_seq" if tp_divides_kv else "kv_seq_full"
        kv = ("layers", None, seq_axis, "kv_heads", None)
    elif tp_divides_kv:
        kv = ("layers", "batch", None, "kv_heads", None)
    else:
        kv = ("layers", "batch", "seq_model", None, None)
    return {"k": kv, "v": kv, "len": ()}


def decode_step(cfg: LMConfig, params: Params, cache: Params,
                tokens: jax.Array, ctx=None):
    """One decode step.  tokens (B,) int32.  Returns (logits (B,V), cache).

    The new token attends to `cache[:len]` plus itself; its K/V are written
    at position `len`.  Works under pjit with the cache sharded per
    ``cache_logical_axes`` (long-context: seq-sharded => flash-decoding-style
    sharded softmax reductions are inserted by SPMD).  The layer stack is a
    ``lax.scan`` whose xs carry both the stacked params and the per-layer
    cache slices, so no traced layer indexing is needed.
    """
    b = tokens.shape[0]
    max_len = cache["k"].shape[2]
    pos = cache["len"]
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.full((b, 1), pos, jnp.int32)
    valid = jnp.arange(max_len)[None, None, :] <= pos       # (1,1,S)
    valid = jnp.broadcast_to(valid, (b, 1, max_len))
    at_pos = (jnp.arange(max_len) == pos)[None, :, None, None]

    def sublayer(h, lp, kc, vc, kind):
        hn = _norm(h, lp["ln1"], lp.get("ln1_b"), cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", hn, lp["wq"])
        k1 = jnp.einsum("bsd,dhk->bshk", hn, lp["wk"])
        v1 = jnp.einsum("bsd,dhk->bshk", hn, lp["wv"])
        if cfg.qkv_bias:
            q, k1, v1 = q + lp["bq"], k1 + lp["bk"], v1 + lp["bv"]
        q = _rope(q, positions, cfg.rope_theta)
        k1 = _rope(k1, positions, cfg.rope_theta)
        kc = jnp.where(at_pos, k1, kc)      # new token's K/V visible to self
        vc = jnp.where(at_pos, v1, vc)
        out = _attn_scores_softmax(q, kc, vc, valid, cfg.logit_softcap, ctx)
        h = h + jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        hn2 = _norm(h, lp["ln2"], lp.get("ln2_b"), cfg.norm)
        if kind == "dense":
            y = _glu(cfg, hn2, lp.get("wi_gate"), lp["wi_up"], lp["wo_ffn"],
                     ctx)
        else:
            y = _moe_token(cfg, lp, hn2, ctx)
        return h + y, k1, v1

    ck, cv = cache["k"], cache["v"]
    if not cfg.scan_layers:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            if not cfg.moe:
                lp, kind = jax.tree.map(lambda a: a[i],
                                        params["blocks"]), "dense"
            elif cfg.moe_every == 1:
                lp, kind = jax.tree.map(lambda a: a[i],
                                        params["moe_blocks"]), "moe"
            elif i % 2 == 0:
                lp, kind = jax.tree.map(lambda a: a[i // 2],
                                        params["blocks"]), "dense"
            else:
                lp, kind = jax.tree.map(lambda a: a[i // 2],
                                        params["moe_blocks"]), "moe"
            x, k1, v1 = sublayer(x, lp, ck[i], cv[i], kind)
            ks.append(k1)
            vs.append(v1)
        nk, nv = jnp.stack(ks), jnp.stack(vs)
    elif not cfg.moe:
        def body(h, xs):
            lp, kc, vc = xs
            h, k1, v1 = sublayer(h, lp, kc, vc, "dense")
            return h, (k1, v1)
        x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"], ck, cv))
    elif cfg.moe_every == 1:
        def body(h, xs):
            lp, kc, vc = xs
            h, k1, v1 = sublayer(h, lp, kc, vc, "moe")
            return h, (k1, v1)
        x, (nk, nv) = jax.lax.scan(body, x, (params["moe_blocks"], ck, cv))
    else:
        half = cfg.n_layers // 2
        ckp = ck.reshape(half, 2, *ck.shape[1:])
        cvp = cv.reshape(half, 2, *cv.shape[1:])

        def body(h, xs):
            dlp, mlp, kc2, vc2 = xs
            h, k0, v0 = sublayer(h, dlp, kc2[0], vc2[0], "dense")
            h, k1, v1 = sublayer(h, mlp, kc2[1], vc2[1], "moe")
            return h, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], params["moe_blocks"], ckp, cvp))
        nk = nk.reshape(cfg.n_layers, *nk.shape[2:])
        nv = nv.reshape(cfg.n_layers, *nv.shape[2:])

    x = _norm(x, params["final_ln"], params.get("final_ln_b"), cfg.norm)
    logits = lm_logits(cfg, params, x)[:, 0]
    if cfg.dus_cache_update:
        # §Perf: O(L*B*K*hd) in-place write instead of a full-cache
        # where-rewrite (which reads+writes the entire cache every step)
        zero = jnp.zeros((), jnp.int32)
        cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], nk, (zero, zero, pos, zero, zero)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], nv, (zero, zero, pos, zero, zero)),
            "len": pos + 1,
        }
    else:
        upd = at_pos[None]                   # (1,1,S,1,1) over (L,B,S,K,hd)
        cache = {
            "k": jnp.where(upd, nk, cache["k"]),
            "v": jnp.where(upd, nv, cache["v"]),
            "len": pos + 1,
        }
    return logits, cache


def _moe_token(cfg: LMConfig, lp: Params, h, ctx):
    """MoE for S==1 (decode): gather only the chosen experts' weights.

    FLOPs are O(B x top_k x d x f) — the weight *gather* (not compute) is
    the cost, which matches the memory-bound reality of MoE decode.
    """
    b, s, d = h.shape
    hh = h.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", hh, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, choice = jax.lax.top_k(probs, cfg.top_k)          # (t,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    wg = jnp.take(lp["we_gate"], choice, axis=0)             # (t,k,d,f)
    wu = jnp.take(lp["we_up"], choice, axis=0)
    wd = jnp.take(lp["we_down"], choice, axis=0)             # (t,k,f,d)
    gate_h = _act(jnp.einsum("td,tkdf->tkf", hh, wg), cfg.activation)
    up_h = jnp.einsum("td,tkdf->tkf", hh, wu)
    out = jnp.einsum("tkf,tkfd->tkd", gate_h * up_h, wd)
    y = jnp.sum(out * gates[..., None].astype(out.dtype), axis=1)
    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + _glu(cfg, h, lp["ws_gate"], lp["ws_up"], lp["ws_down"], ctx)
    return y
