"""Jit-compiled mini-batch k-means coarse quantizer (index subsystem).

The quantizer behind :class:`repro.index.ivf.IVFIndex`: centroids are
trained with streaming mini-batch k-means (Sculley 2010's per-center
count-weighted update, batched) where every batch is one contiguous
``get_range(lo, hi)`` read — the ``EmbeddingCache`` mmap fast path — so
training never materializes the corpus.  Assignment uses squared L2
(``argmin ||x - c||² = argmin ||c||² - 2 x·c``), computed as one matmul
per batch inside a single jitted step.

Determinism: all randomness (centroid seeding, batch window starts)
comes from one ``np.random.default_rng(seed)``, the iteration budget is
fixed, and the jitted update is pure — same seed + same rows = same
centroids, on every worker of a cluster (the multi-node path relies on
every rank building the identical index).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _assign_step(centroids, batch):
    """Nearest-centroid ids for one batch: argmin_c ||x - c||²."""
    c2 = (centroids * centroids).sum(axis=1)
    sims = batch @ centroids.T
    return jnp.argmin(c2[None, :] - 2.0 * sims, axis=1)


@jax.jit
def _train_step(centroids, counts, batch):
    """One mini-batch update: assign, then move each hit centroid to the
    count-weighted running mean of everything ever assigned to it (the
    batched form of the per-sample ``c += (x - c) / count`` rule)."""
    k = centroids.shape[0]
    assign = _assign_step(centroids, batch)
    sums = jax.ops.segment_sum(batch, assign, num_segments=k)
    hits = jax.ops.segment_sum(jnp.ones(batch.shape[0], jnp.float32),
                               assign, num_segments=k)
    new_counts = counts + hits
    moved = ((centroids * counts[:, None] + sums)
             / jnp.maximum(new_counts, 1.0)[:, None])
    # a centroid no batch row hit must stay put, not decay toward zero
    centroids = jnp.where((hits > 0)[:, None], moved, centroids)
    return centroids, new_counts


def train_kmeans(get_range, n_rows: int, n_clusters: int, *,
                 train_steps: int = 40, batch_size: int = 1024,
                 seed: int = 0) -> np.ndarray:
    """Train ``min(n_clusters, n_rows)`` centroids off a row stream.

    ``get_range(lo, hi)`` returns rows ``[lo, hi)`` as an (hi-lo, d)
    array — ``EmbeddingCache.get_range`` or any array slice.  Each of
    the ``train_steps`` mini-batches is one contiguous window at a
    seeded-random start (cache rows arrive in corpus order, which is
    already topic-arbitrary, so contiguous windows behave like uniform
    samples while staying single-mmap-read cheap).  Returns centroids
    as a float32 (k, d) array.
    """
    if n_rows <= 0:
        raise ValueError(f"n_rows must be >= 1, got {n_rows}")
    if train_steps < 1:
        raise ValueError(f"train_steps must be >= 1, got {train_steps}")
    k = int(min(n_clusters, n_rows))
    rng = np.random.default_rng(seed)
    init_rows = np.sort(rng.choice(n_rows, size=k, replace=False))
    cents = np.concatenate(
        [np.asarray(get_range(int(r), int(r) + 1), np.float32)
         for r in init_rows])
    centroids = jnp.asarray(cents, jnp.float32)
    # each centroid starts owning its seed row, so the first batches
    # can't yank a centroid across the space on a single stray sample
    counts = jnp.ones(k, jnp.float32)
    b = int(min(batch_size, n_rows))
    for _ in range(train_steps):
        lo = int(rng.integers(0, n_rows - b + 1))
        batch = jnp.asarray(np.asarray(get_range(lo, lo + b), np.float32))
        centroids, counts = _train_step(centroids, counts, batch)
    return np.asarray(centroids)


def assign_rows(centroids: np.ndarray, get_range, n_rows: int, *,
                batch_size: int = 4096) -> np.ndarray:
    """Stream every row through nearest-centroid assignment.

    Returns an (n_rows,) int32 cluster id per row.  The ragged tail
    batch pads up to ``batch_size`` so the jitted assign compiles once.
    """
    out = np.empty(n_rows, np.int32)
    cj = jnp.asarray(centroids, jnp.float32)
    b = int(min(batch_size, max(n_rows, 1)))
    for lo in range(0, n_rows, b):
        hi = min(lo + b, n_rows)
        batch = np.asarray(get_range(lo, hi), np.float32)
        if hi - lo < b:
            batch = np.pad(batch, ((0, b - (hi - lo)), (0, 0)))
        out[lo:hi] = np.asarray(_assign_step(cj, jnp.asarray(batch)))[
            : hi - lo]
    return out
