"""IVFIndex: cluster-pruned (inverted-file) search layout (ROADMAP item 2).

An :class:`IVFIndex` turns any row-addressable embedding store (the
mmap'd ``EmbeddingCache``, a device-resident array) into a sublinear
search structure:

  * **build** — a mini-batch k-means coarse quantizer
    (:mod:`repro.index.kmeans`) trained off contiguous ``get_range``
    streams; every row is then assigned to its nearest centroid and the
    index keeps a *cluster-sorted row permutation* plus per-cluster
    ``[lo, hi)`` offsets — the append-only ``ids.bin`` idea generalized
    to a cluster-partitioned layout.  The vectors themselves are never
    copied: the permutation addresses the original store.
  * **query** — ``select(q_emb, nprobe)`` scores the query batch against
    the centroids and returns the union of every query's ``nprobe``
    nearest clusters, ascending; ``gather_rows`` concatenates the
    selected clusters' permutation slices.  The caller streams those
    rows through the unchanged superchunk executor, so the pruned path
    inherits the exact fused score+top-k/merge semantics of the flat
    scan — ``nprobe == n_clusters`` reproduces the flat ranking.
  * **persist** — ``save``/``load`` write ``centroids.bin`` /
    ``perm.bin`` / ``offsets.bin`` and atomically replace ``meta.json``
    last, exactly like the embedding cache's commit protocol: readers
    trust only the meta row counts, trailing torn bytes are ignored,
    and a load that can't satisfy the meta (crash mid-save, stale
    corpus digest, shape mismatch) returns ``None`` so callers rebuild.
"""

from __future__ import annotations

import json
import os
import threading

import numpy as np

from repro.index.kmeans import assign_rows, train_kmeans

_I64 = np.dtype("<i8")
_F32 = np.dtype("<f4")


def corpus_digest(hashes, *, seed: int = 0, train_steps: int = 40,
                  train_batch: int = 1024, generation=None) -> str:
    """Digest key binding a persisted index to the exact corpus it was
    built over: the id hashes, the build knobs, and — for a mutable
    cache-backed corpus — the cache generation.  Folding the generation
    in means a post-mutation ``load`` returns ``None`` (rebuild) instead
    of silently serving a permutation over a different row set.
    ``generation`` may be an int or a cache ``(generation, epoch)`` key.
    """
    import hashlib

    digest = hashlib.sha1(
        np.ascontiguousarray(hashes, np.int64).tobytes()).hexdigest()[:16]
    digest += f"-s{seed}-t{train_steps}-b{train_batch}"
    if generation is not None:
        if isinstance(generation, tuple):
            gen, epoch = generation
        else:
            gen, epoch = generation, 0
        digest += f"-g{int(gen)}e{int(epoch)}"
    return digest


def cluster_order(get_range, n_rows: int, n_clusters: int, *,
                  seed: int = 0, train_steps: int = 40,
                  train_batch: int = 1024) -> np.ndarray:
    """The cluster-sorted row permutation for ``n_rows`` rows served by
    ``get_range`` — what :meth:`EmbeddingCache.compact` takes as its
    ``order`` so compaction rewrites live rows into the IVF layout
    (cluster-contiguous on disk: a later index build over the compacted
    cache streams clusters as contiguous ranges)."""
    index = IVFIndex.build(get_range, n_rows,
                           int(min(n_clusters, max(n_rows, 1))),
                           seed=seed, train_steps=train_steps,
                           train_batch=train_batch)
    return index.perm


def _read_exact(path: str, dtype: np.dtype, count: int):
    """Read exactly ``count`` items; ``None`` if the file is missing or
    shorter (torn write) — trailing garbage beyond ``count`` is ignored,
    mirroring the cache's truncate-on-reopen semantics."""
    if not os.path.exists(path):
        return None
    arr = np.fromfile(path, dtype=dtype, count=count)
    if len(arr) != count:
        return None
    return arr


class IVFIndex:
    """Cluster-sorted layout: centroids (k, d), row permutation (n,),
    per-cluster offsets (k + 1,) with cluster ``c`` owning permutation
    slice ``perm[offsets[c]:offsets[c + 1]]`` (rows are indices into the
    original store, in their original relative order — stable sort)."""

    def __init__(self, centroids: np.ndarray, perm: np.ndarray,
                 offsets: np.ndarray):
        self.centroids = np.ascontiguousarray(centroids, np.float32)
        self.perm = np.ascontiguousarray(perm, np.int64)
        self.offsets = np.ascontiguousarray(offsets, np.int64)
        assert self.offsets.shape == (len(self.centroids) + 1,)
        assert self.offsets[0] == 0 and self.offsets[-1] == len(self.perm)

    @property
    def n_rows(self) -> int:
        return len(self.perm)

    @property
    def n_clusters(self) -> int:
        return len(self.centroids)

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    def cluster_sizes(self) -> np.ndarray:
        return self.offsets[1:] - self.offsets[:-1]

    # -- build ----------------------------------------------------------------
    @classmethod
    def build(cls, get_range, n_rows: int, n_clusters: int, *,
              seed: int = 0, train_steps: int = 40,
              train_batch: int = 1024) -> "IVFIndex":
        """Train the quantizer and lay out the cluster-sorted permutation.

        ``get_range(lo, hi)`` serves rows of the store being indexed
        (``EmbeddingCache.get_range``, an array slice, a row-plan
        adapter) — only O(batch) rows are ever resident.
        """
        centroids = train_kmeans(get_range, n_rows, n_clusters,
                                 train_steps=train_steps,
                                 batch_size=train_batch, seed=seed)
        assign = assign_rows(centroids, get_range, n_rows)
        # stable: rows of one cluster keep their original relative order,
        # so a full-probe scan replays the store in a fixed permutation
        perm = np.argsort(assign, kind="stable").astype(np.int64)
        sizes = np.bincount(assign, minlength=len(centroids))
        offsets = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(sizes, dtype=np.int64)])
        return cls(centroids, perm, offsets)

    # -- persistence ----------------------------------------------------------
    def save(self, path: str, *, digest: str | None = None) -> None:
        """Persist under ``path``; crash-safe like the embedding cache:
        payload files land first (tmp + atomic replace, names unique per
        thread so concurrent identical builders never collide), then
        ``meta.json`` replaces atomically — a reader either sees the old
        committed index or the new one, never a torn mix."""
        os.makedirs(path, exist_ok=True)
        tag = f".tmp{os.getpid()}_{threading.get_ident()}"
        for fname, arr in (("centroids.bin", self.centroids.astype(_F32)),
                           ("perm.bin", self.perm.astype(_I64)),
                           ("offsets.bin", self.offsets.astype(_I64))):
            tmp = os.path.join(path, fname + tag)
            with open(tmp, "wb") as f:
                f.write(np.ascontiguousarray(arr).tobytes())
            os.replace(tmp, os.path.join(path, fname))
        meta = {"n": self.n_rows, "dim": self.dim,
                "n_clusters": self.n_clusters, "digest": digest,
                "version": 1}
        tmp = os.path.join(path, "meta.json" + tag)
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(path, "meta.json"))

    @classmethod
    def load(cls, path: str, *, expect_n: int | None = None,
             expect_dim: int | None = None,
             expect_clusters: int | None = None,
             expect_digest: str | None = None) -> "IVFIndex | None":
        """Reopen a persisted layout; ``None`` means "rebuild" — missing
        or torn files, or a meta that doesn't describe the corpus the
        caller is about to search (row count / dim / cluster count /
        content digest mismatch)."""
        meta_path = os.path.join(path, "meta.json")
        if not os.path.exists(meta_path):
            return None
        try:
            with open(meta_path) as f:
                meta = json.load(f)
        except (OSError, ValueError):
            return None
        n, dim, k = meta.get("n"), meta.get("dim"), meta.get("n_clusters")
        if not all(isinstance(v, int) and v >= 0 for v in (n, dim, k)):
            return None
        for want, got in ((expect_n, n), (expect_dim, dim),
                          (expect_clusters, k)):
            if want is not None and want != got:
                return None
        if expect_digest is not None and meta.get("digest") != expect_digest:
            return None
        cents = _read_exact(os.path.join(path, "centroids.bin"), _F32,
                            k * dim)
        perm = _read_exact(os.path.join(path, "perm.bin"), _I64, n)
        offsets = _read_exact(os.path.join(path, "offsets.bin"), _I64,
                              k + 1)
        if cents is None or perm is None or offsets is None:
            return None
        offsets = offsets.astype(np.int64)
        if (offsets[0] != 0 or offsets[-1] != n
                or (np.diff(offsets) < 0).any()):
            return None
        # perm must be a permutation of [0, n): a torn perm.bin whose
        # byte count happens to line up must still be rejected
        if n and (np.bincount(
                np.clip(perm, 0, n - 1), minlength=n) != 1).any():
            return None
        if n and (perm.min() < 0 or perm.max() >= n):
            return None
        return cls(cents.reshape(k, dim), perm.astype(np.int64), offsets)

    # -- query ----------------------------------------------------------------
    def select(self, q_emb: np.ndarray, nprobe: int) -> np.ndarray:
        """Union of each query's ``nprobe`` nearest (squared-L2)
        clusters, ascending, empty clusters dropped.  Host-side: the
        centroid table is tiny next to the corpus, and the selection
        drives host-side gather planning anyway."""
        q = np.asarray(q_emb, np.float32)
        if q.ndim == 1:
            q = q[None]
        k = self.n_clusters
        nprobe = max(1, min(int(nprobe), k))
        if nprobe >= k:
            clusters = np.arange(k, dtype=np.int64)
        else:
            c2 = (self.centroids * self.centroids).sum(axis=1)
            d2 = c2[None, :] - 2.0 * (q @ self.centroids.T)
            part = np.argpartition(d2, nprobe - 1, axis=1)[:, :nprobe]
            clusters = np.unique(part).astype(np.int64)
        sizes = self.offsets[clusters + 1] - self.offsets[clusters]
        return clusters[sizes > 0]

    def gather_rows(self, clusters: np.ndarray) -> np.ndarray:
        """Concatenated store-row indices of the selected clusters — the
        contiguous permutation slices the search space streams."""
        if len(clusters) == 0:
            return np.empty(0, np.int64)
        return np.concatenate(
            [self.perm[self.offsets[c]:self.offsets[c + 1]]
             for c in clusters])

    def slice_boundaries(self, clusters: np.ndarray) -> np.ndarray:
        """Cumulative cluster edges inside the selected search space
        (``[0, s1, s1+s2, ..., n_selected]``) — the cut points a fair
        sharder may split at so every shard stays a run of whole
        clusters (each worker reads a few contiguous permutation
        slices, never a sliver of every cluster)."""
        sizes = self.offsets[clusters + 1] - self.offsets[clusters]
        return np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(sizes, dtype=np.int64)])
