"""Index subsystem: sublinear search backends (ROADMAP item 2).

Everything outside this package scans the whole corpus per query; the
classes here trade a bounded amount of recall for sublinear work:

  * :mod:`repro.index.kmeans` — jit-compiled mini-batch k-means coarse
    quantizer, trained off contiguous ``EmbeddingCache.get_range``
    streams (no full-corpus materialization).
  * :mod:`repro.index.ivf` — :class:`IVFIndex`, a cluster-pruned
    (inverted-file) layout over any row-addressable embedding store:
    rows sorted by cluster, per-cluster ``[lo, hi)`` offsets + a row
    permutation persisted torn-write-safe like the embedding cache.

The flat exhaustive scan stays available as the recall oracle
(``EvaluationArguments.index_impl='flat'``); ``benchmarks/bench_ivf.py``
records the recall@k-vs-speedup trade-off.
"""

from repro.index.ivf import IVFIndex
from repro.index.kmeans import assign_rows, train_kmeans

__all__ = ["IVFIndex", "assign_rows", "train_kmeans"]
