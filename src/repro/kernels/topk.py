"""Pallas TPU kernels: streaming top-k (FastResultHeapq) + fused score+top-k.

TPU adaptation of the paper's FastResultHeapq (DESIGN.md §2.1): the
running (Q, k) top-k buffer lives in a *revisited* output block (aliased
with the input state), and each grid step merges one score tile from
VMEM.  ``fused_score_topk`` additionally produces the score tile on the
MXU from (Q,d)x(d,N) inside the kernel, so the (Q,N) score matrix never
exists in HBM — the HBM-traffic term of retrieval drops from O(Q*N) to
O(N*d + Q*k).

Selection uses a VPU-only iterative max+mask loop (no ``lax.top_k`` /
``sort`` dependency, which Mosaic does not lower): per selected rank we
compute a row max, locate its first occurrence via iota-min, emit, and
mask.  k is a compile-time constant; cost O(k*(k+bc)) VPU ops per tile.

Tiling: bq rows x (k + bc) candidate lanes; defaults keep the working set
(bq*(k+bc)*8B) well under VMEM and lane-align k, bc to 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _select_topk_into(out_v_ref, out_i_ref, cand_v, cand_i, k: int):
    """Iteratively select the k largest (value, id) pairs of cand into refs."""

    def body(j, carry):
        cv, ci = carry
        m = jnp.max(cv, axis=1)                                   # (bq,)
        iota = jax.lax.broadcasted_iota(jnp.int32, cv.shape, 1)
        at_max = cv == m[:, None]
        first = jnp.min(jnp.where(at_max, iota, cv.shape[1]), axis=1)
        onehot = iota == first[:, None]
        sel_id = jnp.max(jnp.where(onehot, ci, -1), axis=1)
        # -inf means "empty / never retrieve": emit -1, not the id.  The
        # NEG_INF mask below can't distinguish an already-selected
        # position from a genuinely empty one — without this, once the
        # running max hits -inf the first selected position would be
        # re-picked and re-emit its real id (duplicate ids in the tail).
        sel_id = jnp.where(m == NEG_INF, -1, sel_id)
        out_v_ref[:, pl.ds(j, 1)] = m[:, None]
        out_i_ref[:, pl.ds(j, 1)] = sel_id[:, None]
        return jnp.where(onehot, NEG_INF, cv), ci

    jax.lax.fori_loop(0, k, body, (cand_v, cand_i))


def _topk_update_kernel(vals_ref, ids_ref, scores_ref, cids_ref,
                        out_v_ref, out_i_ref, *, k: int):
    # out refs are aliased with (vals, ids): they already hold the running
    # state on the first visit and accumulate across the C-grid axis.
    cand_v = jnp.concatenate(
        [out_v_ref[...], scores_ref[...].astype(jnp.float32)], axis=1)
    tile_ids = jnp.broadcast_to(cids_ref[...], scores_ref.shape
                                ).astype(jnp.int32)
    cand_i = jnp.concatenate([out_i_ref[...], tile_ids], axis=1)
    _select_topk_into(out_v_ref, out_i_ref, cand_v, cand_i, k)


def topk_update_pallas(vals, ids, scores, chunk_ids, *, bq: int = 128,
                       bc: int = 512, interpret: bool = False):
    """Merge scores (Q,C) with ids (C,) into running (vals, ids) (Q,k)."""
    q, k = vals.shape
    c = scores.shape[1]
    bq = min(bq, q)
    bc = min(bc, c)
    grid = (pl.cdiv(q, bq), pl.cdiv(c, bc))
    cids2d = chunk_ids.reshape(1, c).astype(jnp.int32)
    kernel = functools.partial(_topk_update_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(vals.astype(jnp.float32), ids.astype(jnp.int32), scores, cids2d)


def _fused_kernel(scal_ref, q_ref, d_ref, out_v_ref, out_i_ref, *, k: int,
                  bn: int):
    # scal_ref (1, 2) int32 = [id_offset, n_valid]: both *traced* scalars,
    # so a streaming caller (lax.scan over corpus superchunks) can vary
    # the chunk's global offset and its valid-row count per step without
    # recompiling — the scan-carry contract of the superchunk executor.
    j = pl.program_id(1)
    id_offset = scal_ref[0, 0]
    n_valid = scal_ref[0, 1]
    scores = jax.lax.dot_general(
        q_ref[...], d_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (bq, bn)
    base = j * bn
    iota = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + base
    # mask padded doc rows: grid padding (n not a multiple of bn) and
    # caller padding (ragged tail chunks stacked to a fixed tile) alike
    valid = iota < n_valid
    scores = jnp.where(valid, scores, NEG_INF)
    tile_ids = jnp.where(valid, iota + id_offset, -1)

    @pl.when(j == 0)
    def _init():
        out_v_ref[...] = jnp.full_like(out_v_ref, NEG_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    cand_v = jnp.concatenate([out_v_ref[...], scores], axis=1)
    cand_i = jnp.concatenate([out_i_ref[...], tile_ids], axis=1)
    _select_topk_into(out_v_ref, out_i_ref, cand_v, cand_i, k)


def fused_score_topk_pallas(queries, docs, k: int, *, id_offset=0,
                            n_valid=None, bq: int = 128, bn: int = 512,
                            interpret: bool = False):
    """Top-k of queries @ docs.T without materializing the score matrix.

    queries (Q, d), docs (N, d) -> (vals (Q,k) desc, ids int32 (Q,k)).

    ``id_offset`` and ``n_valid`` may be traced int scalars (scan-friendly:
    the superchunk executor varies both per scan step under one jit).
    Docs rows at index >= ``n_valid`` (default N) score -inf / id -1, so a
    ragged tail chunk padded up to a fixed tile shape stays inert.
    """
    q, d = queries.shape
    n = docs.shape[0]
    bq = min(bq, q)
    bn = min(bn, n)
    grid = (pl.cdiv(q, bq), pl.cdiv(n, bn))
    n_valid = n if n_valid is None else jnp.minimum(n_valid, n)
    scal = jnp.stack([jnp.asarray(id_offset, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)]).reshape(1, 2)
    kernel = functools.partial(_fused_kernel, k=k, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(scal, queries, docs)
