"""Pallas TPU kernel: fused EmbeddingBag (gather + weighted segment-sum).

RecSys hot path (DESIGN.md §6): JAX has no native EmbeddingBag; the jnp
reference gathers (B, L, D) rows to HBM then reduces.  This kernel keeps
the gathered rows in VMEM: for each batch tile it walks the L bag slots,
dynamically slicing one table row at a time (HBM->VMEM row DMA) and
accumulating on the VPU — HBM traffic drops from O(B*L*D) write +
O(B*L*D) read to O(B*L*D) read only, and the (B,L,D) intermediate never
exists.

The table stays in ANY memory (HBM) via ``pl.BlockSpec(memory_space=ANY)``
and rows are fetched with dynamic loads; padding ids (<0) contribute 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(idx_ref, w_ref, table_ref, out_ref, *, L: int):
    bq, d = out_ref.shape
    acc = jnp.zeros((bq, d), jnp.float32)

    def slot(l, acc):
        ids = idx_ref[:, l]                          # (bq,)
        w = w_ref[:, l].astype(jnp.float32)

        def row(b, acc):
            rid = ids[b]
            safe = jnp.maximum(rid, 0)
            vec = pl.load(table_ref, (pl.ds(safe, 1), slice(None)))
            vec = vec.astype(jnp.float32) * w[b] * (rid >= 0)
            return jax.lax.dynamic_update_slice(
                acc, jax.lax.dynamic_slice(acc, (b, 0), (1, d)) + vec,
                (b, 0))

        return jax.lax.fori_loop(0, bq, row, acc)

    acc = jax.lax.fori_loop(0, L, slot, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag_pallas(table, idx, weights=None, *, bq: int = 256,
                         interpret: bool = False):
    """table (V, D); idx (B, L) int32 (-1 = pad); optional weights (B, L)."""
    b, L = idx.shape
    v, d = table.shape
    if weights is None:
        weights = jnp.ones((b, L), table.dtype)
    bq = min(bq, b)
    grid = (pl.cdiv(b, bq),)
    kernel = functools.partial(_bag_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), weights, table)
