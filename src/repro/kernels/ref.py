"""Pure-jnp oracles for the Pallas kernels (correctness references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_update_ref(vals: jax.Array, ids: jax.Array, scores: jax.Array,
                    chunk_ids: jax.Array):
    """Merge a (Q, C) score chunk into running (Q, k) top-k state.

    vals f32 (Q,k) desc-unordered, ids i32 (Q,k), scores (Q,C),
    chunk_ids i32 (C,).  Returns (vals, ids) of the merged top-k.
    """
    k = vals.shape[1]
    cand_v = jnp.concatenate([vals, scores.astype(jnp.float32)], axis=1)
    cand_i = jnp.concatenate(
        [ids, jnp.broadcast_to(chunk_ids[None, :], scores.shape
                               ).astype(ids.dtype)], axis=1)
    top_v, pos = jax.lax.top_k(cand_v, k)
    return top_v, jnp.take_along_axis(cand_i, pos, axis=1)


def fused_score_topk_ref(queries: jax.Array, docs: jax.Array, k: int,
                         id_offset: int = 0):
    """Exact top-k of queries @ docs.T.

    queries (Q, d), docs (N, d) -> (vals (Q,k) desc, ids i32 (Q,k)).
    """
    scores = jnp.einsum("qd,nd->qn", queries, docs,
                        preferred_element_type=jnp.float32)
    top_v, pos = jax.lax.top_k(scores, k)
    return top_v, (pos + id_offset).astype(jnp.int32)


def embedding_bag_ref(table: jax.Array, idx: jax.Array,
                      weights: jax.Array | None = None):
    """Bagged embedding sum: table (V,D), idx (B, L) -> (B, D).

    idx < 0 entries are masked out (padding); optional per-sample weights.
    """
    mask = (idx >= 0)
    safe = jnp.maximum(idx, 0)
    rows = jnp.take(table, safe, axis=0)              # (B, L, D)
    w = mask.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    return jnp.sum(rows * w[..., None], axis=1)
