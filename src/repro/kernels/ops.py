"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode; on
TPU they compile via Mosaic.  Wrappers handle padding to hardware-aligned
tiles (lanes = multiples of 128 on TPU) and expose plain array APIs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import topk as _topk
from repro.kernels import embedding_bag as _bag


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, axis: int, mult: int, fill):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bc", "interpret"))
def _topk_update_jit(vals, ids, scores, chunk_ids, bq, bc, interpret):
    return _topk.topk_update_pallas(
        vals, ids, scores, chunk_ids, bq=bq, bc=bc, interpret=interpret)


def topk_update(vals, ids, scores, chunk_ids, *, bq: int = 128,
                bc: int = 512, interpret: bool | None = None):
    """FastResultHeapq merge: (Q,k) state x (Q,C) chunk -> (Q,k) state."""
    interpret = _default_interpret() if interpret is None else interpret
    q, k = vals.shape
    scores = _pad_axis(jnp.asarray(scores, jnp.float32), 1, 128,
                       _topk.NEG_INF)
    chunk_ids = _pad_axis(jnp.asarray(chunk_ids, jnp.int32), 0, 128, -1)
    vals_p = _pad_axis(jnp.asarray(vals, jnp.float32), 0, 8, _topk.NEG_INF)
    ids_p = _pad_axis(jnp.asarray(ids, jnp.int32), 0, 8, -1)
    out_v, out_i = _topk_update_jit(
        vals_p, ids_p, _pad_axis(scores, 0, 8, _topk.NEG_INF), chunk_ids,
        bq, min(bc, scores.shape[1]), interpret)
    return out_v[:q], out_i[:q]


@functools.partial(jax.jit,
                   static_argnames=("k", "bq", "bn", "interpret"))
def _fused_jit(queries, docs, id_offset, k, bq, bn, interpret):
    out_v, out_i = _topk.fused_score_topk_pallas(
        queries, docs, k, id_offset=0, bq=bq, bn=bn, interpret=interpret)
    # id_offset is applied outside the kernel as a *traced* scalar: the
    # evaluator's streaming search passes a different offset per corpus
    # chunk, which must not recompile the kernel each time.
    return out_v, jnp.where(out_i >= 0, out_i + id_offset, -1)


def fused_score_topk(queries, docs, k: int, *, id_offset=0,
                     bq: int = 128, bn: int = 512,
                     interpret: bool | None = None):
    """Top-k of queries @ docs.T with no HBM score matrix (beyond-paper)."""
    interpret = _default_interpret() if interpret is None else interpret
    q = queries.shape[0]
    queries_p = _pad_axis(jnp.asarray(queries), 0, 8, 0.0)
    docs = jnp.asarray(docs)
    out_v, out_i = _fused_jit(queries_p, docs,
                              jnp.asarray(id_offset, jnp.int32), k, bq,
                              min(bn, max(docs.shape[0], 8)), interpret)
    return out_v[:q], out_i[:q]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def _bag_jit(table, idx, weights, bq, interpret):
    return _bag.embedding_bag_pallas(
        table, idx, weights, bq=bq, interpret=interpret)


def embedding_bag(table, idx, weights=None, *, bq: int = 256,
                  interpret: bool | None = None):
    """Fused gather+reduce EmbeddingBag; idx < 0 = padding."""
    interpret = _default_interpret() if interpret is None else interpret
    b = idx.shape[0]
    idx_p = _pad_axis(jnp.asarray(idx, jnp.int32), 0, 8, -1)
    if weights is not None:
        weights = _pad_axis(jnp.asarray(weights), 0, 8, 0.0)
    else:
        weights = jnp.ones(idx_p.shape, table.dtype)
    out = _bag_jit(table, idx_p, weights, bq, interpret)
    return out[:b]
