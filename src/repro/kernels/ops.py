"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode; on
TPU they compile via Mosaic.  Wrappers handle padding to hardware-aligned
tiles (lanes = multiples of 128 on TPU) and expose plain array APIs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import topk as _topk
from repro.kernels import embedding_bag as _bag


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, axis: int, mult: int, fill):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _topk_update_fn(vals, ids, scores, chunk_ids, bq, bc, interpret):
    return _topk.topk_update_pallas(
        vals, ids, scores, chunk_ids, bq=bq, bc=bc, interpret=interpret)


_topk_update_jit = jax.jit(
    _topk_update_fn, static_argnames=("bq", "bc", "interpret"))
# The donated variant: the kernel already aliases the (Q, k) state in
# place (input_output_aliases), so with donation the same device buffers
# stream through every chunk merge with zero copies.  Only for callers
# that own the state and never touch the input arrays again
# (FastResultHeapq, the superchunk scan executor).
_topk_update_jit_donated = jax.jit(
    _topk_update_fn, static_argnames=("bq", "bc", "interpret"),
    donate_argnums=(0, 1))


def topk_update(vals, ids, scores, chunk_ids, *, bq: int = 128,
                bc: int = 512, interpret: bool | None = None,
                donate: bool = False):
    """FastResultHeapq merge: (Q,k) state x (Q,C) chunk -> (Q,k) state.

    ``donate=True`` hands the ``vals``/``ids`` buffers to the kernel
    (zero-copy in-place merge); the caller must not use them afterwards.
    """
    interpret = _default_interpret() if interpret is None else interpret
    q, k = vals.shape
    scores = _pad_axis(jnp.asarray(scores, jnp.float32), 1, 128,
                       _topk.NEG_INF)
    chunk_ids = _pad_axis(jnp.asarray(chunk_ids, jnp.int32), 0, 128, -1)
    vals_p = _pad_axis(jnp.asarray(vals, jnp.float32), 0, 8, _topk.NEG_INF)
    ids_p = _pad_axis(jnp.asarray(ids, jnp.int32), 0, 8, -1)
    fn = _topk_update_jit_donated if donate else _topk_update_jit
    out_v, out_i = fn(
        vals_p, ids_p, _pad_axis(scores, 0, 8, _topk.NEG_INF), chunk_ids,
        bq, min(bc, scores.shape[1]), interpret)
    return out_v[:q], out_i[:q]


@functools.partial(jax.jit,
                   static_argnames=("k", "bq", "bn", "interpret"))
def _fused_jit(queries, docs, id_offset, k, bq, bn, interpret):
    # id_offset is a *traced* scalar consumed inside the kernel (SMEM
    # scalar block): the streaming search passes a different offset per
    # corpus chunk, which must not recompile the kernel each time.
    return _topk.fused_score_topk_pallas(
        queries, docs, k, id_offset=id_offset, bq=bq, bn=bn,
        interpret=interpret)


def fused_score_topk(queries, docs, k: int, *, id_offset=0,
                     bq: int = 128, bn: int = 512,
                     interpret: bool | None = None):
    """Top-k of queries @ docs.T with no HBM score matrix (beyond-paper)."""
    interpret = _default_interpret() if interpret is None else interpret
    q = queries.shape[0]
    if docs.shape[0] == 0:
        # FairSharder legitimately emits empty shards (total_items <
        # n_workers); an empty corpus slice has a well-defined answer —
        # an empty heap state — not a zero-size pallas grid.
        return (jnp.full((q, k), _topk.NEG_INF, jnp.float32),
                jnp.full((q, k), -1, jnp.int32))
    queries_p = _pad_axis(jnp.asarray(queries), 0, 8, 0.0)
    docs = jnp.asarray(docs)
    out_v, out_i = _fused_jit(queries_p, docs,
                              jnp.asarray(id_offset, jnp.int32), k, bq,
                              min(bn, max(docs.shape[0], 8)), interpret)
    return out_v[:q], out_i[:q]


# -- superchunk scan executor -------------------------------------------------
#
# One jitted dispatch folds a whole (S, C, d) superchunk of corpus
# embeddings into the running (Q, k) top-k state: lax.scan over the chunk
# axis runs score + top-k-merge entirely on device, with the heap state
# donated between steps (zero-copy carry) and the per-step id_offset /
# n_valid traced through the scan xs — no recompiles across superchunks
# and no host materialization until finalize().  This is what collapses
# the per-chunk Python + jit-dispatch storm (ShardedSearchDriver pays one
# dispatch per superchunk instead of one per encode_batch_size chunk).


@functools.partial(jax.jit,
                   static_argnames=("k", "score", "merge", "interpret"),
                   donate_argnums=(0, 1))
def _superchunk_scan_jit(vals, ids, queries, tile, offsets, n_valids, k,
                         score, merge, interpret):
    c = tile.shape[1]

    def step(carry, xs):
        v, i = carry
        docs, off, nv = xs
        if score == "pallas_fused":
            # in-kernel score+top-k: each chunk arrives pre-reduced to
            # (Q, k); merge exactly like FastResultHeapq.merge_arrays
            cand_v, cand_i = _topk.fused_score_topk_pallas(
                queries, docs, k, id_offset=off, n_valid=nv,
                bq=128, bn=min(512, max(c, 8)), interpret=interpret)
            cand_v = jnp.where(jnp.isnan(cand_v), _topk.NEG_INF, cand_v)
            cv = jnp.concatenate([v, cand_v], axis=1)
            ci = jnp.concatenate([i, cand_i], axis=1)
            top_v, pos = jax.lax.top_k(cv, k)
            return (top_v, jnp.take_along_axis(ci, pos, axis=1)), None
        # score == "jax": device matmul, then the heap-impl merge
        scores = jax.lax.dot_general(
            queries, docs, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (Q, C)
        iota = jnp.arange(c, dtype=jnp.int32)
        valid = iota < nv
        scores = jnp.where(valid[None, :], scores, _topk.NEG_INF)
        scores = jnp.where(jnp.isnan(scores), _topk.NEG_INF, scores)
        cids = jnp.where(valid, iota + off, -1)
        if merge == "pallas":
            v, i = _topk.topk_update_pallas(
                v, i, scores, cids, bq=min(128, v.shape[0]),
                bc=min(512, c), interpret=interpret)
            return (v, i), None
        cv = jnp.concatenate([v, scores], axis=1)
        ci = jnp.concatenate(
            [i, jnp.broadcast_to(cids[None, :], scores.shape)], axis=1)
        top_v, pos = jax.lax.top_k(cv, k)
        return (top_v, jnp.take_along_axis(ci, pos, axis=1)), None

    (vals, ids), _ = jax.lax.scan(
        step, (vals, ids), (tile, offsets, n_valids))
    return vals, ids


def superchunk_update(vals, ids, queries, tile, offsets, n_valids, *,
                      k: int, score: str = "jax", merge: str = "jax",
                      interpret: bool | None = None):
    """Fold an (S, C, d) superchunk into the (Q, k) state in ONE dispatch.

    ``vals``/``ids`` are DONATED — callers must hold onto the returned
    state instead.  ``offsets``/``n_valids`` are per-step (S,) int32:
    each chunk's global corpus offset and its count of valid rows (tail
    chunks are padded up to C rows; padded steps use ``n_valid == 0``).
    ``score`` selects matmul vs in-kernel fused scoring, ``merge``
    selects the jnp vs pallas top-k merge — mirroring the per-chunk
    backends bit for bit.
    """
    interpret = _default_interpret() if interpret is None else interpret
    assert queries.shape[0] == vals.shape[0], (queries.shape, vals.shape)
    tile = jnp.asarray(tile, jnp.float32)
    if not interpret:
        # lane-align the chunk axis for Mosaic; padded rows are masked by
        # n_valid (interpret mode skips this — no alignment constraint)
        tile = _pad_axis(tile, 1, 128, 0.0)
    return _superchunk_scan_jit(
        vals, ids, jnp.asarray(queries, jnp.float32), tile,
        jnp.asarray(offsets, jnp.int32), jnp.asarray(n_valids, jnp.int32),
        k, score, merge, interpret)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def _bag_jit(table, idx, weights, bq, interpret):
    return _bag.embedding_bag_pallas(
        table, idx, weights, bq=bq, interpret=interpret)


def embedding_bag(table, idx, weights=None, *, bq: int = 256,
                  interpret: bool | None = None):
    """Fused gather+reduce EmbeddingBag; idx < 0 = padding."""
    interpret = _default_interpret() if interpret is None else interpret
    b = idx.shape[0]
    idx_p = _pad_axis(jnp.asarray(idx, jnp.int32), 0, 8, -1)
    if weights is not None:
        weights = _pad_axis(jnp.asarray(weights), 0, 8, 0.0)
    else:
        weights = jnp.ones(idx_p.shape, table.dtype)
    out = _bag_jit(table, idx_p, weights, bq, interpret)
    return out[:b]
