"""RetrievalTrainer: the pjit training loop (paper §3.4, scaled out).

Features:
  * gradient accumulation (``lax.scan`` over microbatches inside the step)
  * global-norm clipping, AdamW/Adafactor, LR schedule
  * mesh-sharded state (FSDP/TP logical rules) with donated buffers
  * atomic/async checkpointing + resume; elastic restore to a new mesh
  * fault tolerance: resilient step loop, heartbeat, preemption guard
  * optional explicit-DP mode (``dp_mode="shard_map"``) with compressed
    gradient all-reduce (bf16 / int8 + error feedback)
  * training-time IR metrics on a dev set (IRMetrics, paper §3.4)
"""

from __future__ import annotations

import os
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.config import RetrievalTrainingArguments
from repro.core.metrics import IRMetrics
from repro.sharding.partitioning import AxisRules, data_axes
from repro.training import checkpoint as ckpt
from repro.training import grad_compression as gc
from repro.training.fault_tolerance import (Heartbeat, PreemptionGuard,
                                            resilient_loop)
from repro.training.optimizer import (OptimizerConfig, clip_by_global_norm,
                                      make_optimizer)


class RetrievalTrainer:
    def __init__(self, retriever, args: RetrievalTrainingArguments,
                 collator=None, train_dataset=None,
                 loss_fn: Callable | None = None,
                 dev_dataset=None,
                 compute_metrics: IRMetrics | None = None,
                 mesh=None, rules: AxisRules | None = None,
                 batch_spec_fn: Callable | None = None,
                 dp_mode: str = "pjit"):
        self.retriever = retriever
        self.args = args
        self.collator = collator
        self.train_dataset = train_dataset
        self.dev_dataset = dev_dataset
        self.compute_metrics = compute_metrics
        self.mesh = mesh
        self.rules = rules or (retriever.encoder.axis_rules()
                               if retriever is not None and
                               hasattr(retriever, "encoder") else AxisRules())
        self.dp_mode = dp_mode
        if retriever is not None:
            retriever.aux_loss_weight = args.aux_loss_weight
        self._ctx = (mesh, self.rules) if mesh is not None else None
        self.loss_fn = loss_fn or (
            lambda p, b: retriever.forward(p, b, self._ctx))
        self.opt_cfg = OptimizerConfig(
            name=args.optimizer, learning_rate=args.learning_rate,
            weight_decay=args.weight_decay, warmup_steps=args.warmup_steps,
            total_steps=args.max_steps, grad_clip=args.grad_clip)
        self.opt_init, self.opt_update = make_optimizer(self.opt_cfg)
        self.ckpt_mgr = ckpt.CheckpointManager(
            os.path.join(args.output_dir, "checkpoints"),
            save_every=args.checkpoint_every, keep=args.keep_checkpoints,
            async_save=args.async_checkpoint)
        self._step_jit = None
        self.logs: list[dict] = []

    # -- state -------------------------------------------------------------
    def init_state(self, rng=None) -> dict:
        rng = jax.random.key(self.args.seed) if rng is None else rng
        params = self.retriever.init_params(rng)
        # rng stored as raw key data (uint32) so it checkpoints as numpy
        state = {"step": jnp.zeros((), jnp.int32), "params": params,
                 "opt": self.opt_init(params),
                 "rng": jax.random.key_data(
                     jax.random.key(self.args.seed + 1))}
        if self.args.grad_compression == "int8":
            state["ef"] = gc.init_error_feedback(params)
        if self.mesh is not None:
            state = jax.device_put(state, self.state_shardings(state))
        return state

    def state_shardings(self, state) -> Any:
        """NamedShardings for the train state under the logical rules.

        Optimizer state mirrors parameter sharding (ZeRO-3); adafactor's
        factored vr/vc drop the corresponding spec dims.
        """
        if self.mesh is None:
            return None
        p_axes = self.retriever.param_logical_axes()

        def pspec(leaf, axes):
            return self.rules.spec_for(axes, leaf.shape, self.mesh)

        param_specs = jax.tree.map(
            pspec, state["params"], p_axes,
            is_leaf=lambda x: hasattr(x, "shape"))
        rep = P()
        opt = state["opt"]
        if "mu" in opt:                       # adamw
            opt_specs = {"mu": param_specs, "nu": param_specs}
        else:                                 # adafactor
            def fac(spec, v_dict):
                spec_t = tuple(spec)
                out = {}
                for k in v_dict:
                    if k == "v":
                        out[k] = P(*spec_t)
                    elif k == "vr":
                        out[k] = P(*spec_t[:-1])
                    else:                     # vc
                        out[k] = P(*(spec_t[:-2] + spec_t[-1:]))
                return out
            opt_specs = {"v": jax.tree.map(
                fac, param_specs, opt["v"],
                is_leaf=lambda x: isinstance(x, dict) and (
                    "v" in x or "vr" in x))}
        specs = {"step": rep, "params": param_specs, "opt": opt_specs,
                 "rng": rep}
        if "ef" in state:
            specs["ef"] = param_specs
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))

    def batch_sharding(self):
        """Batch arrays sharded over the data axes on dim 0."""
        if self.mesh is None:
            return None
        axes = data_axes(self.mesh)
        return NamedSharding(self.mesh, P(axes if axes else None))

    # -- train step ----------------------------------------------------------
    def _build_step(self, example_batch):
        accum = self.args.grad_accum_steps

        def loss_and_metrics(params, batch):
            out = self.loss_fn(params, batch)
            if isinstance(out, tuple):
                return out[0], out[1]
            return out, {}

        def grads_of(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_and_metrics, has_aux=True)(params, batch)
            return loss, metrics, grads

        def step_fn(state, batch):
            params = state["params"]
            if accum > 1:
                def micro(carry, mb):
                    loss, metrics, grads = grads_of(params, mb)
                    acc_g, acc_l = carry
                    acc_g = jax.tree.map(jnp.add, acc_g, grads)
                    return (acc_g, acc_l + loss), metrics
                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss_sum), metrics = jax.lax.scan(
                    micro, (zero, jnp.float32(0.0)), batch)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = loss_sum / accum
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            else:
                loss, metrics, grads = grads_of(params, batch)

            if self.dp_mode == "shard_map" and self.mesh is not None:
                grads, state = self._compressed_sync(grads, state)

            grads, gnorm = clip_by_global_norm(grads, self.opt_cfg.grad_clip)
            new_params, new_opt = self.opt_update(
                grads, state["opt"], params, state["step"])
            new_rng = jax.random.key_data(jax.random.fold_in(
                jax.random.wrap_key_data(state["rng"]), 0))
            new_state = dict(state)
            new_state.update(step=state["step"] + 1, params=new_params,
                             opt=new_opt, rng=new_rng)
            metrics = dict(metrics)
            metrics.update(loss=loss, grad_norm=gnorm)
            return new_state, metrics

        return jax.jit(step_fn, donate_argnums=0)

    def _compressed_sync(self, grads, state):
        """Explicit-DP gradient sync with compression (inside shard_map
        this would psum; under single-device tests it is the identity +
        error-feedback bookkeeping)."""
        method = self.args.grad_compression
        if method == "none":
            return grads, state
        if method == "bf16":
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
            return grads, state
        if method == "int8":
            new_state = dict(state)

            def one(g, e):
                g = g.astype(jnp.float32) + e
                q, scale = gc.quantize_int8(g)
                deq = gc.dequantize_int8(q, scale)
                return deq, g - deq
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(state["ef"])
            pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
            new_state["ef"] = jax.tree.unflatten(
                tdef, [p[1] for p in pairs])
            return jax.tree.unflatten(tdef, [p[0] for p in pairs]), new_state
        raise ValueError(method)

    # -- data ------------------------------------------------------------------
    def _batches(self, rng: np.random.Generator) -> Iterator[dict]:
        n = len(self.train_dataset)
        bsz = self.args.per_device_batch_size * max(
            1, len(jax.devices()) if self.mesh is not None else 1)
        accum = self.args.grad_accum_steps
        while True:
            idx = rng.integers(0, n, size=bsz * accum)
            feats = [self.train_dataset[int(i)] for i in idx]
            batch = self.collator(feats)
            if accum > 1:
                batch = jax.tree.map(
                    lambda x: np.reshape(
                        x, (accum, x.shape[0] // accum) + x.shape[1:]),
                    batch)
            yield batch

    # -- main loop ---------------------------------------------------------------
    def train(self, state: dict | None = None,
              inject_failure_at: int | None = None) -> dict:
        args = self.args
        os.makedirs(args.output_dir, exist_ok=True)
        if state is None:
            state = self.init_state()
        restored, rstep = self.ckpt_mgr.restore_latest(
            jax.tree.map(np.asarray, state),
            self.state_shardings(state))
        if restored is not None:
            state = restored
        if self._step_jit is None:
            self._step_jit = self._build_step(None)

        rng = np.random.default_rng(args.seed)
        batches = self._batches(rng)
        box = {"state": state}
        t_start = time.monotonic()

        def do_step(step: int):
            batch = next(batches)
            if inject_failure_at is not None and step == inject_failure_at:
                raise RuntimeError(f"injected failure at step {step}")
            box["state"], metrics = self._step_jit(box["state"], batch)
            if step % args.log_every == 0 or step == args.max_steps - 1:
                rec = {k: float(v) for k, v in metrics.items()}
                rec.update(step=step,
                           wall=time.monotonic() - t_start)
                if self.dev_dataset is not None and self.compute_metrics:
                    rec.update(self._dev_metrics(box["state"]["params"]))
                self.logs.append(rec)
            if self.ckpt_mgr.should_save(step):
                self.ckpt_mgr.save(step, box["state"])
            hb.update(step)
            if guard.should_exit:
                self.ckpt_mgr.save(step, box["state"], blocking=True)
                raise SystemExit(0)

        def on_failure(exc):
            restored, rstep = self.ckpt_mgr.restore_latest(
                jax.tree.map(np.asarray, box["state"]),
                self.state_shardings(box["state"]))
            if restored is None:
                box["state"] = self.init_state()
                return 0
            box["state"] = restored
            return rstep + 1

        start = int(jax.device_get(state["step"]))
        with Heartbeat(os.path.join(args.output_dir, "heartbeat.json")) \
                as hb, PreemptionGuard() as guard:
            resilient_loop(do_step, start, args.max_steps, on_failure)
        self.ckpt_mgr.save(args.max_steps, box["state"], blocking=True)
        self.ckpt_mgr.wait()
        return box["state"]

    # -- training-time IR metrics (paper §3.4) -------------------------------------
    def _dev_metrics(self, params) -> dict:
        groups = self.dev_dataset
        feats = groups if isinstance(groups, list) else groups.dev_groups(32)
        batch = self.collator(feats)
        q = self.retriever.encode_query(params, batch["query"], self._ctx)
        p = self.retriever.encode_passage(params, batch["passage"],
                                          self._ctx)
        nq = q.shape[0]
        p = p.reshape(nq, -1, p.shape[-1])
        scores = np.asarray(jnp.einsum("qd,qgd->qg", q, p))
        labels = batch.get("labels")
        if labels is None:
            labels = np.zeros(scores.shape, np.float32)
            labels[:, 0] = 1.0
        return self.compute_metrics(scores, np.asarray(labels))
