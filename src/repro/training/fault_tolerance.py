"""Fault-tolerance utilities: heartbeat, preemption handling, retry loop.

At 1000+ node scale, node loss and preemption are routine.  The posture:
  * every process emits a heartbeat file an external watchdog can monitor;
  * SIGTERM (preemption notice) triggers checkpoint-and-exit at the next
    step boundary;
  * transient step failures restore the last checkpoint and continue
    (``resilient_loop``), re-forming the mesh if the device set changed
    (elastic restore path in ``checkpoint.restore_checkpoint``).
"""

from __future__ import annotations

import json
import os
import signal
import threading
import time
from typing import Callable


class Heartbeat:
    """Periodic liveness signal from a background thread.

    The default sink writes a heartbeat *file* (atomic tmp+replace) for
    an external watchdog.  ``sink`` swaps that for any callable taking
    the payload dict — the serving stack's
    :class:`repro.core.faults.WorkerHealth` wires its per-worker beat in
    here, so training and serving share one heartbeat implementation.
    """

    def __init__(self, path: str | None = None, interval: float = 10.0,
                 sink: Callable[[dict], None] | None = None):
        if path is None and sink is None:
            raise ValueError("Heartbeat needs a path or a sink")
        self.path = path
        self.interval = interval
        self.sink = sink if sink is not None else self._write_file
        self._stop = threading.Event()
        self._step = 0
        self._thread: threading.Thread | None = None

    def update(self, step: int):
        self._step = step

    def _run(self):
        while not self._stop.wait(self.interval):
            self._emit()

    def _emit(self):
        self.sink({"step": self._step, "time": time.time(),
                   "pid": os.getpid()})

    def _write_file(self, payload: dict):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)

    def __enter__(self):
        self._emit()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="heartbeat")
        self._thread.start()
        return self

    def __exit__(self, *a):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)
        self._emit()
        return False


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a polled ``should_exit`` flag so the
    train loop can checkpoint at a clean step boundary before exiting."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_exit = False
        self._signals = signals
        self._old = {}

    def _handler(self, signum, frame):
        self.should_exit = True

    def __enter__(self):
        for s in self._signals:
            try:
                self._old[s] = signal.signal(s, self._handler)
            except ValueError:          # non-main thread (tests)
                pass
        return self

    def __exit__(self, *a):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False


def resilient_loop(step_fn: Callable[[int], None], start_step: int,
                   end_step: int,
                   on_failure: Callable[[BaseException], int],
                   max_failures: int = 3):
    """Run ``step_fn(step)`` for each step; on exception call
    ``on_failure(exc) -> resume_step`` (restore from checkpoint) and
    continue, up to ``max_failures`` consecutive failures."""
    step = start_step
    failures = 0
    while step < end_step:
        try:
            step_fn(step)
            step += 1
            failures = 0
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException as e:      # noqa: BLE001 — deliberate catch-all
            failures += 1
            if failures > max_failures:
                raise
            step = on_failure(e)
    return step
