"""Optimizers in pure JAX: AdamW + Adafactor (factored, for frontier MoE).

Functional API: ``init(params) -> state``, ``update(grads, state, params,
step) -> (new_params, new_state)``.  Optimizer state mirrors parameter
sharding (ZeRO-3 under FSDP rules: states live on the same shards as
their parameters).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    learning_rate: float = 1e-3
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    warmup_steps: int = 0
    total_steps: int = 0            # >0: cosine decay to 10%
    grad_clip: float = 1.0
    # adafactor
    min_dim_size_to_factor: int = 128


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    lr = jnp.float32(cfg.learning_rate)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    if cfg.total_steps > 0:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
        lr = lr * (0.55 + 0.45 * jnp.cos(jnp.pi * frac))
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(cfg: OptimizerConfig, params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params)}


def adamw_update(cfg: OptimizerConfig, grads, state, params, step):
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        u = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new = [upd(g, m, n, p)
           for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    return (jax.tree.unflatten(tdef, [x[0] for x in new]),
            {"mu": jax.tree.unflatten(tdef, [x[1] for x in new]),
             "nu": jax.tree.unflatten(tdef, [x[2] for x in new])})


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moment, no momentum.
# O(n+m) state for (n,m) matrices: the only tractable optimizer for the
# 400B-class MoE configs.
# ---------------------------------------------------------------------------

def _factored(cfg: OptimizerConfig, shape) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor
            and shape[-2] >= cfg.min_dim_size_to_factor)


def adafactor_init(cfg: OptimizerConfig, params) -> dict:
    def make(p):
        if _factored(cfg, p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(make, params,
                              is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(cfg: OptimizerConfig, grads, state, params, step):
    lr = schedule(cfg, step)
    b2 = 1.0 - (step + 1.0) ** -0.8          # decaying beta2 (paper)
    eps = 1e-30

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if "vr" in v:
            vr = b2 * v["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * v["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True
                                         )[..., None] * vc[..., None, :])
            nv = {"vr": vr, "vc": vc}
        else:
            vf = b2 * v["v"] + (1 - b2) * g2
            denom = jnp.sqrt(vf)
            nv = {"v": vf}
        u = g / jnp.maximum(denom, 1e-30)
        # update clipping (RMS(u) <= 1)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_v = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_v)
    new = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    vdef = jax.tree.structure(state["v"], is_leaf=is_v)
    return (jax.tree.unflatten(tdef, [x[0] for x in new]),
            {"v": jax.tree.unflatten(vdef, [x[1] for x in new])})


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return (lambda p: adamw_init(cfg, p),
                lambda g, s, p, t: adamw_update(cfg, g, s, p, t))
    if cfg.name == "adafactor":
        return (lambda p: adafactor_init(cfg, p),
                lambda g, s, p, t: adafactor_update(cfg, g, s, p, t))
    raise ValueError(cfg.name)


def opt_state_logical_axes(cfg: OptimizerConfig, param_axes) -> Any:
    """Optimizer-state logical axes mirroring the parameters (ZeRO-3)."""
    if cfg.name == "adamw":
        return {"mu": param_axes, "nu": param_axes}

    def make(axes):
        axes = tuple(axes)
        # factored states drop one dim; replicate them (they are tiny)
        return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:],
                "v": axes}

    # NOTE: factored-vs-not is shape-dependent; resolved at tree_map time
    # in the trainer against the concrete opt state.
    return {"v": param_axes}
