"""Fault-tolerant checkpointing: atomic, sharded, async, elastic.

Layout (one directory per step):
    step_000123/
      manifest.json      tree paths, shapes, dtypes, step, save-time
      arrays.npz         leaf arrays keyed by escaped tree path

Guarantees:
  * atomic   — built in a tmp dir, ``os.replace``d into place; a crash
               mid-save never corrupts the latest checkpoint.
  * async    — ``CheckpointManager(async_save=True)`` snapshots to host
               memory synchronously and writes on a background thread
               (overlaps I/O with the next train steps).
  * elastic  — ``restore`` takes target shardings for ANY mesh; arrays are
               ``device_put`` against the new topology (node loss =>
               re-form a smaller mesh, restore, continue).
  * multi-host — each process writes shards it owns (addressable_shards)
               under a process suffix; on this single-process container
               that degenerates to full arrays.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.data.table import atomic_write_dir


def _escape(path: tuple) -> str:
    parts = []
    for p in path:
        key = getattr(p, "key", getattr(p, "idx", None))
        parts.append(str(key))
    return "/".join(parts)


def tree_to_flat(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_escape(path): np.asarray(leaf) for path, leaf in flat}


def flat_to_tree(template: Any, flat: dict[str, Any]) -> Any:
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    tdef = jax.tree.structure(template)
    leaves = []
    for path, tmpl in paths_leaves:
        key = _escape(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(flat[key])
    return jax.tree.unflatten(tdef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, state: Any) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat = tree_to_flat(state)
    with atomic_write_dir(path) as tmp:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    return path


def latest_checkpoint(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [d for d in os.listdir(ckpt_dir)
             if re.fullmatch(r"step_\d+", d)
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    if not steps:
        return None
    return os.path.join(ckpt_dir, max(steps))


def restore_checkpoint(path: str, template: Any,
                       shardings: Any | None = None) -> Any:
    """Restore into ``template``'s structure; reshard to ``shardings``.

    ``shardings`` may target a completely different mesh than the one the
    checkpoint was saved under (elastic scaling).
    """
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = flat_to_tree(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return int(json.load(f)["step"])


class CheckpointManager:
    """save-every-N / keep-M manager with async background writes."""

    def __init__(self, ckpt_dir: str, save_every: int = 100,
                 keep: int = 2, async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, state: Any, blocking: bool | None = None):
        self.wait()
        if self._error:
            raise self._error
        # snapshot to host memory synchronously — the device buffers may be
        # donated by the next step
        host_state = jax.tree.map(np.asarray, state)
        if blocking or not self.async_save:
            self._write(step, host_state)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host_state),
                daemon=True)
            self._thread.start()

    def _write_guarded(self, step, host_state):
        try:
            self._write(step, host_state)
        except BaseException as e:     # surfaced on next save()/wait()
            self._error = e

    def _write(self, step, host_state):
        save_checkpoint(self.ckpt_dir, step, host_state)
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.ckpt_dir)
                       if re.fullmatch(r"step_\d+", d))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, template: Any, shardings=None):
        path = latest_checkpoint(self.ckpt_dir)
        if path is None:
            return None, -1
        return (restore_checkpoint(path, template, shardings),
                checkpoint_step(path))
