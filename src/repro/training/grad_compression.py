"""Gradient compression for cross-replica reduction.

Under full-pjit FSDP training, XLA owns the backward all-reduces (bf16
compute already halves wire bytes).  For the explicit data-parallel mode
(``RetrievalTrainer(dp_mode="shard_map")``) this module provides a
compressed all-reduce used *inside* ``shard_map``:

  * bf16  — cast, psum, upcast (2x wire reduction, unbiased)
  * int8  — per-tensor symmetric quantization with error-feedback
            residuals (EF-SGD): 4x wire reduction; the quantization error
            is carried to the next step, preserving convergence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, axis_name: str | tuple, method: str = "none",
                    error_buf=None, n_replicas: int | None = None):
    """All-reduce-mean grads over ``axis_name`` with optional compression.

    Must be called inside shard_map/pmap.  Returns (grads, new_error_buf).
    """
    if n_replicas is None:
        names = axis_name if isinstance(axis_name, tuple) else (axis_name,)
        n_replicas = 1
        for nm in names:
            n_replicas *= jax.lax.axis_size(nm)

    def mean_psum(x):
        return jax.lax.psum(x, axis_name) / n_replicas

    if method == "none":
        return jax.tree.map(mean_psum, grads), error_buf
    if method == "bf16":
        out = jax.tree.map(
            lambda g: mean_psum(g.astype(jnp.bfloat16)).astype(jnp.float32),
            grads)
        return out, error_buf
    if method == "int8":
        assert error_buf is not None, "int8 compression needs error feedback"

        def one(g, e):
            g = g.astype(jnp.float32) + e            # error feedback
            q, scale = quantize_int8(g)
            deq = dequantize_int8(q, scale)
            new_e = g - deq                           # residual carried over
            # wire format: int8 payload + f32 scale (psum of dequantized
            # int8 values is numerically identical to dequant-after-sum
            # with per-replica scales exchanged alongside)
            summed = jax.lax.psum(deq, axis_name) / n_replicas
            return summed, new_e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(error_buf)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(tdef, [p[0] for p in pairs]),
                jax.tree.unflatten(tdef, [p[1] for p in pairs]))
    raise ValueError(method)


def wire_bytes(params, method: str) -> int:
    """Bytes on the wire per all-reduce for reporting/benchmarks."""
    n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    per = {"none": 4, "bf16": 2, "int8": 1}[method]
    return n * per
