"""User-facing dataset classes (paper §3.2.2).

Datasets are composed of one or more :class:`MaterializedQRel` sources,
each with its own on-the-fly processing (filter/relabel/sample), combined
lazily — no pre-processed files, fully VCS-trackable via the configs.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from repro.core.config import DataArguments, MaterializedQRelConfig
from repro.core.materialized_qrel import MaterializedQRel


def _as_mqrels(cfgs, cache_root) -> list[MaterializedQRel]:
    if isinstance(cfgs, (MaterializedQRelConfig, MaterializedQRel)):
        cfgs = [cfgs]
    return [c if isinstance(c, MaterializedQRel)
            else MaterializedQRel(c, cache_root) for c in cfgs]


def _sources_view(sources: Sequence[MaterializedQRel], which: str):
    """Lazy concat view over the sources' query/corpus tables, deduped
    by table path (sources over the same file share one mmap table)."""
    from repro.data.views import ConcatView, TableView
    seen: dict[str, MaterializedQRel] = {}
    for m in sources:
        table = getattr(m, which)
        seen.setdefault(table.path, table)
    views = [TableView(t) for t in seen.values()]
    return views[0] if len(views) == 1 else ConcatView(*views)


class BinaryDataset:
    """Positives + negatives -> (query, [pos, neg...]) training instances."""

    def __init__(self, data_args: DataArguments,
                 format_query: Callable[[str], str],
                 format_passage: Callable[..., str],
                 positives, negatives,
                 cache_root: str = "/tmp/trove_cache", seed: int = 0):
        self.args = data_args
        self.format_query = format_query
        self.format_passage = format_passage
        self.pos = _as_mqrels(positives, cache_root)
        self.neg = _as_mqrels(negatives, cache_root)
        self.seed = seed
        qids = np.unique(np.concatenate(
            [m.query_id_hashes for m in self.pos]))
        # Keep only queries that still have >= 1 positive AFTER each
        # source's on-the-fly processing: a source's id list alone can
        # include queries whose positive group is empty at access time
        # (e.g. group_random_k=0, or per-group filtering), which used to
        # surface as an IndexError mid-epoch instead of a shorter epoch.
        has_pos = np.fromiter(
            (any(len(m.group(int(q))[0]) > 0 for m in self.pos)
             for q in qids), bool, count=len(qids))
        self.qids = qids[has_pos]

    def __len__(self):
        return len(self.qids)

    def corpus_view(self):
        """Lazy combined corpus of all sources (positives + negatives)."""
        return _sources_view(self.pos + self.neg, "corpus")

    def queries_view(self):
        """Lazy combined query table of the positive sources."""
        return _sources_view(self.pos, "queries")

    def __getitem__(self, i: int) -> dict:
        qid = int(self.qids[i])
        rng = np.random.default_rng((self.seed, qid, i))
        pos_dids, _ = self._merged_group(self.pos, qid)
        neg_dids, _ = self._merged_group(self.neg, qid)
        if len(pos_dids) == 0:
            raise IndexError(f"query {qid} has no positives")
        pos_did = int(rng.choice(pos_dids))
        n_neg = self.args.group_size - 1
        negs: list[int] = []
        if n_neg > 0 and len(neg_dids):
            neg_pool = neg_dids[~np.isin(neg_dids, pos_dids)]
            if len(neg_pool) == 0:
                neg_pool = neg_dids
            negs = list(rng.choice(
                neg_pool, size=n_neg, replace=len(neg_pool) < n_neg))
        src = self.pos[0]
        passages = [self.format_passage(src.doc_text(pos_did))]
        for d in negs:
            passages.append(self.format_passage(self._doc_text(int(d))))
        return {
            "query_id": qid,
            "query": self.format_query(src.query_text(qid)),
            "passages": passages,
        }

    def _doc_text(self, did: int) -> str:
        for m in self.pos + self.neg:
            try:
                return m.doc_text(did)
            except KeyError:
                continue
        raise KeyError(did)

    @staticmethod
    def _merged_group(sources: Sequence[MaterializedQRel], qid: int):
        dids, scores = [], []
        for m in sources:
            d, s = m.group(qid)
            dids.append(d)
            scores.append(s)
        return (np.concatenate(dids) if dids else np.empty(0, np.int64),
                np.concatenate(scores) if scores else np.empty(0, np.float32))


class MultiLevelDataset:
    """Graded-relevance instances from multiple processed sources.

    Each source contributes (doc, label) pairs after its own on-the-fly
    processing; per query the dataset samples ``group_size`` docs,
    label-descending with random tie-break, padding labels with -1.
    """

    def __init__(self, data_args: DataArguments,
                 format_query, format_passage, sources,
                 cache_root: str = "/tmp/trove_cache", seed: int = 0):
        self.args = data_args
        self.format_query = format_query
        self.format_passage = format_passage
        self.sources = _as_mqrels(sources, cache_root)
        self.seed = seed
        self.qids = np.unique(np.concatenate(
            [m.query_id_hashes for m in self.sources]))

    def __len__(self):
        return len(self.qids)

    def corpus_view(self):
        """Lazy combined corpus of all sources."""
        return _sources_view(self.sources, "corpus")

    def __getitem__(self, i: int) -> dict:
        qid = int(self.qids[i])
        rng = np.random.default_rng((self.seed, qid, i))
        dids, labels = BinaryDataset._merged_group(self.sources, qid)
        if len(dids) == 0:
            raise IndexError(f"query {qid} has no documents")
        # de-dup docs across sources: keep max label
        order = np.argsort(dids, kind="stable")
        dids, labels = dids[order], labels[order]
        uniq, starts = np.unique(dids, return_index=True)
        max_lab = np.maximum.reduceat(labels, starts)
        g = self.args.group_size
        jitter = rng.random(len(uniq))
        pick = np.lexsort((jitter, -max_lab))[:g]
        sel_d, sel_l = uniq[pick], max_lab[pick]
        passages = [self.format_passage(self._doc_text(int(d)))
                    for d in sel_d]
        out_labels = np.full(g, -1.0, np.float32)
        out_labels[: len(sel_l)] = sel_l
        while len(passages) < g:       # pad short groups
            passages.append(passages[-1])
        return {
            "query_id": qid,
            "query": self.format_query(self._query_text(qid)),
            "passages": passages,
            "labels": out_labels,
        }

    def _query_text(self, qid):
        for m in self.sources:
            try:
                return m.query_text(qid)
            except KeyError:
                continue
        raise KeyError(qid)

    def _doc_text(self, did):
        for m in self.sources:
            try:
                return m.doc_text(did)
            except KeyError:
                continue
        raise KeyError(did)

    def dev_groups(self, n: int | None = None):
        """(query, docs, labels) groups for training-time IR metrics."""
        n = len(self) if n is None else min(n, len(self))
        return [self[i] for i in range(n)]


class EncodingDataset:
    """Items to encode at inference; embedding-cache aware (paper §3.2.2).

    ``dataset[i]`` returns the cached embedding when available, else text.
    """

    def __init__(self, ids: Sequence, texts: Sequence[str] | None = None,
                 table=None, cache=None, format_fn=None):
        self.ids = list(ids)
        self.texts = texts
        self.table = table
        self.cache = cache
        self.format_fn = format_fn or (lambda t: t)

    def __len__(self):
        return len(self.ids)

    def __getitem__(self, i: int) -> dict:
        rid = self.ids[i]
        if self.cache is not None and rid in self.cache:
            return {"id": rid, "embedding": self.cache.get_one(rid)}
        if self.texts is not None:
            text = self.texts[i]
        else:
            rec = self.table.get(rid)
            text = f"{rec.get('title', '')} {rec.get('text', '')}".strip()
        return {"id": rid, "text": self.format_fn(text)}
