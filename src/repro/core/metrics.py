"""IR metrics: nDCG@k, MRR@k, Recall@k, MAP (+ training-time IRMetrics)."""

from __future__ import annotations

import numpy as np


def _parse(name: str) -> tuple[str, int]:
    if "@" in name:
        base, k = name.split("@")
        return base.lower(), int(k)
    return name.lower(), 10


def dcg(rels: np.ndarray) -> np.ndarray:
    discounts = 1.0 / np.log2(np.arange(rels.shape[-1]) + 2.0)
    return ((2.0 ** rels - 1.0) * discounts).sum(-1)


def ranked_relevances(run_ids: np.ndarray, qid_hashes: np.ndarray,
                      qrels: dict[int, dict[int, float]]) -> np.ndarray:
    """(Q, k) relevance grades for ranked doc-id matrix."""
    out = np.zeros(run_ids.shape, np.float32)
    for qi, qid in enumerate(qid_hashes):
        grades = qrels.get(int(qid), {})
        for ri, did in enumerate(run_ids[qi]):
            out[qi, ri] = grades.get(int(did), 0.0)
    return out


def compute_metrics(metric_names, run_ids, qid_hashes, qrels) -> dict:
    """run_ids (Q, depth) ranked doc hashes; qrels {qid: {did: grade}}."""
    rels = ranked_relevances(run_ids, qid_hashes, qrels)
    n_rel = np.asarray(
        [sum(1 for g in qrels.get(int(q), {}).values() if g > 0)
         for q in qid_hashes], np.float32)
    ideal = [np.sort([g for g in qrels.get(int(q), {}).values() if g > 0]
                     )[::-1] for q in qid_hashes]
    out = {}
    for name in metric_names:
        base, k = _parse(name)
        rk = rels[:, :k]
        if base == "ndcg":
            idcg = np.asarray([dcg(i[:k][None])[0] if len(i) else 0.0
                               for i in ideal])
            val = np.where(idcg > 0, dcg(rk) / np.maximum(idcg, 1e-9), 0.0)
        elif base == "mrr":
            hit = rk > 0
            first = np.argmax(hit, axis=1)
            any_hit = hit.any(axis=1)
            val = np.where(any_hit, 1.0 / (first + 1.0), 0.0)
        elif base == "recall":
            # a query with zero relevant qrels (possible after suite
            # filtering) contributes recall 0, never a 0/0 division
            val = np.where(n_rel > 0, (rk > 0).sum(1) / np.maximum(n_rel, 1),
                           0.0)
        elif base == "map":
            hit = (rk > 0).astype(np.float32)
            prec = np.cumsum(hit, 1) / (np.arange(rk.shape[1]) + 1.0)
            val = np.where(n_rel > 0,
                           (prec * hit).sum(1) / np.maximum(n_rel, 1), 0.0)
        else:
            raise ValueError(name)
        out[name] = float(val.mean())
    return out


class IRMetrics:
    """Training-time approximate IR metrics (paper §3.4).

    Ranks each dev query's own annotated group (a reranking task) — cheap
    enough to run inside the train loop as ``compute_metrics``.
    Call with (scores (Q, G), labels (Q, G); label -1 == padding).
    """

    def __init__(self, metric_names=("ndcg@10", "mrr@10")):
        self.metric_names = metric_names

    def __call__(self, scores: np.ndarray, labels: np.ndarray) -> dict:
        scores = np.asarray(scores, np.float32)
        labels = np.asarray(labels, np.float32)
        mask = labels >= 0
        scores = np.where(mask, scores, -np.inf)
        order = np.argsort(-scores, axis=1)
        ranked = np.take_along_axis(np.where(mask, labels, 0.0), order, 1)
        out = {}
        for name in self.metric_names:
            base, k = _parse(name)
            rk = ranked[:, :k]
            if base == "ndcg":
                ideal = -np.sort(-np.where(mask, labels, 0.0), axis=1)[:, :k]
                idcg = dcg(ideal)
                val = np.where(idcg > 0, dcg(rk) / np.maximum(idcg, 1e-9), 0.0)
            elif base == "mrr":
                hit = rk > 0
                first = np.argmax(hit, 1)
                val = np.where(hit.any(1), 1.0 / (first + 1.0), 0.0)
            else:
                raise ValueError(f"IRMetrics supports ndcg/mrr, got {name}")
            out[name] = float(val.mean())
        return out
