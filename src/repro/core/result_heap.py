"""FastResultHeapq: streaming top-k tracking with matrix ops (paper §3.5).

Replaces Python's ``heapq`` (the paper's 16x-600x baseline) with a fixed
(Q, k) buffer merged against each incoming score chunk via batched top-k.
Three interchangeable impls:

  * ``python``  — the heapq baseline the paper benchmarks against
  * ``jax``     — jnp concat + lax.top_k (the paper's torch analogue)
  * ``pallas``  — fused streaming-merge TPU kernel (repro.kernels)

All return identical results (tested); the evaluator selects via
``EvaluationArguments.heap_impl``.
"""

from __future__ import annotations

import heapq
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = jnp.float32(-jnp.inf)


@partial(jax.jit, static_argnames=("k",), donate_argnums=(0, 1))
def _update_jax(vals, ids, scores, chunk_ids, k: int):
    # NaN scores mean "never retrieve" (see class docstring): sanitize to
    # -inf so lax.top_k's NaN ordering can't differ from the other impls
    scores = jnp.where(jnp.isnan(scores), NEG_INF, scores)
    cand_v = jnp.concatenate([vals, scores.astype(jnp.float32)], axis=1)
    cand_i = jnp.concatenate(
        [ids, jnp.broadcast_to(chunk_ids[None, :],
                               scores.shape).astype(ids.dtype)], axis=1)
    top_v, pos = jax.lax.top_k(cand_v, k)
    top_i = jnp.take_along_axis(cand_i, pos, axis=1)
    return top_v, top_i


@partial(jax.jit, static_argnames=("k",), donate_argnums=(0, 1))
def _merge_arrays_jax(vals, ids, cand_v, cand_i, k: int):
    # one dispatch per merge instead of an eager where/concat/top_k/take
    # op storm; the running state buffers are donated (selection ops
    # only — no float arithmetic — so jit changes nothing numerically)
    cand_v = jnp.where(jnp.isnan(cand_v), NEG_INF, cand_v)
    cv = jnp.concatenate([vals, cand_v], axis=1)
    ci = jnp.concatenate([ids, cand_i], axis=1)
    top_v, pos = jax.lax.top_k(cv, k)
    return top_v, jnp.take_along_axis(ci, pos, axis=1)


@jax.jit
def _finalize_sort(vals, ids):
    order = jnp.argsort(-vals, axis=1)
    return (jnp.take_along_axis(vals, order, 1),
            jnp.take_along_axis(ids, order, 1))


class FastResultHeapq:
    """Tracks top-k (score, doc_id) per query over streamed score chunks.

    Device-side ids are int32 *positions* (e.g. global corpus offsets);
    callers map positions back to raw/hashed ids on the host.  (JAX
    defaults to 32-bit — storing 63-bit id hashes on device would
    silently truncate.)

    NaN and -inf scores are defined to mean "never retrieve": such
    candidates never surface a doc id, in any impl.  (NaN: Python
    float/tuple comparisons and lax.top_k order NaN differently; -inf:
    the device impls can't distinguish a real -inf candidate from an
    empty -inf/-1 buffer slot, so the python impl drops them too —
    without this the impls would diverge on under-filled heaps.)
    """

    HEAP_IMPLS = ("python", "jax", "pallas")

    def __init__(self, n_queries: int, k: int, impl: str = "jax"):
        # fail at construction, not deep in a search round: an unknown
        # impl used to silently run the jax path, and k < 1 only
        # surfaced as a shape error inside lax.top_k
        if impl not in self.HEAP_IMPLS:
            raise ValueError(f"unknown heap impl {impl!r}; expected one "
                             f"of {list(self.HEAP_IMPLS)}")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if n_queries < 0:
            raise ValueError(f"n_queries must be >= 0, got {n_queries}")
        self.k = k
        self.n_queries = n_queries
        self.impl = impl
        if impl == "python":
            self._heaps: list[list[tuple[float, int]]] = [
                [] for _ in range(n_queries)]
        else:
            self.vals = jnp.full((n_queries, k), NEG_INF, jnp.float32)
            self.ids = jnp.full((n_queries, k), -1, jnp.int32)

    def update(self, scores, chunk_ids):
        """scores (Q, C) for C docs with ids chunk_ids (C,)."""
        if self.impl == "python":
            s = np.asarray(scores)
            cid = np.asarray(chunk_ids)
            for q in range(self.n_queries):
                h = self._heaps[q]
                for c in range(s.shape[1]):
                    sc = float(s[q, c])
                    if sc != sc or sc == -np.inf:    # never retrieve
                        continue
                    item = (sc, int(cid[c]))
                    if len(h) < self.k:
                        heapq.heappush(h, item)
                    elif item > h[0]:
                        heapq.heapreplace(h, item)
            return
        if self.impl == "pallas":
            from repro.kernels import ops as kops
            scores = jnp.asarray(scores)
            scores = jnp.where(jnp.isnan(scores), NEG_INF, scores)
            # the heap owns its state arrays and replaces them right
            # here, so the kernel may merge into the donated buffers
            self.vals, self.ids = kops.topk_update(
                self.vals, self.ids, scores, jnp.asarray(chunk_ids),
                donate=True)
            return
        self.vals, self.ids = _update_jax(
            self.vals, self.ids, jnp.asarray(scores),
            jnp.asarray(chunk_ids), self.k)

    def merge_arrays(self, vals, ids):
        """Merge per-query candidate arrays vals (Q, m), ids (Q, m).

        The entry point for fused score+top-k kernel output: each corpus
        chunk already arrives reduced to (Q, k') on device, and merges
        here without constructing a throwaway heap object.  ``ids`` < 0
        marks empty slots (vals must be -inf there).
        """
        if self.impl == "python":
            v = np.asarray(vals)
            i = np.asarray(ids)
            for q in range(self.n_queries):
                h = self._heaps[q]
                for c in range(v.shape[1]):
                    sc = float(v[q, c])
                    if i[q, c] < 0 or sc != sc or sc == -np.inf:
                        continue
                    item = (sc, int(i[q, c]))
                    if len(h) < self.k:
                        heapq.heappush(h, item)
                    elif item > h[0]:
                        heapq.heapreplace(h, item)
            return
        self.vals, self.ids = _merge_arrays_jax(
            self.vals, self.ids, jnp.asarray(vals, jnp.float32),
            jnp.asarray(ids).astype(self.ids.dtype), self.k)

    def merge(self, other: "FastResultHeapq"):
        """Merge another heap's state (cross-shard top-k reduction)."""
        self.merge_arrays(*other.finalize())

    def adopt_state(self, vals, ids):
        """Install a device-resident (Q, k) state wholesale — the hand-off
        point for the superchunk scan executor, whose donated scan carry
        IS the heap state.  Device impls only."""
        assert self.impl != "python", "python impl has no array state"
        assert vals.shape == (self.n_queries, self.k), vals.shape
        self.vals = jnp.asarray(vals, jnp.float32)
        self.ids = jnp.asarray(ids, jnp.int32)

    def finalize_device(self):
        """Device-side sorted finalize: -> (vals (Q,k) desc, ids int32)
        as device arrays — no host transfer (device impls only; callers
        that need numpy use :meth:`finalize`)."""
        assert self.impl != "python", "python impl finalizes on host"
        return _finalize_sort(self.vals, self.ids)

    def finalize(self):
        """-> (scores (Q,k) desc-sorted, doc_ids (Q,k)); -1 id == empty."""
        if self.impl == "python":
            vals = np.full((self.n_queries, self.k), -np.inf, np.float32)
            ids = np.full((self.n_queries, self.k), -1, np.int64)
            for q, h in enumerate(self._heaps):
                for j, (s, d) in enumerate(sorted(h, reverse=True)):
                    vals[q, j] = s
                    ids[q, j] = d
            return vals, ids
        vals, ids = self.finalize_device()
        return np.asarray(vals), np.asarray(ids, dtype=np.int64)
