"""Fault injection + fault-tolerant shard recovery (the chaos layer).

The distributed search stack (``ShardedSearchDriver`` + ``FairSharder``
+ ``SimulatedCluster``) used to be all-or-nothing: one worker dying
mid-round propagated ``ShardAborted`` to every sibling and the whole
round — including accepted serve requests riding on it — died with it.
This module turns that into *degrade, don't collapse*:

  * :class:`FaultInjector` — a deterministic, schedule- or seed-driven
    injector for every failure mode the stack can hit, so chaos tests
    are reproducible in-process: worker **crash** at round r, **stall**
    (slow chunk loads), gather transport **drop** (a worker's merged
    state never arrives), and **torn cache writes** (crash mid-append /
    between payload and ``meta.json``).
  * :class:`WorkerHealth` — liveness tracking for a W-worker cluster,
    fed by the *same* :class:`repro.training.fault_tolerance.Heartbeat`
    implementation the trainer uses (one heartbeat, two consumers).
  * :class:`ResilientAllGather` — the fault-tolerant replacement for
    ``InMemoryAllGather``: per-round worker deadlines; a missed deadline
    or death notice orphans that worker's shard, which survivors rescore
    (bounded retries + exponential backoff, deterministic assignee) and
    merge **at the dead rank's merge position** — so a recovered round
    is bitwise-equal to the no-fault round (same rows, same kernels,
    same merge order).  When the retry budget or the request deadline is
    exhausted, the round resolves to a *partial* top-k annotated with
    corpus coverage < 1 instead of raising.
  * :class:`SearchOutcome` — a ``(a, b[, c])``-unpackable tuple carrying
    ``coverage`` (per-query fraction of the search space actually
    scored) and a ``degraded`` flag, so every existing call site keeps
    unpacking results while fault-aware callers read the metadata.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np


class InjectedFault(RuntimeError):
    """Base class for scheduled failures raised by :class:`FaultInjector`."""


class InjectedCrash(InjectedFault):
    """A scheduled worker (or cache-write) crash."""


class InjectedTransportDrop(InjectedFault):
    """A scheduled gather-transport loss: the worker survives but its
    merged shard state never reaches its siblings."""


@dataclass(frozen=True)
class Fault:
    """One scheduled failure.  ``None`` fields are wildcards.

    kind : ``crash`` | ``stall`` | ``drop`` | ``torn_write``
    round : search round (the FairSharder's issued round number) the
        fault fires in; ``None`` = any round.
    worker : target rank; ``None`` = any worker.
    phase : ``load`` (primary chunk streaming) | ``retry`` (a survivor
        rescoring an orphaned shard) | ``gather`` | ``cache``.
    chunk : fire on the n-th chunk event of the matching scoring pass
        (crash/stall only); ``None`` = the first.
    point : torn-write location: ``payload`` (between the vector payload
        and the id-index append — a mid-append crash), ``meta``
        (payloads written, ``meta.json`` never replaced), ``tombstone``
        (tombstones appended, meta never replaced), or one of the
        compaction points — ``compact_payload`` (new epoch's payload
        written, meta still names the old epoch), ``compact_meta``
        (catch-up appended, meta not yet replaced), ``compact_swap``
        (meta replaced, old epoch's files not yet retired).
    stall_s : sleep duration for ``stall``.
    repeat : fire on every matching event instead of once.
    """

    kind: str
    round: int | None = None
    worker: int | None = None
    phase: str = "load"
    chunk: int | None = None
    point: str = "payload"
    stall_s: float = 0.25
    repeat: bool = False

    def __post_init__(self):
        if self.kind not in ("crash", "stall", "drop", "torn_write"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.phase not in ("load", "retry", "gather", "cache"):
            raise ValueError(f"unknown fault phase {self.phase!r}")
        if self.point not in ("payload", "meta", "tombstone",
                              "compact_payload", "compact_meta",
                              "compact_swap"):
            raise ValueError(f"unknown torn-write point {self.point!r}")


class FaultInjector:
    """Deterministic fault scheduler.

    Construct with an explicit fault list, or :meth:`from_seed` for a
    seed-derived schedule (same seed → same faults, always).  The stack
    consults the injector at its named fault points (chunk loads, gather
    sends, cache writes); each :class:`Fault` fires once (unless
    ``repeat``) and every firing is recorded in :attr:`fired` for
    assertions.  Thread-safe — one injector may be shared by all workers
    of a simulated cluster.
    """

    def __init__(self, faults=()):
        self.faults = list(faults)
        self.fired: list[tuple] = []
        self._spent: set[int] = set()
        self._lock = threading.Lock()

    @classmethod
    def from_seed(cls, seed: int, n_workers: int, *, n_faults: int = 1,
                  rounds: tuple[int, int] = (0, 4),
                  kinds=("crash", "stall", "drop"),
                  stall_s: float = 0.25) -> "FaultInjector":
        """A reproducible schedule: ``n_faults`` draws of (kind, worker,
        round) from ``default_rng(seed)``."""
        rng = np.random.default_rng(seed)
        faults = [Fault(kind=str(rng.choice(list(kinds))),
                        worker=int(rng.integers(0, n_workers)),
                        round=int(rng.integers(rounds[0], rounds[1])),
                        stall_s=stall_s)
                  for _ in range(n_faults)]
        return cls(faults)

    def _match(self, kind_set, worker, round_no, phase) -> Fault | None:
        with self._lock:
            for idx, f in enumerate(self.faults):
                if f.kind not in kind_set or f.phase != phase:
                    continue
                if f.worker is not None and worker is not None \
                        and f.worker != worker:
                    continue
                if f.round is not None and round_no is not None \
                        and f.round != round_no:
                    continue
                if not f.repeat and idx in self._spent:
                    continue
                self._spent.add(idx)
                self.fired.append((f.kind, worker, round_no, phase))
                return f
        return None

    # -- fault points ---------------------------------------------------------
    def on_chunk(self, worker: int, round_no: int, chunk_index: int,
                 phase: str = "load") -> None:
        """Called before each streamed chunk is scored.  May raise
        :class:`InjectedCrash` (the worker dies here) or sleep (a stalled
        / slow chunk load)."""
        with self._lock:
            candidates = [
                (idx, f) for idx, f in enumerate(self.faults)
                if f.kind in ("crash", "stall") and f.phase == phase
                and (f.worker is None or f.worker == worker)
                and (f.round is None or f.round == round_no)
                and (f.chunk or 0) == chunk_index
                and (f.repeat or idx not in self._spent)]
            if not candidates:
                return
            idx, f = candidates[0]
            self._spent.add(idx)
            self.fired.append((f.kind, worker, round_no, phase))
        if f.kind == "crash":
            raise InjectedCrash(
                f"injected crash: worker {worker} round {round_no} "
                f"chunk {chunk_index} ({phase})")
        time.sleep(f.stall_s)

    def on_gather(self, worker: int, round_no: int) -> None:
        """Called when a worker hands its shard state to the gather
        transport; raises :class:`InjectedTransportDrop` when this
        worker's state is scheduled to be lost in flight."""
        f = self._match(("drop",), worker, round_no, "gather")
        if f is not None:
            raise InjectedTransportDrop(
                f"injected transport drop: worker {worker} round "
                f"{round_no}")

    def on_cache(self, point: str) -> None:
        """Called by :class:`~repro.core.embedding_cache.EmbeddingCache`
        between the write steps of one append / compaction; raises
        :class:`InjectedCrash` (``torn_write`` — a process dying with a
        torn write on disk) or sleeps (``stall`` with ``phase="cache"``
        — a slow disk hanging mid-protocol while readers keep
        serving)."""
        with self._lock:
            hit = None
            for idx, f in enumerate(self.faults):
                if f.kind == "torn_write":
                    pass
                elif f.kind == "stall" and f.phase == "cache":
                    pass
                else:
                    continue
                if f.point != point:
                    continue
                if not f.repeat and idx in self._spent:
                    continue
                self._spent.add(idx)
                self.fired.append((f.kind, None, None, f"cache:{point}"))
                hit = f
                break
        if hit is None:
            return
        if hit.kind == "torn_write":
            raise InjectedCrash(f"injected torn write at cache point "
                                f"{point!r}")
        time.sleep(hit.stall_s)


class SearchOutcome(tuple):
    """A result tuple that still unpacks like the plain tuple every call
    site expects, plus the fault-tolerance metadata riding along:

    ``coverage``  — per-query fraction of the round's search space that
        was actually scored (``1.0`` everywhere on a clean or fully
        recovered round).
    ``degraded``  — True when any coverage < 1 (retry budget or request
        deadline exhausted mid-recovery).
    """

    coverage: np.ndarray | None
    degraded: bool

    def __new__(cls, items, coverage=None, degraded: bool = False):
        self = super().__new__(cls, tuple(items))
        self.coverage = coverage
        self.degraded = bool(degraded)
        return self


def full_coverage(n_queries: int) -> np.ndarray:
    return np.ones(n_queries, np.float32)


# -- worker health ------------------------------------------------------------


class WorkerHealth:
    """Liveness board for a W-worker cluster.

    Workers prove liveness through the *training stack's*
    :class:`~repro.training.fault_tolerance.Heartbeat` (``sink``-wired
    into :meth:`beat` — one heartbeat implementation serves training and
    serving).  Deaths are reported explicitly (:meth:`mark_dead`, e.g.
    a worker thread raising) or inferred from heartbeat staleness
    (:meth:`failed` with ``stale_after_s``).
    """

    def __init__(self, n_workers: int, stale_after_s: float | None = None):
        self.n_workers = n_workers
        self.stale_after_s = stale_after_s
        self._last_beat = [time.monotonic()] * n_workers
        self._dead: set[int] = set()
        self._lock = threading.Lock()

    def beat(self, worker: int, step: int = 0) -> None:
        with self._lock:
            self._last_beat[worker] = time.monotonic()

    def heartbeat(self, worker: int, interval: float = 0.05):
        """A :class:`~repro.training.fault_tolerance.Heartbeat` context
        whose sink feeds this board instead of a watchdog file."""
        from repro.training.fault_tolerance import Heartbeat
        return Heartbeat(interval=interval,
                         sink=lambda payload: self.beat(
                             worker, payload.get("step", 0)))

    def mark_dead(self, worker: int) -> None:
        with self._lock:
            self._dead.add(worker)

    def is_dead(self, worker: int) -> bool:
        with self._lock:
            return worker in self._dead

    @property
    def dead(self) -> set[int]:
        with self._lock:
            return set(self._dead)

    def live(self) -> list[int]:
        with self._lock:
            return [w for w in range(self.n_workers)
                    if w not in self._dead]

    def failed(self, worker: int) -> bool:
        """Dead, or heartbeat-stale beyond ``stale_after_s``."""
        with self._lock:
            if worker in self._dead:
                return True
            if self.stale_after_s is None:
                return False
            return (time.monotonic() - self._last_beat[worker]
                    > self.stale_after_s)


# -- resilient gather ---------------------------------------------------------


@dataclass
class _Round:
    """Book-keeping for one search round's gather/recovery."""

    bounds: list[tuple[int, int]]
    total: int
    n_queries: int = 0
    k: int = 0
    impl: str = "jax"
    t0: float = field(default_factory=time.monotonic)
    # rank -> finalized (vals, ids); recovery installs at the orphan rank
    contrib: dict[int, tuple] = field(default_factory=dict)
    # ranks whose state is known lost for this round (drop faults)
    undelivered: set[int] = field(default_factory=set)
    given_up: set[int] = field(default_factory=set)
    claimed: dict[int, int] = field(default_factory=dict)   # rank->claimer
    attempts: dict[int, int] = field(default_factory=dict)
    participants: set[int] = field(default_factory=set)
    deadline: float | None = None          # absolute request deadline
    merged: tuple | None = None            # (vals, ids, coverage)


class ResilientAllGather:
    """Fault-tolerant in-process shard gather (allgather semantics).

    Drop-in for ``InMemoryAllGather`` when the driver supplies a round
    context: contributions are keyed per (round, rank); instead of a
    barrier, each worker waits on a condition variable until every
    expected shard state is present — and when one is *not* (its owner
    died, its transport send was dropped, or its per-round deadline
    lapsed), a deterministically-chosen survivor rescans the orphaned
    shard with the caller-provided ``rescore`` callback (the same
    kernels over the same rows) and installs the result at the orphan's
    merge position.  Recovery retries are bounded with exponential
    backoff; on exhaustion — or when the round's request deadline
    expires — the round resolves *partial*: the merged top-k over the
    shards that did arrive, with coverage < 1.

    Every worker of a round returns the identical merged arrays (the
    merge is computed once, under the round lock, in ascending rank
    order — exactly the order ``InMemoryAllGather`` and
    ``ProcessAllGather`` merge in, so a fully-recovered round is
    bitwise-equal to the no-fault round).
    """

    # how long a waiter sleeps between re-evaluations when no wake-up
    # (death notice / contribution) arrives
    _POLL_S = 0.02
    # retain this many resolved rounds so a stalled straggler waking up
    # late can still fetch its round's merged result
    _KEEP_ROUNDS = 16

    def __init__(self, world_size: int, health: WorkerHealth | None = None,
                 sharder=None):
        self.world_size = world_size
        self.health = health if health is not None else WorkerHealth(
            world_size)
        self.sharder = sharder
        self._rounds: dict[int, _Round] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)

    # -- cluster-side notifications -------------------------------------------
    def notify_death(self, worker: int) -> None:
        """A worker thread died; wake all waiters so its shards get
        reassigned immediately instead of after the round deadline."""
        self.health.mark_dead(worker)
        with self._cv:
            self._cv.notify_all()

    # -- legacy barrier-style entry point (no round context) ------------------
    def merge(self, heap, worker_index: int):
        """Compatibility shim: without a round context there is nothing
        to recover, so behave like a 1-round resilient merge keyed by an
        internal counter is impossible — resilient merging requires the
        driver's round number.  Drivers always pass a context; anything
        else should use ``InMemoryAllGather``."""
        raise TypeError(
            "ResilientAllGather requires the driver's round context; "
            "use InMemoryAllGather for barrier-style merging")

    # -- the resilient merge --------------------------------------------------
    def _get_round(self, round_no: int, bounds, heap) -> _Round:
        st = self._rounds.get(round_no)
        if st is None:
            total = max((hi for _, hi in bounds), default=0)
            st = _Round(bounds=list(bounds), total=total,
                        n_queries=heap.n_queries, k=heap.k, impl=heap.impl)
            self._rounds[round_no] = st
            for r in [r for r in self._rounds
                      if r < round_no - self._KEEP_ROUNDS]:
                del self._rounds[r]
        return st

    def _expected_ranks(self, st: _Round) -> list[int]:
        return [rank for rank, (lo, hi) in enumerate(st.bounds) if hi > lo]

    def _pending_ranks(self, st: _Round) -> list[int]:
        return [r for r in self._expected_ranks(st)
                if r not in st.contrib and r not in st.given_up]

    def _absolve(self, rank: int, round_no: int) -> None:
        """Count a recovered/abandoned worker's round as reported so the
        FairSharder's round commit doesn't wait forever for it."""
        if self.sharder is not None:
            absolve = getattr(self.sharder, "absolve", None)
            if absolve is not None:
                absolve(rank, round_no)

    def _compute_merge(self, st: _Round, round_no: int) -> tuple:
        """Merge present contributions in ascending rank order (the
        transports' canonical order) — called once per round, under the
        round lock."""
        from repro.core.result_heap import FastResultHeapq
        merged = FastResultHeapq(st.n_queries, st.k, impl=st.impl)
        covered = 0
        for rank in sorted(st.contrib):
            merged.merge_arrays(*st.contrib[rank])
            lo, hi = st.bounds[rank]
            covered += hi - lo
        vals, ids = merged.finalize()
        cov = 1.0 if st.total == 0 else covered / st.total
        coverage = np.full(st.n_queries, cov, np.float32)
        st.merged = (vals, ids, coverage)
        for rank in self._pending_ranks(st):
            # round resolved without them: absolve so the sharder commits
            st.given_up.add(rank)
            self._absolve(rank, round_no)
        self._cv.notify_all()
        return st.merged

    def _owner_failed(self, st: _Round, rank: int,
                      round_deadline_s: float) -> bool:
        if rank in st.undelivered or self.health.failed(rank):
            return True
        return time.monotonic() > st.t0 + round_deadline_s

    def merge_resilient(self, heap, worker_index: int, round_no: int,
                        bounds, rescore, *, dropped: bool = False,
                        round_deadline_s: float = 30.0,
                        max_retries: int = 2,
                        backoff_s: float = 0.05,
                        deadline_s: float | None = None):
        """One worker's gather for ``round_no``.

        ``bounds`` is the round's full per-rank partition (identical on
        every caller — they come from the round-versioned
        ``FairSharder.acquire``); ``rescore(lo, hi) -> (vals, ids)``
        re-runs this driver's scoring phase over an orphaned shard.
        ``dropped`` marks this worker's own contribution as lost in
        flight (it participates in recovery but does not install its
        state directly).  Returns ``(vals, ids, coverage)``.
        """
        vals, ids = heap.finalize()
        my_lo, my_hi = bounds[worker_index]
        with self._cv:
            st = self._get_round(round_no, bounds, heap)
            st.participants.add(worker_index)
            if deadline_s is not None:
                abs_deadline = time.monotonic() + deadline_s
                st.deadline = (abs_deadline if st.deadline is None
                               else min(st.deadline, abs_deadline))
            if dropped:
                st.undelivered.add(worker_index)
            elif (st.merged is None and my_hi > my_lo
                  and worker_index not in st.contrib):
                # a straggler arriving after its shard was recovered and
                # the round merged must not mutate the resolved round
                st.contrib[worker_index] = (vals, ids)
            self._cv.notify_all()

        while True:
            rescue = None
            with self._cv:
                if st.merged is not None:
                    return st.merged
                pending = self._pending_ranks(st)
                unclaimed = [r for r in pending if r not in st.claimed]
                if not pending:
                    return self._compute_merge(st, round_no)
                now = time.monotonic()
                if st.deadline is not None and now > st.deadline:
                    # request deadline exhausted: resolve partial NOW —
                    # in-flight recoveries are abandoned (their install
                    # finds the round already merged)
                    for r in pending:
                        st.given_up.add(r)
                        self._absolve(r, round_no)
                    return self._compute_merge(st, round_no)
                actionable = [r for r in unclaimed
                              if self._owner_failed(st, r,
                                                    round_deadline_s)]
                if actionable:
                    # deterministic assignee: survivors (participants
                    # not dead) sorted by rank, rotated by attempt count
                    rank = actionable[0]
                    dead = self.health.dead
                    cands = sorted(p for p in st.participants
                                   if p not in dead)
                    if not cands:
                        # nobody left to rescue — resolve partial
                        for r in pending:
                            st.given_up.add(r)
                            self._absolve(r, round_no)
                        return self._compute_merge(st, round_no)
                    attempt = st.attempts.get(rank, 0)
                    assignee = cands[(rank + attempt) % len(cands)]
                    if assignee == worker_index:
                        st.claimed[rank] = worker_index
                        rescue = (rank, attempt)
                    else:
                        self._cv.wait(self._POLL_S)
                else:
                    self._cv.wait(self._POLL_S)
            if rescue is None:
                continue
            rank, attempt = rescue
            lo, hi = st.bounds[rank]
            if attempt:
                time.sleep(backoff_s * (2 ** (attempt - 1)))
            try:
                r_vals, r_ids = rescore(lo, hi)
            except BaseException:          # noqa: BLE001 — retried below
                with self._cv:
                    st.claimed.pop(rank, None)
                    st.attempts[rank] = attempt + 1
                    if st.attempts[rank] > max_retries:
                        st.given_up.add(rank)
                        self._absolve(rank, round_no)
                    self._cv.notify_all()
                continue
            with self._cv:
                st.claimed.pop(rank, None)
                if st.merged is None and rank not in st.contrib:
                    st.contrib[rank] = (r_vals, r_ids)
                    self._absolve(rank, round_no)
                self._cv.notify_all()
