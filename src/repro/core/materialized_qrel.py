"""MaterializedQRel: on-the-fly retrieval data management (paper §3.2).

Holds query, corpus and qrel records *by id only*; text is materialized
lazily, per instance, from memory-mapped tables.  Qrel triplets are
grouped by query id with a sort-based groupby (the Polars role in the
paper), filtered/relabeled per the config, and the grouped arrays are
cached to disk (fingerprinted, atomic) so subsequent runs are ~instant
(paper Table 4).

Resident memory = grouped qrel id arrays (mmap'd) + touched text pages —
the paper's 2.6x memory reduction mechanism (Table 1).
"""

from __future__ import annotations

import hashlib
import os

import numpy as np

from repro.core.config import MaterializedQRelConfig
from repro.data.loaders import load_qrels, load_records
from repro.data.table import (MMapTable, atomic_write_dir,
                              config_fingerprint, file_fingerprint)


def _fn_digest(fn) -> str | None:
    """Cache-key contribution of a user callback.

    ``__name__`` alone collides: every lambda is ``"<lambda>"``, so two
    different filters would silently share a cached grouped-qrel dir.
    Digest the bytecode plus everything that parameterizes it (consts,
    names, closure cell values) so behaviourally different callables get
    different keys, while re-defining the same lambda across runs keeps
    hitting the cache.
    """
    if fn is None:
        return None
    code = getattr(fn, "__code__", None)
    if code is None:                      # builtins / C callables
        return getattr(fn, "__name__", repr(fn))
    payload = code.co_code + repr(
        (code.co_consts, code.co_names, code.co_varnames)).encode()
    closure = getattr(fn, "__closure__", None)
    if closure:
        try:
            payload += repr([c.cell_contents for c in closure]).encode()
        except ValueError:                # empty cell
            payload += b"<empty-cell>"
    defaults = getattr(fn, "__defaults__", None)
    if defaults:
        payload += repr(defaults).encode()
    return hashlib.blake2b(payload, digest_size=8).hexdigest()


def _config_key(cfg: MaterializedQRelConfig) -> str:
    stable = (cfg.min_score, cfg.max_score, cfg.new_label,
              cfg.group_random_k, cfg.query_subset_from, cfg.seed,
              _fn_digest(cfg.filter_fn), _fn_digest(cfg.transform_fn))
    return config_fingerprint(stable)


class MaterializedQRel:
    def __init__(self, cfg: MaterializedQRelConfig,
                 cache_root: str = "/tmp/trove_cache"):
        self.cfg = cfg
        self.cache_root = cache_root
        os.makedirs(cache_root, exist_ok=True)

        self.queries = self._table(cfg.query_path)
        self.corpus = self._table(cfg.corpus_path)
        self._load_groups()

    # -- tables ---------------------------------------------------------------
    def _table(self, path: str) -> MMapTable:
        fp = file_fingerprint(path)
        return MMapTable.build_cached(
            lambda: load_records(path), os.path.join(self.cache_root,
                                                     "tables"), fp)

    # -- qrel grouping ---------------------------------------------------------
    def _load_groups(self):
        fp = file_fingerprint(self.cfg.qrel_path, _config_key(self.cfg))
        gdir = os.path.join(self.cache_root, "groups", fp)
        if not os.path.exists(os.path.join(gdir, "qids.npy")):
            self._build_groups(gdir)
        self.group_qids = np.load(os.path.join(gdir, "qids.npy"),
                                  mmap_mode="r")
        self.group_offsets = np.load(os.path.join(gdir, "offsets.npy"),
                                     mmap_mode="r")
        self.group_dids = np.load(os.path.join(gdir, "dids.npy"),
                                  mmap_mode="r")
        self.group_scores = np.load(os.path.join(gdir, "scores.npy"),
                                    mmap_mode="r")

    def _build_groups(self, gdir: str):
        cfg = self.cfg
        qids, dids, scores = load_qrels(cfg.qrel_path, cfg.loader)

        keep = np.ones(len(qids), bool)
        if cfg.min_score is not None:
            keep &= scores >= cfg.min_score
        if cfg.max_score is not None:
            keep &= scores <= cfg.max_score
        if cfg.query_subset_from:
            sub_q, _, _ = load_qrels(cfg.query_subset_from)
            keep &= np.isin(qids, np.unique(sub_q))
        if cfg.filter_fn is not None:
            keep &= np.fromiter(
                (bool(cfg.filter_fn(q, d, s))
                 for q, d, s in zip(qids, dids, scores)),
                bool, len(qids))
        qids, dids, scores = qids[keep], dids[keep], scores[keep]

        if cfg.transform_fn is not None:
            scores = np.asarray(
                [cfg.transform_fn(s) for s in scores], np.float32)
        if cfg.new_label is not None:
            scores = np.full_like(scores, cfg.new_label)

        order = np.argsort(qids, kind="stable")
        qids, dids, scores = qids[order], dids[order], scores[order]
        uniq, starts = np.unique(qids, return_index=True)
        offsets = np.concatenate([starts, [len(qids)]]).astype(np.int64)

        with atomic_write_dir(gdir) as tmp:
            np.save(os.path.join(tmp, "qids.npy"), uniq)
            np.save(os.path.join(tmp, "offsets.npy"), offsets)
            np.save(os.path.join(tmp, "dids.npy"), dids)
            np.save(os.path.join(tmp, "scores.npy"),
                    scores.astype(np.float32))

    # -- access -----------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.group_qids)

    @property
    def query_id_hashes(self) -> np.ndarray:
        return np.asarray(self.group_qids)

    def group(self, qid_hash: int, rng: np.random.Generator | None = None):
        """(doc id hashes, labels) for one query — ids only, no text."""
        pos = int(np.searchsorted(self.group_qids, qid_hash))
        if pos >= len(self.group_qids) or self.group_qids[pos] != qid_hash:
            return (np.empty(0, np.int64), np.empty(0, np.float32))
        lo, hi = int(self.group_offsets[pos]), int(self.group_offsets[pos + 1])
        dids = np.asarray(self.group_dids[lo:hi])
        scores = np.asarray(self.group_scores[lo:hi])
        k = self.cfg.group_random_k
        if k is not None and len(dids) > k:
            rng = rng or np.random.default_rng(
                (self.cfg.seed * 0x9E3779B1 + qid_hash) & 0xFFFFFFFF)
            sel = rng.choice(len(dids), size=k, replace=False)
            dids, scores = dids[sel], scores[sel]
        return dids, scores

    # -- views -----------------------------------------------------------------
    def queries_view(self):
        """Lazy :class:`~repro.data.views.TableView` over the query table."""
        from repro.data.views import TableView
        return TableView(self.queries)

    def corpus_view(self):
        """Lazy :class:`~repro.data.views.TableView` over the corpus table."""
        from repro.data.views import TableView
        return TableView(self.corpus)

    def qrels_dict(self) -> dict[int, dict[int, float]]:
        """Grouped qrels as ``{qid_hash: {did_hash: score}}``.

        Hash-keyed, so it feeds ``RetrievalEvaluator.evaluate`` directly
        (``stable_id_hash`` is the identity on already-hashed int ids).
        Materializes id/score pairs only — no text.
        """
        out: dict[int, dict[int, float]] = {}
        for pos, qid in enumerate(np.asarray(self.group_qids)):
            lo = int(self.group_offsets[pos])
            hi = int(self.group_offsets[pos + 1])
            out[int(qid)] = {
                int(d): float(s)
                for d, s in zip(self.group_dids[lo:hi],
                                self.group_scores[lo:hi])}
        return out

    def query_text(self, qid_hash: int) -> str:
        return self.queries.get(qid_hash).get("text", "")

    def doc(self, did_hash: int) -> dict:
        return self.corpus.get(did_hash)

    def doc_text(self, did_hash: int) -> str:
        rec = self.doc(did_hash)
        title = rec.get("title", "")
        return f"{title} {rec.get('text', '')}".strip()
