"""Configuration objects mirroring the paper's workflow (§3.1).

Users build experiments from small config dataclasses, instantiable from
the command line (``parse_cli``): MaterializedQRelConfig + DataArguments
-> dataset;  ModelArguments -> retriever;  RetrievalTrainingArguments /
EvaluationArguments -> trainer / evaluator.
"""

from __future__ import annotations

import dataclasses
import sys
from typing import Any, Callable, Sequence


@dataclasses.dataclass
class DataArguments:
    query_max_len: int = 32
    passage_max_len: int = 128
    group_size: int = 2                  # 1 positive + (group_size-1) negatives
    append_eos: bool = False
    vocab_size: int = 50304              # hashing-tokenizer vocab
    pad_to_multiple: int = 8


@dataclasses.dataclass
class ModelArguments:
    arch: str = "trove-base"             # key into repro.configs registry
    encoder_class: str = "lm"            # encoder registry alias
    pooling: str = "last"
    normalize: bool = True
    temperature: float = 0.02
    loss: str = "infonce"                # loss registry alias or callable
    lora_rank: int = 0
    dtype: str = "float32"


@dataclasses.dataclass
class RetrievalTrainingArguments:
    output_dir: str = "/tmp/trove_run"
    learning_rate: float = 1e-3
    weight_decay: float = 0.01
    warmup_steps: int = 10
    max_steps: int = 100
    per_device_batch_size: int = 8
    grad_accum_steps: int = 1
    optimizer: str = "adamw"             # adamw | adafactor
    grad_clip: float = 1.0
    checkpoint_every: int = 50
    keep_checkpoints: int = 2
    async_checkpoint: bool = True
    grad_compression: str = "none"       # none | bf16 | int8
    seed: int = 0
    log_every: int = 10
    aux_loss_weight: float = 0.01        # MoE load-balance loss


@dataclasses.dataclass
class EvaluationArguments:
    topk: int = 100
    encode_batch_size: int = 32
    query_batch_size: int = 256
    cache_dir: str | None = None         # embedding cache (mmap)
    use_cached_embeddings: bool = True
    fair_sharding: bool = True
    metrics: tuple[str, ...] = ("ndcg@10", "mrr@10", "recall@100")
    heap_impl: str = "jax"               # jax | pallas | python (baseline)
    # Scoring backend for RetrievalEvaluator.search (all return identical
    # rankings): "numpy" = host q@d.T baseline; "jax" = device-resident
    # jit'd matmul; "pallas_fused" = fused score+top-k kernel — the (Q,N)
    # score matrix never exists in HBM (interpret-mode on CPU, Mosaic on
    # TPU).
    score_impl: str = "jax"              # numpy | jax | pallas_fused
    # Double-buffered chunk pipeline (ShardedSearchDriver): chunk i+1's
    # cache-read/encode/h2d overlaps chunk i's scoring.  Same results
    # either way (chunks are scored in order); off = fully synchronous.
    async_prefetch: bool = True
    # Superchunk scan executor (device score/heap backends): fold this
    # many streamed chunks into ONE jitted lax.scan dispatch with the
    # (Q, k) top-k state donated between steps.  0 = autotune from a
    # warmup measurement of dispatch overhead vs per-chunk compute;
    # 1 = disable (one dispatch per chunk); N > 1 = fixed.  Identical
    # rankings either way — only the dispatch count changes.
    superchunk_size: int = 0
    # Cap on the stacked (S, C, d) superchunk tile uploaded per dispatch.
    superchunk_max_mb: int = 64
    # Recompile-free bucketed encode pipeline (core.encode_pipeline):
    # sort texts by token length, pad each fixed-batch-dim batch to the
    # smallest rung of a geometric length ladder, restore the original
    # order on output.  Encoder compiles are bounded by the ladder size
    # (not the corpus) and padding FLOPs drop on varied-length corpora.
    # encode_buckets = ladder rung count; 0 = legacy per-batch
    # pad-to-longest encoding (one XLA compile per distinct shape).
    encode_buckets: int = 6
    # Host tokenization threads per tokenize call.  The intra-call
    # fan-out pays off for tokenizers that release the GIL (e.g. Rust
    # HF tokenizers duck-typed in); the pure-Python HashTokenizer is
    # GIL-bound, where the win comes from the pipeline's tokenize-ahead
    # overlap (encode_pipeline_depth) instead.
    tokenizer_workers: int = 2
    # Windows of text tokenized ahead of the device encode stage
    # (bounded queue depth; 0 = tokenize synchronously).
    encode_pipeline_depth: int = 2
    # Continuous-batching serve frontend defaults (core.serving): a
    # micro-batch flushes at serve_max_batch coalesced queries or after
    # serve_max_wait_ms from its first request, whichever first;
    # serve_max_queue bounds pending requests (admission control —
    # submissions beyond it fast-fail with ServeOverloadError).
    serve_max_batch: int = 32
    serve_max_wait_ms: float = 2.0
    serve_max_queue: int = 256
    # Search index backend (repro.index).  "flat" = exhaustive scan over
    # every corpus row (the recall oracle); "ivf" = cluster-pruned
    # inverted-file search: a mini-batch k-means coarse quantizer over
    # ivf_nclusters clusters, and each query batch only scans the union
    # of its ivf_nprobe nearest clusters.  nprobe == nclusters replays
    # the flat ranking bitwise (same kernels, permuted scan order).
    index_impl: str = "flat"             # flat | ivf
    ivf_nclusters: int = 64
    ivf_nprobe: int = 8
    # k-means budget: fixed iteration count + contiguous mini-batch
    # reads off the cache; deterministic under ivf_seed (every worker
    # of a multi-node job rebuilds the identical index).
    ivf_train_steps: int = 40
    ivf_train_batch: int = 1024
    ivf_seed: int = 0
    # Fault tolerance (core.faults, resilient gathers only): how long a
    # round waits for a silent worker before reassigning its shard to a
    # survivor, how many rescore attempts an orphaned shard gets before
    # the round degrades to partial coverage, and the exponential-
    # backoff base between attempts.
    round_deadline_s: float = 30.0
    shard_retries: int = 2
    shard_retry_backoff_s: float = 0.05

    def __post_init__(self):
        # Validate at construction (satellite of ISSUE 7): a bad knob
        # used to surface only deep in the call stack — unknown
        # score_impl at the first scored chunk, topk=0 as a lax.top_k
        # shape error mid-search.
        from repro.core.result_heap import FastResultHeapq
        from repro.core.sharded_search import SCORE_BACKENDS
        if self.score_impl not in SCORE_BACKENDS:
            raise ValueError(
                f"unknown score_impl {self.score_impl!r}; expected one "
                f"of {sorted(SCORE_BACKENDS)}")
        if self.heap_impl not in FastResultHeapq.HEAP_IMPLS:
            raise ValueError(
                f"unknown heap_impl {self.heap_impl!r}; expected one "
                f"of {list(FastResultHeapq.HEAP_IMPLS)}")
        if self.index_impl not in ("flat", "ivf"):
            raise ValueError(
                f"unknown index_impl {self.index_impl!r}; expected one "
                f"of ['flat', 'ivf']")
        for name, floor in (("topk", 1), ("encode_batch_size", 1),
                            ("query_batch_size", 1),
                            ("superchunk_size", 0),
                            ("superchunk_max_mb", 1),
                            ("encode_buckets", 0),
                            ("tokenizer_workers", 0),
                            ("encode_pipeline_depth", 0),
                            ("serve_max_batch", 1),
                            ("serve_max_queue", 1),
                            ("ivf_nclusters", 1),
                            ("ivf_nprobe", 1),
                            ("ivf_train_steps", 1),
                            ("ivf_train_batch", 1)):
            if getattr(self, name) < floor:
                raise ValueError(
                    f"{name} must be >= {floor}, got {getattr(self, name)}")
        if self.serve_max_wait_ms < 0:
            raise ValueError(f"serve_max_wait_ms must be >= 0, got "
                             f"{self.serve_max_wait_ms}")
        if self.round_deadline_s <= 0:
            raise ValueError(f"round_deadline_s must be > 0, got "
                             f"{self.round_deadline_s}")
        if self.shard_retries < 0:
            raise ValueError(f"shard_retries must be >= 0, got "
                             f"{self.shard_retries}")
        if self.shard_retry_backoff_s < 0:
            raise ValueError(f"shard_retry_backoff_s must be >= 0, got "
                             f"{self.shard_retry_backoff_s}")


def parse_cli(*arg_classes, argv: Sequence[str] | None = None):
    """Minimal HfArgumentParser equivalent: ``--field value`` pairs."""
    argv = list(sys.argv[1:] if argv is None else argv)
    kv: dict[str, str] = {}
    i = 0
    while i < len(argv):
        tok = argv[i]
        if tok.startswith("--"):
            if "=" in tok:
                k, v = tok[2:].split("=", 1)
                kv[k] = v
                i += 1
            else:
                kv[tok[2:]] = argv[i + 1] if i + 1 < len(argv) else "true"
                i += 2
        else:
            i += 1
    out = []
    for cls in arg_classes:
        fields = {f.name: f for f in dataclasses.fields(cls)}
        kwargs: dict[str, Any] = {}
        for name, field in fields.items():
            if name not in kv:
                continue
            raw = kv[name]
            typ = field.type if isinstance(field.type, type) else type(
                field.default)
            if typ is bool:
                kwargs[name] = raw.lower() in ("1", "true", "yes")
            elif typ in (int, float):
                kwargs[name] = typ(raw)
            elif typ is tuple or isinstance(field.default, tuple):
                kwargs[name] = tuple(x.strip() for x in raw.split(","))
            else:
                kwargs[name] = raw
        out.append(cls(**kwargs))
    return tuple(out) if len(out) > 1 else out[0]


@dataclasses.dataclass
class MaterializedQRelConfig:
    """How one (query, corpus, qrel) source is loaded & processed on the fly.

    Mirrors the paper's options: score-window filtering, relabeling,
    per-query random subsetting of documents, query-id subsetting, and
    arbitrary user callbacks.
    """

    qrel_path: str = ""
    query_path: str = ""
    corpus_path: str = ""
    # filtering / transformation (applied lazily, in this order)
    min_score: float | None = None
    max_score: float | None = None
    filter_fn: Callable[..., Any] | None = None     # (qid, did, score) -> bool
    new_label: float | None = None                  # relabel kept triplets
    transform_fn: Callable[..., Any] | None = None  # (score) -> score
    group_random_k: int | None = None               # sample k docs per query
    query_subset_from: str | None = None            # qrel file giving query ids
    loader: str | None = None                       # registered loader name
    seed: int = 0
