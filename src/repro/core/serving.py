"""Continuous-batching serve frontend (ROADMAP item 1, DS SERVE-style).

``launch.serve`` used to be an offline batch loop over pre-made
requests; this module is the real online frontend behind it:

  * **admission** — :meth:`ServeFrontend.submit` accepts concurrent
    single-query (or small-batch) requests onto a bounded queue and
    returns a Future.  When the queue is full the submit fast-fails
    with :class:`ServeOverloadError` (the 503 path) instead of letting
    latency grow without bound; once accepted, a request is never
    dropped — overload, shutdown, and backend errors all resolve its
    Future (result or exception).
  * **adaptive micro-batching** — a dispatcher thread coalesces queued
    requests into one micro-batch, flushing at ``max_batch`` coalesced
    queries or ``max_wait_ms`` after the batch's first request,
    whichever comes first — so a lone query pays at most the deadline,
    and a burst amortizes encode+score over the whole batch.
  * **batched execute, per-request demux** — the coalesced texts encode
    through the bucketed :class:`~repro.core.encode_pipeline.
    EncodePipeline` at its smallest viable rung (length rung covering
    the batch, power-of-two batch dim floored at 1) and score against
    the prepared (device-resident) corpus via the driver's superchunk
    executor; the merged ``(ids, scores)`` rows split back to each
    request's Future by position.  Requests never share ids — demux is
    positional — so concurrent clients may reuse query ids freely.
  * **round pipelining** — with the :class:`EvaluatorServeBackend`,
    micro-batch ``r``'s shard merge/finalize runs on the driver's
    reduce thread (``ShardedSearchDriver.search_async``) while the
    dispatcher already encodes and scores micro-batch ``r + 1``.  Each
    in-flight micro-batch owns a fresh ``FastResultHeapq`` state, so
    donated device buffers are never shared across concurrent requests.
  * **clean shutdown** — :meth:`close` stops admission, drains every
    queued request through the normal batch path, joins the dispatcher
    and the backend's reduce thread, and only then returns.

Backends: :class:`EvaluatorServeBackend` (one evaluator — single node
or one rank of a real ``jax.distributed`` cluster — with a persistent
driver and a :class:`~repro.core.evaluator.PreparedCorpus`) and
:class:`ClusterServeBackend` (W real evaluators through
``SimulatedCluster``, the zero-code-change multi-worker path of
``launch.serve --workers N``).  Results are bitwise-identical to solo
``RetrievalEvaluator.search`` calls per query (tests pin the
``score_impl`` × W matrix).
"""

from __future__ import annotations

import inspect
import queue
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Callable, Sequence

import numpy as np

from repro.core.fair_sharding import GenerationMismatch
from repro.core.faults import SearchOutcome


class ServeError(RuntimeError):
    """Base class for serve-frontend errors."""


class ServeOverloadError(ServeError):
    """Admission control rejected the request (queue full — the
    503-style fast-fail; resubmit with backoff)."""


class ServeClosedError(ServeError):
    """The frontend is shut down (or shutting down) and accepts no new
    requests."""


class ServeTimeoutError(ServeError):
    """A blocking :meth:`ServeFrontend.search` wait timed out; the
    request was marked abandoned so the dispatcher skips it instead of
    encoding/scoring work nobody will read."""


class _Request:
    __slots__ = ("texts", "n", "future", "t_submit", "deadline",
                 "abandoned")

    def __init__(self, texts: list[str], deadline_ms: float | None = None):
        self.texts = texts
        self.n = len(texts)
        self.future: Future = Future()
        self.t_submit = time.monotonic()
        # absolute deadline: past it the request resolves degraded-empty
        # (coverage 0) instead of being scored — never dropped
        self.deadline = (None if deadline_ms is None
                         else self.t_submit + deadline_ms / 1e3)
        # set when a blocking search() wait gave up on this request;
        # its Future is already resolved (ServeTimeoutError), so the
        # dispatcher skips it entirely
        self.abandoned = False

    def remaining_s(self, now: float) -> float | None:
        return None if self.deadline is None else self.deadline - now


_SENTINEL = object()


# -- backends -----------------------------------------------------------------


class EvaluatorServeBackend:
    """One evaluator, one persistent driver, one prepared corpus.

    ``begin(texts, topk)`` encodes the micro-batch (smallest viable
    bucket rung, batch-dim floor 1), runs the scoring phase inline on
    the dispatcher thread, and hands the reduce (merge + finalize +
    position→id mapping) to the driver's background reduce thread —
    returning a Future so the dispatcher can start the next
    micro-batch's encode/score while this one merges.

    With ``live_cache`` the corpus *is* the cache's live document set
    (generation-versioned :class:`~repro.core.embedding_cache.
    EmbeddingCache`): each micro-batch is pinned to the newest committed
    generation at dispatch time, a mutation committed mid-stream takes
    effect at the next micro-batch boundary, and an in-flight
    micro-batch finishes on the snapshot it pinned — its prepared corpus
    (and mmap'd snapshot) is only closed once its reduce completes and a
    newer generation has replaced it.
    """

    def __init__(self, evaluator, corpus, cache=None, *,
                 live_cache=None, device_resident: bool = True,
                 min_batch_dim: int = 1):
        self.ev = evaluator
        self.min_batch_dim = min_batch_dim
        self.on_device = evaluator.args.score_impl != "numpy"
        self.live_cache = live_cache
        self._swap_lock = threading.Lock()
        self._inflight: dict[int, int] = {}     # id(prepared) -> rounds
        self._retired: dict[int, object] = {}   # superseded, still in flight
        if live_cache is not None:
            # warm the cache from the seed corpus (one committed
            # generation when anything was missing), then serve the
            # cache's own live set — mutations included
            if corpus:
                cv = evaluator._corpus_view(corpus)
                if len(cv):
                    evaluator.encode_corpus(np.asarray(cv.id_hashes),
                                            cv.texts(), live_cache)
            self.prepared = evaluator.prepare_cache_corpus(live_cache)
        else:
            # the expensive pass: corpus encode / cache warm-up, once
            self.prepared = evaluator.prepare_corpus(
                corpus, cache=cache, device_resident=device_resident)
        self.driver = evaluator.make_driver()

    def _acquire(self):
        """The prepared corpus this micro-batch scores — refreshed to the
        newest committed cache generation at the micro-batch boundary
        (dispatcher thread, so refresh never races another refresh)."""
        if self.live_cache is None:
            return self.prepared
        with self._swap_lock:
            cur = self.prepared
            if self.live_cache.generation_key != cur.generation:
                self.prepared = self.ev.prepare_cache_corpus(
                    self.live_cache)
                if self._inflight.get(id(cur), 0):
                    self._retired[id(cur)] = cur   # close when drained
                else:
                    cur.close()
                cur = self.prepared
            self._inflight[id(cur)] = self._inflight.get(id(cur), 0) + 1
            return cur

    def _release(self, prepared) -> None:
        if self.live_cache is None:
            return
        with self._swap_lock:
            k = id(prepared)
            n = self._inflight.get(k, 0) - 1
            if n > 0:
                self._inflight[k] = n
                return
            self._inflight.pop(k, None)
            retired = self._retired.pop(k, None)
        if retired is not None:
            retired.close()

    def begin(self, texts: Sequence[str], topk: int,
              deadline_s: float | None = None) -> Future:
        prepared = self._acquire()
        try:
            q_emb = self.ev._encode_texts(list(texts), True,
                                          device=self.on_device,
                                          min_batch_dim=self.min_batch_dim)
            # per-round triple: flat corpora hand back their static
            # members; an IVF-prepared corpus derives this micro-batch's
            # pruned search space (top-nprobe clusters) from the query
            # embeddings
            sized, load_chunk, to_ids = prepared.round_for(q_emb)
            inner = self.driver.search_async(
                q_emb, sized, load_chunk, topk, deadline_s=deadline_s,
                generation=prepared.generation)
        except BaseException:
            self._release(prepared)
            raise
        outer: Future = Future()

        def _done(f: Future) -> None:
            try:
                out = f.result()
                vals, pos = out
                coverage = getattr(out, "coverage", None)
                result = (to_ids(pos), vals)
                if coverage is not None:
                    result = SearchOutcome(result, coverage=coverage,
                                           degraded=out.degraded)
                outer.set_result(result)
            except BaseException as exc:   # noqa: BLE001 — routed to caller
                outer.set_exception(exc)
            finally:
                self._release(prepared)

        inner.add_done_callback(_done)
        return outer

    def close(self) -> None:
        self.driver.close()
        with self._swap_lock:
            stale = list(self._retired.values())
            self._retired.clear()
            stale.append(self.prepared)
        for p in stale:
            p.close()


class ClusterServeBackend:
    """W real evaluators in one process (``SimulatedCluster``) — the
    ``launch.serve --workers N`` path.  Each micro-batch runs one full
    sharded round: every rank scores its fair shard and merges through
    the in-memory all-gather; rank 0's (identical) result is returned.

    With ``live_cache`` (one cache shared by every rank) each
    micro-batch pins one ``(generation, epoch)`` key for all W ranks
    before the round starts, so the fair sharder's generation agreement
    passes by construction; a rank that still lands on
    :class:`~repro.core.fair_sharding.GenerationMismatch` (e.g. a
    prepared corpus pinned before a mutation slipped in) re-prepares at
    the round's agreed key and retries — the round is never consumed by
    the losing acquire.
    """

    def __init__(self, evaluators, cluster, corpus, caches=None, *,
                 live_cache=None, device_resident: bool = True,
                 min_batch_dim: int = 1):
        if len(evaluators) != cluster.world_size:
            raise ValueError(
                f"{len(evaluators)} evaluators for a world of "
                f"{cluster.world_size}")
        self.evs = list(evaluators)
        self.cluster = cluster
        self.min_batch_dim = min_batch_dim
        self.live_cache = live_cache
        if live_cache is not None:
            if corpus:
                cv = self.evs[0]._corpus_view(corpus)
                if len(cv):
                    self.evs[0].encode_corpus(np.asarray(cv.id_hashes),
                                              cv.texts(), live_cache)
            self.prepared = [ev.prepare_cache_corpus(live_cache)
                             for ev in self.evs]
        else:
            caches = (caches if caches is not None
                      else [None] * len(self.evs))
            self.prepared = [
                ev.prepare_corpus(corpus, cache=c,
                                  device_resident=device_resident)
                for ev, c in zip(self.evs, caches)]

    def _refresh(self) -> None:
        """Pin every rank to one key — the newest committed generation —
        at the micro-batch boundary.  Reading the key once and passing
        it explicitly means a mutation landing mid-refresh waits for the
        next micro-batch instead of splitting the round."""
        key = self.live_cache.generation_key
        for i, ev in enumerate(self.evs):
            if self.prepared[i].generation != key:
                old = self.prepared[i]
                self.prepared[i] = ev.prepare_cache_corpus(
                    self.live_cache, generation=key)
                old.close()

    def _rank_search(self, rank: int, texts, topk: int,
                     deadline_s: float | None):
        while True:
            try:
                return self.evs[rank].search_texts(
                    texts, self.prepared[rank], topk,
                    min_batch_dim=self.min_batch_dim,
                    deadline_s=deadline_s)
            except GenerationMismatch as e:
                if self.live_cache is None:
                    raise
                # losing acquire: roll forward to the round's agreed
                # snapshot and retry (the sharder did not consume the
                # round for this worker)
                old = self.prepared[rank]
                self.prepared[rank] = self.evs[rank].prepare_cache_corpus(
                    self.live_cache, generation=e.agreed)
                old.close()

    def run(self, texts: Sequence[str], topk: int,
            deadline_s: float | None = None):
        if self.live_cache is not None:
            self._refresh()
        outs = self.cluster.run(
            lambda rank: self._rank_search(rank, texts, topk, deadline_s))
        return outs[0]

    def close(self) -> None:
        for p in self.prepared:
            p.close()


# -- the frontend -------------------------------------------------------------


class ServeFrontend:
    """Queue + dispatcher turning concurrent requests into micro-batches.

    Parameters
    ----------
    backend : object with ``begin(texts, topk) -> Future[(ids, scores)]``
        (pipelined) or ``run(texts, topk) -> (ids, scores)`` (synchronous),
        e.g. :class:`EvaluatorServeBackend` / :class:`ClusterServeBackend`,
        or any callable for tests.
    topk : results per query.
    max_batch : flush when this many queries have coalesced.
    max_wait_ms : flush this long after a batch's first request even if
        under ``max_batch`` (0 = never wait: each flush takes whatever
        is already queued).
    max_queue : pending-request bound (admission control).
    """

    def __init__(self, backend, *, topk: int = 10, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 256):
        if topk < 1:
            raise ValueError(f"topk must be >= 1, got {topk}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(
                f"max_wait_ms must be >= 0, got {max_wait_ms}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if not (callable(backend) or hasattr(backend, "begin")
                or hasattr(backend, "run")):
            raise ValueError(
                "backend must expose begin(texts, topk) or "
                "run(texts, topk), or be callable")
        self.backend = backend
        self.topk = topk
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.stats = {"accepted": 0, "rejected": 0, "completed": 0,
                      "failed": 0, "batches": 0, "queries": 0,
                      "flush_full": 0, "flush_deadline": 0,
                      "flush_drain": 0, "max_batch_seen": 0,
                      "abandoned": 0, "expired": 0, "degraded": 0}
        # does the backend accept a deadline_s kwarg (per-request
        # deadlines threaded down to the driver's recovery budget)?
        target = getattr(backend, "begin", None)
        if target is None:
            target = getattr(backend, "run", backend)
        try:
            self._backend_deadline = ("deadline_s" in
                                      inspect.signature(target).parameters)
        except (TypeError, ValueError):
            self._backend_deadline = False
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._carry: _Request | None = None
        self._lock = threading.Lock()
        self._closed = False
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-dispatch", daemon=True)
        self._thread.start()

    # -- classmethod constructors ---------------------------------------------
    @classmethod
    def from_evaluator(cls, evaluator, corpus, cache=None, *,
                       topk: int | None = None,
                       max_batch: int | None = None,
                       max_wait_ms: float | None = None,
                       max_queue: int | None = None,
                       device_resident: bool = True,
                       live: bool = False) -> "ServeFrontend":
        """Frontend over one evaluator (knob defaults come from its
        ``EvaluationArguments.serve_*`` / ``topk`` fields).  ``live=True``
        serves the cache's live document set with between-micro-batch
        generation swaps (``cache`` required; ``corpus`` just warms it)."""
        if live and cache is None:
            raise ValueError("live=True requires a cache")
        a = evaluator.args
        return cls(
            EvaluatorServeBackend(evaluator, corpus,
                                  None if live else cache,
                                  live_cache=cache if live else None,
                                  device_resident=(device_resident
                                                   and not live)),
            topk=a.topk if topk is None else topk,
            max_batch=a.serve_max_batch if max_batch is None else max_batch,
            max_wait_ms=(a.serve_max_wait_ms if max_wait_ms is None
                         else max_wait_ms),
            max_queue=a.serve_max_queue if max_queue is None else max_queue)

    @classmethod
    def from_cluster(cls, evaluators, cluster, corpus, caches=None, *,
                     topk: int | None = None,
                     max_batch: int | None = None,
                     max_wait_ms: float | None = None,
                     max_queue: int | None = None,
                     device_resident: bool = True,
                     live: bool = False) -> "ServeFrontend":
        """Frontend over W simulated workers (``launch.serve
        --workers N``); knob defaults from rank 0's arguments.
        ``live=True`` serves the shared cache's live set (every rank
        pins the same generation per micro-batch); the first cache in
        ``caches`` is the shared live cache."""
        if live and not (caches and caches[0] is not None):
            raise ValueError("live=True requires a cache in caches[0]")
        a = evaluators[0].args
        return cls(
            ClusterServeBackend(evaluators, cluster, corpus,
                                None if live else caches,
                                live_cache=caches[0] if live else None,
                                device_resident=(device_resident
                                                 and not live)),
            topk=a.topk if topk is None else topk,
            max_batch=a.serve_max_batch if max_batch is None else max_batch,
            max_wait_ms=(a.serve_max_wait_ms if max_wait_ms is None
                         else max_wait_ms),
            max_queue=a.serve_max_queue if max_queue is None else max_queue)

    # -- request admission ----------------------------------------------------
    def _submit(self, request, deadline_ms: float | None) -> _Request:
        if isinstance(request, str):
            texts = [request]
        elif isinstance(request, dict):
            texts = list(request.values())
        else:
            texts = list(request)
        if not texts:
            raise ValueError("empty request")
        if len(texts) > self.max_batch:
            raise ValueError(
                f"request of {len(texts)} queries exceeds max_batch="
                f"{self.max_batch}")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0, got {deadline_ms}")
        req = _Request(texts, deadline_ms)
        with self._lock:
            if self._closed:
                raise ServeClosedError("frontend is closed")
            try:
                self._queue.put_nowait(req)
            except queue.Full:
                self.stats["rejected"] += 1
                raise ServeOverloadError(
                    f"queue full ({self._queue.maxsize} pending "
                    f"requests); retry with backoff") from None
            self.stats["accepted"] += 1
        return req

    def submit(self, request, deadline_ms: float | None = None) -> Future:
        """Accept one request — a single query text, a sequence of
        texts, or an ``{id: text}`` dict — and return a Future resolving
        to ``(doc_id_hashes (q, topk), scores (q, topk))`` with one row
        per query, in request order.

        ``deadline_ms`` bounds the request's total latency: a request
        still queued past its deadline resolves immediately with a
        degraded empty result (ids ``-1``, coverage 0) instead of being
        scored, and a dispatched one hands its remaining budget to the
        backend as the shard-recovery deadline — either way the Future
        resolves (accepted requests are never dropped).  Degraded
        results are :class:`~repro.core.faults.SearchOutcome` tuples
        with ``.degraded``/``.coverage`` set.

        Raises :class:`ServeOverloadError` when the queue is full and
        :class:`ServeClosedError` after :meth:`close`.
        """
        return self._submit(request, deadline_ms).future

    def search(self, request, timeout: float | None = None,
               deadline_ms: float | None = None):
        """Blocking convenience wrapper: submit + wait.

        On ``timeout`` the request is marked **abandoned** — the
        dispatcher skips it during coalescing instead of spending
        encode/score on a result nobody will read — its Future resolves
        with :class:`ServeTimeoutError`, and the same error is raised
        here.
        """
        req = self._submit(request, deadline_ms)
        try:
            return req.future.result(timeout)
        except _FutureTimeout:
            req.abandoned = True
            with self._lock:
                self.stats["abandoned"] += 1
            exc = ServeTimeoutError(
                f"request not served within {timeout}s; abandoned "
                f"(coalescing will skip it)")
            try:
                # resolve the Future so no accepted request is ever left
                # unresolved; a dispatch racing us wins harmlessly
                req.future.set_exception(exc)
            except Exception:
                pass
            raise exc from None

    # -- dispatcher -----------------------------------------------------------
    def _expire(self, req: _Request) -> None:
        """Resolve a deadline-expired queued request with a degraded
        empty result — the no-time-left analogue of a partial search;
        the accepted-never-dropped invariant holds."""
        ids = np.full((req.n, self.topk), -1, np.int64)
        scores = np.full((req.n, self.topk), -np.inf, np.float32)
        cov = np.zeros(req.n, np.float32)
        try:
            req.future.set_result(SearchOutcome((ids, scores),
                                                coverage=cov,
                                                degraded=True))
        except Exception:                  # cancelled by the caller
            pass
        with self._lock:
            self.stats["expired"] += 1

    def _admissible(self, req: _Request) -> bool:
        """Should this queued request still be scored?  Abandoned ones
        are skipped (their Future is already resolved); deadline-expired
        ones resolve degraded-empty here."""
        if req.abandoned:
            return False
        if req.deadline is not None and time.monotonic() > req.deadline:
            self._expire(req)
            return False
        return True

    def _collect(self) -> tuple[list[_Request], str | None, bool]:
        """Block for the next micro-batch.  Returns ``(batch, flush
        reason, stop)``; an empty batch with ``stop`` means shutdown."""
        while True:
            if self._carry is not None:
                first, self._carry = self._carry, None
            else:
                first = self._queue.get()
                if first is _SENTINEL:
                    return [], None, True
            if self._admissible(first):
                break
        batch, n = [first], first.n
        deadline = time.monotonic() + self.max_wait_s
        reason = "full"
        while n < self.max_batch:
            timeout = deadline - time.monotonic()
            try:
                nxt = (self._queue.get(timeout=timeout) if timeout > 0
                       else self._queue.get_nowait())
            except queue.Empty:
                reason = "deadline"
                break
            if nxt is _SENTINEL:
                return batch, "drain", True
            if not self._admissible(nxt):
                continue
            if n + nxt.n > self.max_batch:
                self._carry = nxt          # keeps arrival order intact
                break
            batch.append(nxt)
            n += nxt.n
        return batch, reason, False

    def _loop(self) -> None:
        while True:
            batch, reason, stop = self._collect()
            if batch:
                self._dispatch(batch, reason)
            if stop:
                if self._carry is not None:
                    carry, self._carry = self._carry, None
                    if self._admissible(carry):
                        self._dispatch([carry], "drain")
                return

    def _dispatch(self, batch: list[_Request], reason: str) -> None:
        texts = [t for req in batch for t in req.texts]
        n_real = len(texts)
        # pad the micro-batch to its power-of-two rung (demux below only
        # reads the real rows): encode AND the scoring executor are
        # jit-keyed on the query count, so without this every distinct
        # coalesced size would recompile in steady state — with it the
        # compile set is the rung ladder {1, 2, 4, ..., 2^ceil(log2
        # max_batch)}, all warmable up front
        rung = 1
        while rung < n_real:
            rung *= 2
        texts = texts + [texts[0]] * (rung - n_real)
        with self._lock:
            self.stats["batches"] += 1
            self.stats["queries"] += n_real
            self.stats[f"flush_{reason}"] += 1
            self.stats["max_batch_seen"] = max(
                self.stats["max_batch_seen"], n_real)
        # the batch's recovery budget is the tightest member deadline:
        # a resilient backend stops shard recovery there and returns the
        # partial merge instead of blowing every member's latency bound
        deadline_s = None
        if self._backend_deadline:
            now = time.monotonic()
            remaining = [req.remaining_s(now) for req in batch
                         if req.deadline is not None]
            if remaining:
                deadline_s = max(min(remaining), 1e-3)
        kwargs = ({"deadline_s": deadline_s}
                  if self._backend_deadline and deadline_s is not None
                  else {})
        begin = getattr(self.backend, "begin", None)
        try:
            if begin is not None:
                # pipelined: scoring ran inline; merge/demux complete on
                # the backend's reduce thread while we collect the next
                # micro-batch
                fut = begin(texts, self.topk, **kwargs)
                fut.add_done_callback(
                    lambda f, b=batch: self._demux(b, f))
            else:
                run = getattr(self.backend, "run", self.backend)
                out = run(texts, self.topk, **kwargs)
                self._finish(batch, out)
        except BaseException as exc:       # noqa: BLE001 — routed to futures
            self._fail(batch, exc)

    def _demux(self, batch: list[_Request], fut: Future) -> None:
        try:
            out = fut.result()
        except BaseException as exc:       # noqa: BLE001 — routed to futures
            self._fail(batch, exc)
            return
        self._finish(batch, out)

    def _finish(self, batch: list[_Request], out) -> None:
        ids, scores = out
        coverage = getattr(out, "coverage", None)
        ids = np.asarray(ids)
        scores = np.asarray(scores)
        off = 0
        n_degraded = 0
        for req in batch:
            rows = (ids[off: off + req.n], scores[off: off + req.n])
            if coverage is not None:
                cov = np.asarray(coverage)[off: off + req.n]
                degraded = bool((cov < 1.0).any())
                rows = SearchOutcome(rows, coverage=cov,
                                     degraded=degraded)
                n_degraded += degraded
            try:
                req.future.set_result(rows)
            except Exception:              # cancelled by the caller
                pass
            off += req.n
        with self._lock:
            self.stats["completed"] += len(batch)
            self.stats["degraded"] += n_degraded

    def _fail(self, batch: list[_Request], exc: BaseException) -> None:
        for req in batch:
            try:
                req.future.set_exception(exc)
            except Exception:              # cancelled by the caller
                pass
        with self._lock:
            self.stats["failed"] += len(batch)

    # -- shutdown -------------------------------------------------------------
    def close(self) -> None:
        """Stop admission, drain every queued request, join the
        dispatcher and the backend's reduce thread.  Every accepted
        Future is resolved when this returns.  Idempotent."""
        with self._lock:
            already = self._closed
            self._closed = True
        if not already:
            # the sentinel lands after every accepted request (submit
            # holds the lock and refuses once _closed), so the
            # dispatcher drains everything first
            self._queue.put(_SENTINEL)
        self._thread.join()
        close_backend = getattr(self.backend, "close", None)
        if close_backend is not None:
            close_backend()

    def __enter__(self) -> "ServeFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
