"""Memory-mapped, lazily-loaded embedding cache (paper §3.2.2).

``cache_records(ids, vectors)`` appends; vectors are served from an
``np.memmap`` so only requested rows are faulted in.  Writes are atomic
(tmp files + os.replace of the index) and append-safe across sessions.

Thread-safety: one instance may be shared by the sharded search driver's
prefetch thread and by simulated-cluster worker threads — appends are
serialized under a lock (vector bytes land in file order matching the id
index) and reads snapshot the (index, perm, mmap) triple under the same
lock, so a concurrent append can never mix old row mappings with a new
mmap.
"""

from __future__ import annotations

import json
import os
import threading

import numpy as np

from repro.data.table import stable_id_hash, stable_id_hash_array


class EmbeddingCache:
    def __init__(self, path: str, dim: int, dtype=np.float16):
        self.path = path
        self.dim = dim
        self.dtype = np.dtype(dtype)
        os.makedirs(path, exist_ok=True)
        self._vec_path = os.path.join(path, "vectors.bin")
        self._ids_path = os.path.join(path, "ids.npy")
        self._meta_path = os.path.join(path, "meta.json")
        self._ids = np.empty(0, np.int64)
        self._sorted = None
        self._mmap = None
        self._lock = threading.RLock()
        self._load()

    def _load(self):
        if os.path.exists(self._meta_path):
            with open(self._meta_path) as f:
                meta = json.load(f)
            assert meta["dim"] == self.dim, "cache dim mismatch"
            self.dtype = np.dtype(meta["dtype"])
            self._ids = np.load(self._ids_path, mmap_mode="r")
            self._refresh_mmap()

    def _refresh_mmap(self):
        n = len(self._ids)
        self._mmap = (np.memmap(self._vec_path, dtype=self.dtype, mode="r",
                                shape=(n, self.dim)) if n else None)
        self._sorted = None

    def __len__(self):
        return len(self._ids)

    # -- write ------------------------------------------------------------------
    def cache_records(self, ids, vectors: np.ndarray):
        """Append (ids, vectors).  ids: raw ids or int hashes."""
        vectors = np.ascontiguousarray(vectors, dtype=self.dtype)
        assert vectors.shape[1] == self.dim
        hashes = stable_id_hash_array(ids)
        assert len(hashes) == len(vectors)
        with self._lock:
            with open(self._vec_path, "ab") as f:
                f.write(vectors.tobytes())
            new_ids = np.concatenate([np.asarray(self._ids), hashes])
            tmp = self._ids_path + ".tmp.npy"
            np.save(tmp, new_ids)
            os.replace(tmp, self._ids_path)
            tmp_meta = self._meta_path + ".tmp"
            with open(tmp_meta, "w") as f:
                json.dump({"dim": self.dim, "dtype": self.dtype.name,
                           "n": len(new_ids)}, f)
            os.replace(tmp_meta, self._meta_path)
            self._ids = new_ids
            self._refresh_mmap()

    # -- read -------------------------------------------------------------------
    def _index(self):
        """Consistent (sorted_ids, perm, mmap) snapshot (see module doc)."""
        with self._lock:
            if self._sorted is None:
                ids = np.asarray(self._ids)
                self._perm = np.argsort(ids, kind="stable")
                self._sorted = ids[self._perm]
            return self._sorted, self._perm, self._mmap

    def _rows_for(self, hashes: np.ndarray,
                  sorted_ids=None, perm=None) -> np.ndarray:
        if sorted_ids is None:
            sorted_ids, perm, _ = self._index()
        pos = np.searchsorted(sorted_ids, hashes)
        pos = np.clip(pos, 0, len(sorted_ids) - 1)
        ok = sorted_ids[pos] == hashes
        rows = np.where(ok, perm[pos], -1)
        return rows

    def __contains__(self, raw_id) -> bool:
        if not len(self._ids):
            return False
        h = np.asarray([stable_id_hash(raw_id)], np.int64)
        return bool(self._rows_for(h)[0] >= 0)

    def has(self, ids) -> np.ndarray:
        if not len(self._ids):
            return np.zeros(len(ids), bool)
        return self._rows_for(stable_id_hash_array(ids)) >= 0

    def get(self, ids) -> np.ndarray:
        """Lazy fetch: only the requested rows are read from disk."""
        if not len(self._ids):
            raise KeyError(f"{len(ids)} ids not cached (cache empty)")
        sorted_ids, perm, mmap = self._index()
        rows = self._rows_for(stable_id_hash_array(ids), sorted_ids, perm)
        if (rows < 0).any():
            raise KeyError(f"{(rows < 0).sum()} ids not cached")
        return np.asarray(mmap[rows])

    def get_one(self, raw_id) -> np.ndarray:
        return self.get([raw_id])[0]
