"""Memory-mapped, lazily-loaded embedding cache (paper §3.2.2).

``cache_records(ids, vectors)`` appends; vectors are served from an
``np.memmap`` so only requested rows are faulted in.  Both the vector
payload and the id index are **append-only** files — an append writes
only the new rows' bytes (O(delta), not O(n): the old layout re-saved
the full id index on every append, turning N appends into O(n²) I/O).
Crash safety is kept via the meta file: a record batch is appended to
``vectors.bin`` and ``ids.bin`` first, then ``meta.json`` is atomically
replaced (tmp + ``os.replace``) with the new committed row count.
Readers trust only ``meta['n']`` — torn trailing bytes from a crashed
append are ignored and truncated away before the next append so row
alignment between the two files can never drift.

Thread-safety: one instance may be shared by the sharded search driver's
prefetch thread and by simulated-cluster worker threads — appends are
serialized under a lock (vector bytes land in file order matching the id
index) and reads snapshot the (index, perm, mmap) triple under the same
lock, so a concurrent append can never mix old row mappings with a new
mmap.
"""

from __future__ import annotations

import json
import os
import threading

import numpy as np

from repro.data.table import stable_id_hash, stable_id_hash_array

_IDS_DTYPE = np.dtype("<i8")


class EmbeddingCache:
    def __init__(self, path: str, dim: int, dtype=np.float16):
        self.path = path
        self.dim = dim
        self.dtype = np.dtype(dtype)
        # optional FaultInjector (repro.core.faults) consulted between
        # the write steps of one append — lets chaos tests produce real
        # torn-on-disk states (crash mid-append / before the meta
        # commit) instead of hand-truncating files
        self.fault_injector = None
        os.makedirs(path, exist_ok=True)
        self._vec_path = os.path.join(path, "vectors.bin")
        self._ids_path = os.path.join(path, "ids.bin")
        self._legacy_ids_path = os.path.join(path, "ids.npy")
        self._meta_path = os.path.join(path, "meta.json")
        self._ids = np.empty(0, np.int64)
        self._sorted = None
        self._mmap = None
        self._lock = threading.RLock()
        self._load()

    def _load(self):
        if not os.path.exists(self._meta_path):
            return
        with open(self._meta_path) as f:
            meta = json.load(f)
        assert meta["dim"] == self.dim, "cache dim mismatch"
        self.dtype = np.dtype(meta["dtype"])
        if (os.path.exists(self._legacy_ids_path)
                and not os.path.exists(self._ids_path)):
            # one-shot migration from the legacy full-rewrite ids.npy
            # layout (atomic: tmp + replace; the .npy is kept as-is and
            # simply ignored once ids.bin exists)
            legacy = np.load(self._legacy_ids_path)
            tmp = self._ids_path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(np.ascontiguousarray(legacy, _IDS_DTYPE).tobytes())
            os.replace(tmp, self._ids_path)
        self._truncate_uncommitted(int(meta["n"]))
        self._refresh(int(meta["n"]))

    def _truncate_uncommitted(self, n: int):
        """Drop torn trailing bytes left by a crashed append: everything
        past the committed ``n`` rows in either file is garbage."""
        for fpath, row_bytes in ((self._ids_path, _IDS_DTYPE.itemsize),
                                 (self._vec_path,
                                  self.dim * self.dtype.itemsize)):
            want = n * row_bytes
            if os.path.exists(fpath) and os.path.getsize(fpath) > want:
                with open(fpath, "r+b") as f:
                    f.truncate(want)

    def _refresh(self, n: int):
        self._ids = (np.memmap(self._ids_path, dtype=_IDS_DTYPE, mode="r",
                               shape=(n,)) if n else np.empty(0, np.int64))
        self._mmap = (np.memmap(self._vec_path, dtype=self.dtype, mode="r",
                                shape=(n, self.dim)) if n else None)
        self._sorted = None

    def __len__(self):
        return len(self._ids)

    # -- write ------------------------------------------------------------------
    def cache_records(self, ids, vectors: np.ndarray):
        """Append (ids, vectors).  ids: raw ids or int hashes."""
        vectors = np.ascontiguousarray(vectors, dtype=self.dtype)
        assert vectors.shape[1] == self.dim
        hashes = stable_id_hash_array(ids)
        assert len(hashes) == len(vectors)
        with self._lock:
            n = len(self._ids)
            self._truncate_uncommitted(n)
            with open(self._vec_path, "ab") as f:
                f.write(vectors.tobytes())
            if self.fault_injector is not None:
                # crash mid-append: vector payload on disk, id index not
                self.fault_injector.on_cache("payload")
            with open(self._ids_path, "ab") as f:
                f.write(np.ascontiguousarray(hashes, _IDS_DTYPE).tobytes())
            if self.fault_injector is not None:
                # crash after both payloads but before the meta commit
                self.fault_injector.on_cache("meta")
            new_n = n + len(hashes)
            tmp_meta = self._meta_path + ".tmp"
            with open(tmp_meta, "w") as f:
                json.dump({"dim": self.dim, "dtype": self.dtype.name,
                           "n": new_n}, f)
            os.replace(tmp_meta, self._meta_path)
            self._refresh(new_n)

    # -- read -------------------------------------------------------------------
    def _index(self):
        """Consistent (sorted_ids, perm, mmap) snapshot (see module doc)."""
        with self._lock:
            if self._sorted is None:
                ids = np.asarray(self._ids)
                self._perm = np.argsort(ids, kind="stable")
                self._sorted = ids[self._perm]
            return self._sorted, self._perm, self._mmap

    def _rows_for(self, hashes: np.ndarray,
                  sorted_ids=None, perm=None) -> np.ndarray:
        if sorted_ids is None:
            sorted_ids, perm, _ = self._index()
        pos = np.searchsorted(sorted_ids, hashes)
        pos = np.clip(pos, 0, len(sorted_ids) - 1)
        ok = sorted_ids[pos] == hashes
        rows = np.where(ok, perm[pos], -1)
        return rows

    def __contains__(self, raw_id) -> bool:
        if not len(self._ids):
            return False
        h = np.asarray([stable_id_hash(raw_id)], np.int64)
        return bool(self._rows_for(h)[0] >= 0)

    def has(self, ids) -> np.ndarray:
        if not len(self._ids):
            return np.zeros(len(ids), bool)
        return self._rows_for(stable_id_hash_array(ids)) >= 0

    def get(self, ids) -> np.ndarray:
        """Lazy fetch: only the requested rows are read from disk."""
        if not len(self._ids):
            raise KeyError(f"{len(ids)} ids not cached (cache empty)")
        sorted_ids, perm, mmap = self._index()
        rows = self._rows_for(stable_id_hash_array(ids), sorted_ids, perm)
        if (rows < 0).any():
            missing = np.flatnonzero(rows < 0)
            sample = ", ".join(repr(ids[int(i)]) for i in missing[:5])
            more = "" if len(missing) <= 5 else ", ..."
            raise KeyError(
                f"{len(missing)} ids not cached (e.g. {sample}{more})")
        return np.asarray(mmap[rows])

    def get_one(self, raw_id) -> np.ndarray:
        return self.get([raw_id])[0]

    # -- bulk plans (superchunk streaming) ---------------------------------------
    def ids_array(self) -> np.ndarray:
        """Committed id hashes in insertion (row) order."""
        with self._lock:
            return np.asarray(self._ids)

    def get_range(self, lo: int, hi: int) -> np.ndarray:
        """Rows ``[lo, hi)`` in insertion order: one contiguous mmap read,
        no searchsorted — the streaming fast path when the cache's row
        order is the corpus order (see :meth:`row_plan`)."""
        with self._lock:
            n, mmap = len(self._ids), self._mmap
        if not 0 <= lo <= hi <= n:
            raise IndexError(f"range [{lo}, {hi}) outside [0, {n}]")
        if lo == hi:
            return np.empty((0, self.dim), self.dtype)
        return np.asarray(mmap[lo:hi])

    def get_rows(self, rows: np.ndarray) -> np.ndarray:
        """Fetch explicit row numbers (from a precomputed plan).

        Rows must be in ``[0, n)``: a stale plan carrying ``-1``
        missing-id sentinels (what :meth:`_rows_for` returns) used to
        wrap via fancy indexing and silently serve the *last* row's
        embedding — now it's an ``IndexError``.
        """
        with self._lock:
            n, mmap = len(self._ids), self._mmap
        rows = np.asarray(rows)
        if len(rows) and (rows.min() < 0 or rows.max() >= n):
            bad = rows[(rows < 0) | (rows >= n)]
            raise IndexError(
                f"{len(bad)} row(s) outside [0, {n}) (e.g. "
                f"{bad[:5].tolist()}); negative rows usually mean a "
                f"stale plan with -1 missing-id sentinels")
        if not len(rows):
            return np.empty((0, self.dim), self.dtype)
        return np.asarray(mmap[rows])

    def row_plan(self, hashes: np.ndarray):
        """One-shot lookup plan for streaming ``hashes`` in order.

        Returns ``("range", None)`` when the cache rows are exactly
        ``hashes`` in insertion order (chunks can use :meth:`get_range`
        — zero per-chunk index work), ``("rows", rows)`` when every hash
        is cached but permuted (one upfront searchsorted instead of one
        per chunk), or ``None`` if any hash is missing (callers fall
        back to the encode-missing path)."""
        ids = self.ids_array()
        if len(ids) == len(hashes) and np.array_equal(ids, hashes):
            return ("range", None)
        if len(ids):
            rows = self._rows_for(np.asarray(hashes, np.int64))
            if not (rows < 0).any():
                return ("rows", rows)
        return None
