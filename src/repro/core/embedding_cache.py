"""Memory-mapped, generation-versioned embedding cache (paper §3.2.2).

``cache_records(ids, vectors)`` appends; vectors are served from an
``np.memmap`` so only requested rows are faulted in.  Both the vector
payload and the id index are **append-only** files — an append writes
only the new rows' bytes (O(delta), not O(n)).  Crash safety is kept via
the meta file: a record batch is appended to the payload files first,
then ``meta.json`` is atomically replaced (pid-unique tmp +
``os.replace``) with the new committed counts.  Readers trust only the
meta counts — torn trailing bytes from a crashed append are ignored and
truncated away before the next append so row alignment between the
files can never drift.

Live corpus mutation (generation log)
-------------------------------------
The cache is a *log*, not a table:

  * re-caching an existing id appends a new row — lookups are
    **last-write-wins** (the newest committed row for a hash wins);
  * :meth:`delete_records` appends a *tombstone* ``(hash, seq)`` to
    ``tombstones.bin`` where ``seq`` is the committed row count at
    delete time: the tombstone kills every row of that hash below
    ``seq``, and a later re-add (row ≥ seq) resurrects the id;
  * every committed mutation bumps ``generation``; ``meta.json`` keeps
    a bounded history of ``(generation, n_rows, n_tombstones)`` triples
    so past generations stay resolvable;
  * :meth:`snapshot` pins an immutable view of one generation — a live
    row set + id→row map that ``get_range`` / ``get_rows`` /
    ``row_plan`` all honor.  A reader pinned to generation g never sees
    rows from g+1 or resurrected tombstones, even mid-compaction.

:meth:`compact` rewrites the live rows into a fresh payload *epoch*
(``vectors.e<k>.bin`` / ``ids.e<k>.bin``), optionally permuted into the
IVF cluster-sorted layout, using the same pid-unique tmp +
atomic-replace + meta-last protocol.  Writers are only blocked for the
short catch-up append at the end; pinned readers keep streaming the old
epoch, whose files are retired only once no pinned reader remains.
Crash at any point (the ``compact_payload`` / ``compact_meta`` /
``compact_swap`` fault-injection points) reopens to exactly the pre- or
post-compaction generation — never a torn hybrid; stray epoch files are
swept on open.

Thread-safety: one instance may be shared by the sharded search driver's
prefetch thread and by simulated-cluster worker threads — mutations are
serialized under a lock and reads snapshot the (index, perm, mmap)
triple under the same lock, so a concurrent append can never mix old
row mappings with a new mmap.
"""

from __future__ import annotations

import glob
import json
import os
import threading

import numpy as np

from repro.data.table import stable_id_hash, stable_id_hash_array

_IDS_DTYPE = np.dtype("<i8")
# tombstones are (id_hash, rows_at_delete) int64 pairs
_TOMB_DTYPE = np.dtype("<i8")
# generations resolvable via snapshot(generation=g); older ones age out
_HISTORY_KEEP = 256


def _live_rows(ids, tombs, n: int, n_tombs: int) -> np.ndarray:
    """Row indices (ascending) live at log position ``(n, n_tombs)``:
    the newest row per hash (last-write-wins), minus rows killed by a
    tombstone whose ``seq`` exceeds the winning row's index."""
    if n == 0:
        return np.empty(0, np.int64)
    ids = np.asarray(ids[:n], np.int64)
    perm = np.argsort(ids, kind="stable")
    sids = ids[perm]
    last = np.empty(n, bool)
    last[:-1] = sids[1:] != sids[:-1]
    last[-1] = True
    winners = perm[last]          # newest row per unique hash
    if n_tombs:
        uids = sids[last]
        t = np.asarray(tombs[:n_tombs], np.int64)
        pos = np.minimum(np.searchsorted(uids, t[:, 0]), len(uids) - 1)
        valid = uids[pos] == t[:, 0]
        dead_seq = np.zeros(len(uids), np.int64)
        np.maximum.at(dead_seq, pos[valid], t[valid, 1])
        winners = winners[winners >= dead_seq]
    winners.sort()
    return winners


class CacheSnapshot:
    """An immutable, pinned view of one cache generation.

    ``ids`` holds the live id hashes in insertion (winning-row) order;
    positions are *live-space* — ``get_range(lo, hi)`` / ``get_rows``
    address ``[0, n_live)`` and resolve through the frozen live-row map,
    so the view never changes under later appends, deletes, or
    compactions.  The snapshot pins its payload epoch: compaction
    retires the old epoch's files only once every snapshot on it is
    closed (or garbage-collected).
    """

    def __init__(self, cache: "EmbeddingCache", epoch: int, generation: int,
                 n: int, n_tombs: int, ids, mmap, tombs):
        self._cache = cache
        self.epoch = epoch
        self.generation = generation
        self.dim = cache.dim
        self.dtype = cache.dtype
        self._rows = _live_rows(ids, tombs, n, n_tombs)
        self.ids = (np.asarray(ids[:n], np.int64)[self._rows]
                    if n else np.empty(0, np.int64))
        self.n_live = len(self._rows)
        self._mmap = mmap
        self._contig = self.n_live == n  # live rows are exactly [0, n)
        self._sorted = None
        self._closed = False

    @property
    def key(self) -> tuple[int, int]:
        """Agreement key for multi-worker rounds: compaction changes the
        physical row layout without changing the generation, so workers
        must agree on ``(generation, epoch)``, not the generation
        alone."""
        return (self.generation, self.epoch)

    def __len__(self):
        return self.n_live

    # -- reads (live-space positions) -----------------------------------------
    def get_range(self, lo: int, hi: int) -> np.ndarray:
        if not 0 <= lo <= hi <= self.n_live:
            raise IndexError(
                f"range [{lo}, {hi}) outside [0, {self.n_live}]")
        if lo == hi:
            return np.empty((0, self.dim), self.dtype)
        if self._contig:
            return np.asarray(self._mmap[lo:hi])
        return np.asarray(self._mmap[self._rows[lo:hi]])

    def get_rows(self, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows)
        if len(rows) and (rows.min() < 0 or rows.max() >= self.n_live):
            bad = rows[(rows < 0) | (rows >= self.n_live)]
            raise IndexError(
                f"{len(bad)} row(s) outside [0, {self.n_live}) (e.g. "
                f"{bad[:5].tolist()}); positions are live-space for "
                f"generation {self.generation}")
        if not len(rows):
            return np.empty((0, self.dim), self.dtype)
        if self._contig:
            return np.asarray(self._mmap[rows])
        return np.asarray(self._mmap[self._rows[rows]])

    def _positions(self, hashes: np.ndarray) -> np.ndarray:
        """Live-space position per hash (-1 = not live in this view)."""
        if self._sorted is None:
            order = np.argsort(self.ids)     # live ids are unique
            self._order = order
            self._sorted = self.ids[order]
        if not self.n_live:
            return np.full(len(hashes), -1, np.int64)
        pos = np.minimum(np.searchsorted(self._sorted, hashes),
                         self.n_live - 1)
        ok = self._sorted[pos] == hashes
        return np.where(ok, self._order[pos], -1)

    def has(self, ids) -> np.ndarray:
        return self._positions(stable_id_hash_array(ids)) >= 0

    def get(self, ids) -> np.ndarray:
        pos = self._positions(stable_id_hash_array(ids))
        if (pos < 0).any():
            missing = np.flatnonzero(pos < 0)
            sample = ", ".join(repr(ids[int(i)]) for i in missing[:5])
            more = "" if len(missing) <= 5 else ", ..."
            raise KeyError(f"{len(missing)} ids not live in generation "
                           f"{self.generation} (e.g. {sample}{more})")
        return self.get_rows(pos)

    def row_plan(self, hashes: np.ndarray):
        """Same contract as :meth:`EmbeddingCache.row_plan`, but
        positions are live-space (feed them to :meth:`get_rows` of this
        snapshot, not of the cache)."""
        hashes = np.asarray(hashes, np.int64)
        if len(self.ids) == len(hashes) and np.array_equal(self.ids,
                                                           hashes):
            return ("range", None)
        if self.n_live:
            pos = self._positions(hashes)
            if not (pos < 0).any():
                return ("rows", pos)
        return None

    # -- pin lifetime ---------------------------------------------------------
    def close(self):
        if not self._closed:
            self._closed = True
            self._cache._unpin(self.epoch)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass


class EmbeddingCache:
    def __init__(self, path: str, dim: int, dtype=np.float16):
        self.path = path
        self.dim = dim
        self.dtype = np.dtype(dtype)
        # optional FaultInjector (repro.core.faults) consulted between
        # the write steps of one append / compaction — lets chaos tests
        # produce real torn-on-disk states instead of hand-truncating
        # files
        self.fault_injector = None
        os.makedirs(path, exist_ok=True)
        self._legacy_ids_path = os.path.join(path, "ids.npy")
        self._meta_path = os.path.join(path, "meta.json")
        self._epoch = 0
        self._gen = 0
        self._n = 0
        self._n_tombs = 0
        self._history = [[0, 0, 0]]
        self._set_epoch_paths(0)
        self._ids = np.empty(0, np.int64)
        self._tombs = np.empty((0, 2), np.int64)
        self._sorted = None
        self._live = None
        self._mmap = None
        self._pins: dict[int, int] = {}
        self._retired: dict[int, dict] = {}
        self._lock = threading.RLock()
        self._load()

    # -- layout ---------------------------------------------------------------
    def _epoch_paths(self, epoch: int) -> tuple[str, str, str]:
        if epoch == 0:     # epoch 0 keeps the original file names
            names = ("vectors.bin", "ids.bin", "tombstones.bin")
        else:
            names = (f"vectors.e{epoch}.bin", f"ids.e{epoch}.bin",
                     f"tombstones.e{epoch}.bin")
        return tuple(os.path.join(self.path, nm) for nm in names)

    def _set_epoch_paths(self, epoch: int):
        self._vec_path, self._ids_path, self._tombs_path = \
            self._epoch_paths(epoch)

    def _tmp_tag(self) -> str:
        return f".tmp{os.getpid()}_{threading.get_ident()}"

    def _load(self):
        if not os.path.exists(self._meta_path):
            return
        with open(self._meta_path) as f:
            meta = json.load(f)
        assert meta["dim"] == self.dim, "cache dim mismatch"
        self.dtype = np.dtype(meta["dtype"])
        n = int(meta["n"])
        # pre-generation metas: epoch 0, no tombstones, one synthetic
        # generation covering whatever rows were committed
        self._epoch = int(meta.get("epoch", 0))
        self._gen = int(meta.get("generation", 1 if n else 0))
        self._n_tombs = int(meta.get("n_tombstones", 0))
        self._history = [list(map(int, h)) for h in meta.get(
            "history", [[self._gen, n, self._n_tombs]])]
        self._set_epoch_paths(self._epoch)
        if (os.path.exists(self._legacy_ids_path)
                and not os.path.exists(self._ids_path)):
            # one-shot migration from the legacy full-rewrite ids.npy
            # layout (atomic: tmp + replace; the .npy is kept as-is and
            # simply ignored once ids.bin exists)
            legacy = np.load(self._legacy_ids_path)
            tmp = self._ids_path + self._tmp_tag()
            with open(tmp, "wb") as f:
                f.write(np.ascontiguousarray(legacy, _IDS_DTYPE).tobytes())
            os.replace(tmp, self._ids_path)
        self._sweep_stray_files()
        self._truncate_uncommitted(n, self._n_tombs)
        self._refresh(n, self._n_tombs)

    def _sweep_stray_files(self):
        """Remove payload files that do not belong to the committed
        epoch: a crash between a compaction's meta commit and its
        old-file retirement (or before its meta commit) leaves the
        losing epoch's files behind."""
        keep = set(self._epoch_paths(self._epoch))
        for pat in ("vectors*.bin*", "ids*.bin*", "tombstones*.bin*"):
            for p in glob.glob(os.path.join(self.path, pat)):
                if p not in keep and os.path.isfile(p):
                    try:
                        os.remove(p)
                    except OSError:
                        pass

    def _truncate_uncommitted(self, n: int, n_tombs: int):
        """Drop torn trailing bytes left by a crashed append: everything
        past the committed counts in any payload file is garbage."""
        for fpath, row_bytes, rows in (
                (self._ids_path, _IDS_DTYPE.itemsize, n),
                (self._vec_path, self.dim * self.dtype.itemsize, n),
                (self._tombs_path, 2 * _TOMB_DTYPE.itemsize, n_tombs)):
            want = rows * row_bytes
            if os.path.exists(fpath) and os.path.getsize(fpath) > want:
                with open(fpath, "r+b") as f:
                    f.truncate(want)

    def _refresh(self, n: int, n_tombs: int):
        self._n = n
        self._n_tombs = n_tombs
        self._ids = (np.memmap(self._ids_path, dtype=_IDS_DTYPE, mode="r",
                               shape=(n,)) if n else np.empty(0, np.int64))
        self._mmap = (np.memmap(self._vec_path, dtype=self.dtype, mode="r",
                                shape=(n, self.dim)) if n else None)
        if n_tombs and os.path.exists(self._tombs_path):
            self._tombs = np.fromfile(
                self._tombs_path, dtype=_TOMB_DTYPE,
                count=2 * n_tombs).reshape(-1, 2)
        else:
            self._tombs = np.empty((0, 2), np.int64)
        self._sorted = None
        self._live = None

    def __len__(self):
        """Committed *physical* rows (the log length, superseded and
        tombstoned rows included); see :attr:`n_live` for the logical
        corpus size."""
        return self._n

    @property
    def generation(self) -> int:
        with self._lock:
            return self._gen

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    @property
    def generation_key(self) -> tuple[int, int]:
        with self._lock:
            return (self._gen, self._epoch)

    @property
    def n_live(self) -> int:
        return len(self._live_rows_locked())

    def _on_fault(self, point: str):
        if self.fault_injector is not None:
            self.fault_injector.on_cache(point)

    # -- write ----------------------------------------------------------------
    def _write_meta(self, n: int, n_tombs: int):
        tmp_meta = self._meta_path + self._tmp_tag()
        with open(tmp_meta, "w") as f:
            json.dump({"dim": self.dim, "dtype": self.dtype.name,
                       "n": n, "version": 2, "epoch": self._epoch,
                       "generation": self._gen,
                       "n_tombstones": n_tombs,
                       "history": self._history}, f)
        os.replace(tmp_meta, self._meta_path)

    def _commit(self, n: int, n_tombs: int):
        """Meta-last commit of one mutation: bump the generation, extend
        the history, atomically replace meta.json, re-mmap."""
        self._gen += 1
        self._history.append([self._gen, n, n_tombs])
        del self._history[:-_HISTORY_KEEP]
        self._write_meta(n, n_tombs)
        self._refresh(n, n_tombs)

    def cache_records(self, ids, vectors: np.ndarray):
        """Append (ids, vectors); re-caching an existing id appends a
        new version that wins every later lookup.  ids: raw ids or int
        hashes."""
        vectors = np.asarray(vectors)
        if vectors.ndim != 2 or vectors.shape[1] != self.dim:
            raise ValueError(
                f"vectors must be (n, {self.dim}), got shape "
                f"{vectors.shape}")
        hashes = stable_id_hash_array(ids)
        if len(hashes) != len(vectors):
            raise ValueError(
                f"ids/vectors length mismatch: {len(hashes)} ids vs "
                f"{len(vectors)} vector rows")
        with np.errstate(over="ignore"):
            # overflow in a narrowing cast shows up as inf below and is
            # rejected with the offending positions, not warned about
            vectors = np.ascontiguousarray(vectors, dtype=self.dtype)
        bad = np.flatnonzero(~np.isfinite(vectors).all(axis=1))
        if len(bad):
            more = "" if len(bad) <= 5 else ", ..."
            raise ValueError(
                f"non-finite embedding vectors: {len(bad)} row(s) "
                f"contain NaN/inf after cast to {self.dtype.name} "
                f"(positions {bad[:5].tolist()}{more})")
        with self._lock:
            n = self._n
            self._truncate_uncommitted(n, self._n_tombs)
            with open(self._vec_path, "ab") as f:
                f.write(vectors.tobytes())
            # crash mid-append: vector payload on disk, id index not
            self._on_fault("payload")
            with open(self._ids_path, "ab") as f:
                f.write(np.ascontiguousarray(hashes, _IDS_DTYPE).tobytes())
            # crash after both payloads but before the meta commit
            self._on_fault("meta")
            self._commit(n + len(hashes), self._n_tombs)

    def delete_records(self, ids):
        """Tombstone ``ids``: append ``(hash, committed_row_count)``
        pairs — every existing row of those hashes is dead from the next
        generation on; a later :meth:`cache_records` of the same id
        resurrects it.  Deleting an id that was never cached is a no-op
        tombstone (still a new generation)."""
        hashes = stable_id_hash_array(ids)
        if not len(hashes):
            return
        with self._lock:
            n, nt = self._n, self._n_tombs
            self._truncate_uncommitted(n, nt)
            pairs = np.empty((len(hashes), 2), _TOMB_DTYPE)
            pairs[:, 0] = hashes
            pairs[:, 1] = n
            with open(self._tombs_path, "ab") as f:
                f.write(pairs.tobytes())
            # crash after the tombstone append, before the meta commit
            self._on_fault("tombstone")
            self._commit(n, nt + len(hashes))

    # -- snapshots ------------------------------------------------------------
    def snapshot(self, generation=None) -> CacheSnapshot:
        """Pin an immutable view.  ``generation`` may be ``None`` (the
        newest committed generation), an int (resolved in the current
        epoch's history), or a ``(generation, epoch)`` key from another
        snapshot — resolvable across a compaction as long as a pinned
        reader kept the old epoch alive."""
        with self._lock:
            if generation is None:
                gen, epoch = self._gen, self._epoch
            elif isinstance(generation, tuple):
                gen, epoch = int(generation[0]), int(generation[1])
            else:
                gen, epoch = int(generation), self._epoch
            if epoch == self._epoch:
                ids, mmap, tombs = self._ids, self._mmap, self._tombs
                history = self._history
            else:
                st = self._retired.get(epoch)
                if st is None:
                    raise KeyError(
                        f"epoch {epoch} is retired (no pinned reader "
                        f"kept it alive); current epoch is "
                        f"{self._epoch}")
                ids, mmap, tombs = st["ids"], st["mmap"], st["tombs"]
                history = st["history"]
            for g, n, nt in reversed(history):
                if g == gen:
                    break
            else:
                raise KeyError(
                    f"generation {gen} not resolvable in epoch {epoch} "
                    f"(history keeps the last {_HISTORY_KEEP} "
                    f"generations; compaction drops pre-compaction "
                    f"entries)")
            self._pins[epoch] = self._pins.get(epoch, 0) + 1
            return CacheSnapshot(self, epoch, gen, n, nt, ids, mmap,
                                 tombs)

    def _unpin(self, epoch: int):
        drop_paths = None
        with self._lock:
            count = self._pins.get(epoch, 0) - 1
            if count > 0:
                self._pins[epoch] = count
            else:
                self._pins.pop(epoch, None)
                if epoch != self._epoch and epoch in self._retired:
                    drop_paths = self._retired.pop(epoch)["paths"]
        if drop_paths:
            for p in drop_paths:
                try:
                    os.remove(p)
                except OSError:
                    pass

    # -- compaction -----------------------------------------------------------
    def compact(self, order=None) -> dict:
        """Rewrite the live rows into a fresh payload epoch, dropping
        superseded rows and applied tombstones.  ``order`` optionally
        permutes the live rows (live-space positions — e.g. an IVF
        cluster-sorted permutation from ``repro.index.ivf``).

        Zero-downtime: the payload rewrite streams outside the write
        lock; writers are blocked only for the final catch-up append
        (rows/tombstones committed since the compaction snapshot) and
        the meta swap.  Pinned snapshots keep reading the old epoch,
        whose files are removed only when the last pin drops.  The
        logical content — and therefore the generation — is unchanged.
        """
        with self._lock:
            n0, nt0, g0 = self._n, self._n_tombs, self._gen
            old_epoch = self._epoch
            ids0, tombs0, old_mmap = self._ids, self._tombs, self._mmap
            live = _live_rows(ids0, tombs0, n0, nt0)
        if order is not None:
            order = np.asarray(order, np.int64)
            if (len(order) != len(live)
                    or (len(order)
                        and not np.array_equal(np.sort(order),
                                               np.arange(len(live))))):
                raise ValueError(
                    f"order must be a permutation of the {len(live)} "
                    f"live rows")
            rows = live[order]
        else:
            rows = live
        n_live = len(rows)
        new_epoch = old_epoch + 1
        new_vec, new_ids, new_tombs = self._epoch_paths(new_epoch)
        tag = self._tmp_tag()
        # payload first (pid-unique tmp + atomic replace), meta last
        with open(new_vec + tag, "wb") as f:
            for s in range(0, n_live, 65536):
                block = rows[s:s + 65536]
                f.write(np.ascontiguousarray(
                    old_mmap[block], self.dtype).tobytes())
        os.replace(new_vec + tag, new_vec)
        with open(new_ids + tag, "wb") as f:
            f.write(np.ascontiguousarray(
                np.asarray(ids0[:n0], np.int64)[rows],
                _IDS_DTYPE).tobytes())
        os.replace(new_ids + tag, new_ids)
        # crash here: meta still names the old epoch — reopen is
        # pre-compaction, the new epoch's files are swept as strays
        self._on_fault("compact_payload")
        with self._lock:
            n1, nt1 = self._n, self._n_tombs
            if n1 > n0:
                # rows committed since the snapshot carry over verbatim
                with open(new_vec, "ab") as f:
                    f.write(np.ascontiguousarray(
                        self._mmap[n0:n1], self.dtype).tobytes())
                with open(new_ids, "ab") as f:
                    f.write(np.ascontiguousarray(
                        np.asarray(self._ids[n0:n1], np.int64),
                        _IDS_DTYPE).tobytes())
            if nt1 > nt0:
                # remap seq: old row r >= n0 lands at n_live + (r - n0)
                t = np.array(self._tombs[nt0:nt1], _TOMB_DTYPE)
                t[:, 1] = n_live + (t[:, 1] - n0)
                with open(new_tombs, "ab") as f:
                    f.write(np.ascontiguousarray(t,
                                                 _TOMB_DTYPE).tobytes())
            new_n = n_live + (n1 - n0)
            new_nt = nt1 - nt0
            # history entries from the snapshot generation on remap into
            # the new epoch; older generations age out with the old one
            new_history = [[g, n_live + (n - n0), nt - nt0]
                           for g, n, nt in self._history if g >= g0]
            # crash here: catch-up written but meta not replaced —
            # still pre-compaction on reopen
            self._on_fault("compact_meta")
            old_state = {"ids": ids0, "mmap": old_mmap, "tombs": tombs0,
                         "history": self._history,
                         "paths": self._epoch_paths(old_epoch)}
            self._epoch = new_epoch
            self._history = new_history
            self._set_epoch_paths(new_epoch)
            self._write_meta(new_n, new_nt)
            self._refresh(new_n, new_nt)
            pinned = self._pins.get(old_epoch, 0) > 0
            if pinned:
                self._retired[old_epoch] = old_state
            # crash here: meta already names the new epoch — reopen is
            # post-compaction, the old epoch's files are swept as strays
            self._on_fault("compact_swap")
            if not pinned:
                for p in old_state["paths"]:
                    try:
                        os.remove(p)
                    except OSError:
                        pass
        return {"epoch": new_epoch, "rows_before": n1, "rows_after": new_n,
                "dropped": n1 - new_n, "tombstones_applied": nt0}

    # -- read -----------------------------------------------------------------
    def _live_rows_locked(self) -> np.ndarray:
        with self._lock:
            if self._live is None:
                self._live = _live_rows(self._ids, self._tombs, self._n,
                                        self._n_tombs)
            return self._live

    def _index(self):
        """Consistent (sorted_live_ids, perm, mmap) snapshot: lookups
        resolve to the newest non-tombstoned row per hash (see module
        doc)."""
        with self._lock:
            if self._sorted is None:
                live = self._live_rows_locked()
                lids = (np.asarray(self._ids, np.int64)[live]
                        if len(live) else np.empty(0, np.int64))
                order = np.argsort(lids)       # live ids are unique
                self._perm = live[order]
                self._sorted = lids[order]
            return self._sorted, self._perm, self._mmap

    def _rows_for(self, hashes: np.ndarray,
                  sorted_ids=None, perm=None) -> np.ndarray:
        if sorted_ids is None:
            sorted_ids, perm, _ = self._index()
        if not len(sorted_ids):
            return np.full(len(hashes), -1, np.int64)
        pos = np.searchsorted(sorted_ids, hashes)
        pos = np.clip(pos, 0, len(sorted_ids) - 1)
        ok = sorted_ids[pos] == hashes
        rows = np.where(ok, perm[pos], -1)
        return rows

    def __contains__(self, raw_id) -> bool:
        if not self._n:
            return False
        h = np.asarray([stable_id_hash(raw_id)], np.int64)
        return bool(self._rows_for(h)[0] >= 0)

    def has(self, ids) -> np.ndarray:
        if not self._n:
            return np.zeros(len(ids), bool)
        return self._rows_for(stable_id_hash_array(ids)) >= 0

    def get(self, ids) -> np.ndarray:
        """Lazy fetch: only the requested rows are read from disk;
        resolves to each id's newest live version."""
        if not self._n:
            raise KeyError(f"{len(ids)} ids not cached (cache empty)")
        sorted_ids, perm, mmap = self._index()
        rows = self._rows_for(stable_id_hash_array(ids), sorted_ids, perm)
        if (rows < 0).any():
            missing = np.flatnonzero(rows < 0)
            sample = ", ".join(repr(ids[int(i)]) for i in missing[:5])
            more = "" if len(missing) <= 5 else ", ..."
            raise KeyError(
                f"{len(missing)} ids not cached (e.g. {sample}{more})")
        return np.asarray(mmap[rows])

    def get_one(self, raw_id) -> np.ndarray:
        return self.get([raw_id])[0]

    # -- bulk plans (superchunk streaming) ---------------------------------------
    def ids_array(self) -> np.ndarray:
        """Committed id hashes in insertion (row) order — the raw log,
        superseded and tombstoned rows included."""
        with self._lock:
            return np.asarray(self._ids)

    def live_ids(self) -> np.ndarray:
        """Live id hashes in insertion (winning-row) order."""
        with self._lock:
            live = self._live_rows_locked()
            return (np.asarray(self._ids, np.int64)[live]
                    if len(live) else np.empty(0, np.int64))

    def get_range(self, lo: int, hi: int) -> np.ndarray:
        """Physical rows ``[lo, hi)`` in insertion order: one contiguous
        mmap read, no searchsorted — the streaming fast path when the
        cache's row order is the corpus order (see :meth:`row_plan`)."""
        with self._lock:
            n, mmap = self._n, self._mmap
        if not 0 <= lo <= hi <= n:
            raise IndexError(f"range [{lo}, {hi}) outside [0, {n}]")
        if lo == hi:
            return np.empty((0, self.dim), self.dtype)
        return np.asarray(mmap[lo:hi])

    def get_rows(self, rows: np.ndarray) -> np.ndarray:
        """Fetch explicit physical row numbers (from a precomputed
        plan).

        Rows must be in ``[0, n)``: a stale plan carrying ``-1``
        missing-id sentinels (what :meth:`_rows_for` returns) used to
        wrap via fancy indexing and silently serve the *last* row's
        embedding — now it's an ``IndexError``.
        """
        with self._lock:
            n, mmap = self._n, self._mmap
        rows = np.asarray(rows)
        if len(rows) and (rows.min() < 0 or rows.max() >= n):
            bad = rows[(rows < 0) | (rows >= n)]
            raise IndexError(
                f"{len(bad)} row(s) outside [0, {n}) (e.g. "
                f"{bad[:5].tolist()}); negative rows usually mean a "
                f"stale plan with -1 missing-id sentinels")
        if not len(rows):
            return np.empty((0, self.dim), self.dtype)
        return np.asarray(mmap[rows])

    def row_plan(self, hashes: np.ndarray):
        """One-shot lookup plan for streaming ``hashes`` in order.

        Returns ``("range", None)`` when the cache rows are exactly
        ``hashes`` in insertion order with nothing superseded or
        tombstoned (chunks can use :meth:`get_range` — zero per-chunk
        index work), ``("rows", rows)`` when every hash resolves to a
        live row but permuted (one upfront searchsorted instead of one
        per chunk), or ``None`` if any hash is missing or deleted
        (callers fall back to the encode-missing path)."""
        hashes = np.asarray(hashes, np.int64)
        with self._lock:
            live = self._live_rows_locked()
            ids = np.asarray(self._ids)
        if (len(live) == self._n and len(ids) == len(hashes)
                and np.array_equal(ids, hashes)):
            return ("range", None)
        if len(live):
            rows = self._rows_for(hashes)
            if not (rows < 0).any():
                return ("rows", rows)
        return None
