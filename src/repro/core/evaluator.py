"""RetrievalEvaluator: unified evaluation + hard-negative mining (§3.5).

One interface, three scales, zero code changes — all three are thin
single-worker instantiations of
:class:`repro.core.sharded_search.ShardedSearchDriver`:

  * single device — one driver (W=1) streams corpus chunks through
    ``encode`` + FastResultHeapq with double-buffered async prefetch
  * multi-device  — corpus chunks sharded over the mesh's data axes by pjit
  * multi-node    — each process runs its driver over a fair-sharded
    corpus slice; local top-k states reduce through a ``ShardGather``
    transport (an O(Q*k*W) reduction, not O(Q*N))

Scoring is a pluggable backend (``EvaluationArguments.score_impl``, see
``sharded_search.SCORE_BACKENDS``), all returning identical rankings:
``numpy`` (host baseline), ``jax`` (device matmul), ``pallas_fused``
(in-kernel score+top-k; the (Q, C) score matrix never materializes).

Embedding caching: encoded chunks are written to the mmap'd
EmbeddingCache; subsequent calls stream cached vectors (paper Table 3
"w/ Cached Embs" path).

Online (cache-less) encoding runs through the bucketed encode pipeline
(``core.encode_pipeline``): background tokenization, ladder-bounded
encoder compiles, device-resident chunks streamed straight into the
driver's superchunk executor.  ``encode_buckets=0`` restores the legacy
per-batch pad-to-longest loop; rankings are identical either way.

Queries and corpora are ``{id: text}`` dicts or lazy
``repro.data.views`` compositions — views stream per chunk through the
driver, so filtered/combined corpora are searched without materialized
copies.  ``evaluate_suite`` builds on that: N datasets evaluated
per-dataset and against their lazily concatenated union, metric tables
written once per suite.
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import EvaluationArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.core.encode_pipeline import EncodePipeline, PipelineChunkSource
from repro.core.fair_sharding import FairSharder
from repro.core.metrics import compute_metrics
from repro.core.sharded_search import (  # noqa: F401 — re-exported API
    SCORE_BACKENDS, MergeFnGather, ProcessAllGather, ShardedSearchDriver,
    get_score_backend)
from repro.data.table import stable_id_hash, stable_id_hash_array
from repro.data.views import ConcatView, DatasetView, as_view


def select_hard_negatives(q_ids: Sequence[str], run_ids: np.ndarray,
                          scores: np.ndarray,
                          qrels: dict[str, dict[str, float]],
                          hash_to_raw: dict[int, str],
                          exclude_positives: bool = True
                          ) -> list[tuple[str, str, float]]:
    """Turn ranked (Q, depth) id hashes into negative qrel triplets.

    Vectorized per query: positives are hashed into one int64 array and
    excluded via ``np.isin`` over the whole ranked row, instead of a
    Python set-membership test per (query, rank) item.
    """
    out: list[tuple[str, str, float]] = []
    for qi, q in enumerate(q_ids):
        row = run_ids[qi]
        keep = row >= 0
        if exclude_positives:
            pos = [d for d, g in qrels.get(q, {}).items() if g > 0]
            if pos:
                keep &= ~np.isin(row, stable_id_hash_array(pos))
        out.extend(
            (q, hash_to_raw[h], s)
            for h, s in zip(row[keep].tolist(),
                            scores[qi][keep].tolist()))
    return out


def format_metrics_table(results: dict[str, dict]) -> str:
    """Markdown table: one row per dataset, one column per metric."""
    if not results:
        return "(no results)\n"
    metrics = list(next(iter(results.values())).keys())
    widths = [max(len("dataset"),
                  *(len(n) for n in results))] + [
        max(len(m), 6) for m in metrics]
    def fmt_row(cells):
        return "| " + " | ".join(
            c.ljust(w) for c, w in zip(cells, widths)) + " |\n"
    out = fmt_row(["dataset"] + metrics)
    out += "|" + "|".join("-" * (w + 2) for w in widths) + "|\n"
    for name, vals in results.items():
        out += fmt_row([name] + [f"{vals[m]:.4f}" for m in metrics])
    return out


class PreparedCorpus:
    """A corpus resolved once for repeated searches (serving regime).

    Bundles what :meth:`RetrievalEvaluator.search` used to recompute per
    call: the corpus id hashes, the sized object the FairSharder
    partitions positionally, and the chunk loader (mmap plan / encode
    pipeline / device-resident slices) the driver streams.

    A cache-backed preparation pins a :class:`CacheSnapshot`:
    ``generation`` carries its ``(generation, epoch)`` key, searches
    against this corpus are pinned to exactly that view (concurrent
    mutations and compactions never show through), and with W > 1
    workers the driver hands the key to the sharder so every worker of a
    round provably scores the same snapshot.  :meth:`close` releases the
    pin (so compaction may retire the old epoch's files); non-cache
    corpora have ``generation is None`` and :meth:`close` is a no-op.
    """

    __slots__ = ("hashes", "n_docs", "load_chunk", "sized", "generation",
                 "snapshot")

    def __init__(self, hashes: np.ndarray, n_docs: int, load_chunk,
                 sized=None, generation=None, snapshot=None):
        self.hashes = hashes
        self.n_docs = n_docs
        self.load_chunk = load_chunk
        self.sized = n_docs if sized is None else sized
        self.generation = generation
        self.snapshot = snapshot

    def __len__(self) -> int:
        return self.n_docs

    def close(self) -> None:
        if self.snapshot is not None:
            self.snapshot.close()

    def positions_to_ids(self, pos: np.ndarray) -> np.ndarray:
        """Map the driver's int32 global positions to 63-bit id hashes
        on the host (-1 marks empty slots)."""
        return np.where(pos >= 0, self.hashes[np.clip(pos, 0, None)], -1)

    def round_for(self, q_emb):
        """The ``(sized, load_chunk, positions_to_ids)`` triple for one
        search round against this query batch.

        Flat corpora are query-independent — every round scans the same
        ``[0, n_docs)`` space — so the prepared members come back as-is.
        Index-pruned corpora (:class:`IVFPreparedCorpus`) override this
        to derive a per-batch search space from the query embeddings.
        """
        return self.sized, self.load_chunk, self.positions_to_ids


class IVFSearchSpace:
    """The sized object for one IVF round: the concatenation of the
    selected clusters' permutation slices, positions ``[0, n_selected)``.
    ``partition_boundaries`` exposes the cluster edges inside that space
    so the :class:`~repro.core.fair_sharding.FairSharder` snaps shard
    cuts to whole clusters (each worker then streams a few contiguous
    permutation slices)."""

    __slots__ = ("n_selected", "partition_boundaries")

    def __init__(self, n_selected: int, partition_boundaries: np.ndarray):
        self.n_selected = n_selected
        self.partition_boundaries = partition_boundaries

    def __len__(self) -> int:
        return self.n_selected


class IVFPreparedCorpus(PreparedCorpus):
    """A corpus prepared behind an :class:`repro.index.ivf.IVFIndex`.

    ``fetch_rows(rows)`` serves arbitrary store rows (cache plan /
    materialized array); each :meth:`round_for` call selects this query
    batch's top-``nprobe`` clusters and virtualizes their concatenated
    permutation slices as the round's search space — the driver and
    kernels see an ordinary ``[0, n_selected)`` corpus and run
    completely unchanged.  With ``nprobe == n_clusters`` the space is
    the whole corpus (cluster-permuted), reproducing the flat ranking.
    """

    __slots__ = ("index", "fetch_rows", "nprobe")

    def __init__(self, hashes: np.ndarray, n_docs: int, fetch_rows,
                 index, nprobe: int, generation=None, snapshot=None):
        super().__init__(hashes, n_docs, load_chunk=None,
                         generation=generation, snapshot=snapshot)
        self.index = index
        self.fetch_rows = fetch_rows
        self.nprobe = int(nprobe)

    def round_for(self, q_emb):
        q = np.asarray(q_emb, np.float32)
        clusters = self.index.select(q, self.nprobe)
        sel_rows = self.index.gather_rows(clusters)
        sized = IVFSearchSpace(len(sel_rows),
                               self.index.slice_boundaries(clusters))
        fetch = self.fetch_rows

        def load_chunk(lo: int, hi: int):
            return fetch(sel_rows[lo:hi])

        def positions_to_ids(pos: np.ndarray) -> np.ndarray:
            if len(sel_rows) == 0:
                return np.full(np.shape(pos), -1, np.int64)
            # sel-space position -> store row -> id hash
            rows = sel_rows[np.clip(pos, 0, None)]
            return np.where(pos >= 0, self.hashes[rows], -1)

        return sized, load_chunk, positions_to_ids


class RetrievalEvaluator:
    def __init__(self, args: EvaluationArguments, retriever, collator,
                 params, mesh=None,
                 process_index: int | None = None,
                 process_count: int | None = None,
                 shard_merge_fn: Callable | None = None,
                 gather=None, sharder: FairSharder | None = None,
                 fault_injector=None):
        self.args = args
        # optional core.faults.FaultInjector threaded into every driver
        # this evaluator builds (chaos tests, serve --chaos)
        self.fault_injector = fault_injector
        self.retriever = retriever
        self.collator = collator
        self.params = params
        self.mesh = mesh
        self.process_index = (jax.process_index() if process_index is None
                              else process_index)
        self.process_count = (jax.process_count() if process_count is None
                              else process_count)
        # pass a shared FairSharder (e.g. SimulatedCluster.sharder) so all
        # workers of one cluster see the same throughput-EMA state
        self.sharder = (FairSharder(self.process_count) if sharder is None
                        else sharder)
        # shard-state transport, precedence: explicit merge fn (legacy
        # test injection) > explicit gather > jax.distributed allgather
        if shard_merge_fn is not None:
            self.gather = MergeFnGather(shard_merge_fn)
        elif gather is not None:
            self.gather = gather
        elif self.process_count > 1:
            self.gather = ProcessAllGather()
        else:
            self.gather = None
        self._encode_jit = jax.jit(
            lambda p, b: self.retriever.encoder.encode(p, b))
        # bucketed encode pipeline (encode_buckets=0 -> legacy per-batch
        # pad-to-longest loop, one XLA compile per distinct shape)
        data_args = getattr(collator, "args", None)
        self.encode_pipeline = (EncodePipeline(
            lambda p, b: self.retriever.encoder.encode(p, b),
            collator.tokenizer,
            append_eos=getattr(collator, "append_eos", False),
            pad_to_multiple=getattr(data_args, "pad_to_multiple", 8),
            buckets=args.encode_buckets,
            batch_size=args.encode_batch_size,
            tokenizer_workers=args.tokenizer_workers,
            depth=args.encode_pipeline_depth)
            if args.encode_buckets > 0 and data_args is not None
            and hasattr(collator, "tokenizer") else None)
        # (corpus_obj, key list, DictView): dict corpora are wrapped and
        # hashed once, reused across search/evaluate/mine_hard_negatives.
        self._corpus_view_cache: tuple[dict, list, DatasetView] | None = None

    # -- encoding ------------------------------------------------------------
    def _max_len(self, is_query: bool) -> int | None:
        resolve = getattr(self.collator, "max_len_for", None)
        if resolve is not None:
            return resolve(is_query)
        data_args = getattr(self.collator, "args", None)  # duck-types
        if data_args is None:
            return None
        return (data_args.query_max_len if is_query
                else data_args.passage_max_len)

    def _encode_texts(self, texts: Sequence[str], is_query: bool,
                      max_len: int | None = None,
                      device: bool = False,
                      min_batch_dim: int = 8):
        """Encode texts; ``device=True`` keeps the result device-resident
        (no per-chunk host round-trip) for the device score backends.
        ``min_batch_dim`` floors the pipeline's small-input batch dim
        (the serve frontend passes 1 for latency-proportional
        micro-batches; ignored on the legacy loop)."""
        fmt = (self.retriever.format_query if is_query
               else self.retriever.format_passage)
        bs = (self.args.query_batch_size if is_query
              else self.args.encode_batch_size)
        if max_len is None:
            # queries must truncate/pad at query_max_len, not silently
            # inherit the passage budget
            max_len = self._max_len(is_query)
        if self.encode_pipeline is not None:
            return self.encode_pipeline.encode(
                self.params, list(texts), max_len, fmt=fmt, device=device,
                batch_size=bs, min_batch_dim=min_batch_dim)
        out = []
        for lo in range(0, len(texts), bs):
            chunk = [fmt(t) for t in texts[lo: lo + bs]]
            batch = self.collator.encode_texts(chunk, max_len)
            enc = self._encode_jit(self.params, batch)
            out.append(enc if device else np.asarray(enc))
        if not out:
            return (jnp.empty((0, 0), jnp.float32) if device
                    else np.empty((0, 0), np.float32))
        return jnp.concatenate(out) if device else np.concatenate(out)

    def encode_corpus(self, ids: Sequence, texts: Sequence[str],
                      cache: EmbeddingCache | None = None,
                      device: bool = False):
        """Encode (with cache read/write) the given corpus slice.

        ``device=True`` without a cache keeps encoder output
        device-resident (the online regime: no d2h+h2d round-trip per
        chunk for the device score backends); cache read/write is a host
        path regardless, since the mmap'd cache stores numpy rows."""
        if cache is None and device:
            return self._encode_texts(texts, False, device=True)
        if cache is not None and len(cache):
            have = cache.has(ids)
        else:
            have = np.zeros(len(ids), bool)
        embs = np.empty((len(ids), 0), np.float32)
        missing = np.nonzero(~have)[0]
        if len(missing):
            enc = self._encode_texts([texts[i] for i in missing], False)
            embs = np.empty((len(ids), enc.shape[1]), np.float32)
            embs[missing] = enc
            if cache is not None:
                cache.cache_records([ids[i] for i in missing], enc)
        if have.any():
            got = cache.get([ids[i] for i in np.nonzero(have)[0]])
            if embs.shape[1] == 0:
                embs = np.empty((len(ids), got.shape[1]), np.float32)
            embs[np.nonzero(have)[0]] = got
        return embs

    def _corpus_view(self, corpus) -> DatasetView:
        """Coerce a corpus/query container to a lazy view.

        Views pass through (they cache their own id hashes); dicts are
        wrapped in a ``DictView`` memoized per (object, key list) — the
        key-list equality check (cheap C-level compare, pointer fast
        path) rather than identity alone means an in-place mutated dict
        is never served stale hashes.
        """
        if isinstance(corpus, DatasetView):
            return corpus
        if isinstance(corpus, dict):
            keys = list(corpus.keys())
            cached = self._corpus_view_cache
            if (cached is not None and cached[0] is corpus
                    and cached[1] == keys):
                return cached[2]
            view = as_view(corpus)
            self._corpus_view_cache = (corpus, keys, view)
            return view
        return as_view(corpus)

    def _corpus_hashes(self, corpus) -> np.ndarray:
        return np.asarray(self._corpus_view(corpus).id_hashes)

    # -- search ----------------------------------------------------------------
    def make_driver(self) -> ShardedSearchDriver:
        """This evaluator's :class:`ShardedSearchDriver` instantiation —
        the one thin object every search entry point (and the serve
        frontend, which keeps a persistent driver for round-pipelined
        micro-batches) is built on."""
        return ShardedSearchDriver(
            n_workers=self.process_count, worker_index=self.process_index,
            sharder=self.sharder, score_impl=self.args.score_impl,
            heap_impl=self.args.heap_impl,
            chunk_size=self.args.encode_batch_size,
            prefetch=self.args.async_prefetch, gather=self.gather,
            superchunk_size=self.args.superchunk_size,
            superchunk_max_mb=self.args.superchunk_max_mb,
            fault_injector=self.fault_injector,
            round_deadline_s=self.args.round_deadline_s,
            max_shard_retries=self.args.shard_retries,
            retry_backoff_s=self.args.shard_retry_backoff_s)

    def prepare_corpus(self, corpus, cache: EmbeddingCache | None = None,
                       *, device_resident: bool = False) -> "PreparedCorpus":
        """Resolve a corpus ONCE for repeated searches against it.

        Returns a :class:`PreparedCorpus` bundling the id hashes, the
        document count, and the chunk loader the driver streams — the
        cached-corpus ``row_plan``, the online encode-pipeline chunk
        source, or the encode-with-cache fallback, exactly as
        :meth:`search` used to resolve per call.  The serve frontend
        prepares once at startup so per-request work is only
        encode+score+merge.

        ``device_resident=True`` additionally materializes the corpus
        embeddings as one array living where scoring happens (device for
        the device backends, host for ``numpy``): chunk loads become
        zero-copy slices — no per-request mmap reads or encode. Encoding
        (and cache warm-up) happens here, so construction is the
        expensive pass.
        """
        on_device = self.args.score_impl != "numpy"
        corpus_v = self._corpus_view(corpus)
        corpus_texts = corpus_v.texts()
        all_hashes = np.asarray(corpus_v.id_hashes)
        n_docs = len(corpus_v)

        if self.args.index_impl == "ivf" and n_docs > 0:
            return self._prepare_ivf(corpus_v, cache,
                                     device_resident=device_resident)

        if device_resident:
            embs = self.encode_corpus(all_hashes, corpus_texts, cache)
            arr = jnp.asarray(embs, jnp.float32) if on_device \
                else np.asarray(embs, np.float32)
            return PreparedCorpus(all_hashes, n_docs,
                                  lambda lo, hi: arr[lo:hi])

        # cached-corpus plan: when the cache already covers the corpus,
        # pin a snapshot and resolve the position->row mapping ONCE (or
        # skip it entirely if the live rows are the corpus order)
        # instead of running a searchsorted per streamed chunk; chunk
        # loads become plain contiguous mmap reads that the driver
        # stacks and uploads once per superchunk.  The snapshot pins the
        # generation: concurrent mutations/compactions never show
        # through this prepared corpus.
        plan = snap = None
        if (cache is not None and len(cache)
                and self.args.use_cached_embeddings):
            snap = cache.snapshot()
            plan = snap.row_plan(all_hashes)
            if plan is None:
                snap.close()
                snap = None

        if plan is None and cache is None and \
                self.encode_pipeline is not None:
            # online regime: the bucketed pipeline streams ordered,
            # (device-resident for device backends) chunks straight into
            # the driver's executor — tokenize overlaps encode, encoder
            # compiles stay ladder-bounded, no per-chunk host round-trip.
            # ``corpus_texts`` is a lazy per-slice sequence, so view rows
            # materialize one pipeline window at a time.
            load_chunk = PipelineChunkSource(
                self.encode_pipeline, self.params,
                corpus_texts, self._max_len(False),
                fmt=self.retriever.format_passage, device=on_device)
        else:
            def load_chunk(lo: int, hi: int):
                if plan is not None:
                    kind, rows = plan
                    if kind == "range":
                        return snap.get_range(lo, hi).astype(np.float32)
                    return snap.get_rows(rows[lo:hi]).astype(np.float32)
                # cache keys are stable hashes, so the already-hashed id
                # slice addresses it for raw-id dicts and views alike
                return self.encode_corpus(
                    all_hashes[lo:hi], corpus_texts[lo:hi], cache,
                    device=on_device)
        return PreparedCorpus(all_hashes, n_docs, load_chunk,
                              sized=corpus_v,
                              generation=snap.key if snap else None,
                              snapshot=snap)

    def _prepare_ivf(self, corpus_v: DatasetView,
                     cache: EmbeddingCache | None, *,
                     device_resident: bool = False) -> "IVFPreparedCorpus":
        """Prepare a corpus behind a cluster-pruned IVF index.

        The coarse quantizer trains off contiguous ``get_range`` streams
        of a corpus-ordered row store — the cache's mmap plan when it
        covers the corpus (no full-corpus materialization), else the
        embeddings encoded here (warming ``cache`` when given).  A
        cache-backed index persists torn-write-safe under
        ``{cache.path}/ivf_k{K}`` keyed by a digest of the corpus hashes
        and build knobs, so repeated serve startups reload instead of
        retraining; any mismatch (corpus changed, knobs changed, torn
        save) silently rebuilds.
        """
        import os

        a = self.args
        on_device = a.score_impl != "numpy"
        all_hashes = np.asarray(corpus_v.id_hashes)
        n_docs = len(corpus_v)
        k = int(min(a.ivf_nclusters, n_docs))

        plan = snap = None
        if (cache is not None and len(cache)
                and a.use_cached_embeddings and not device_resident):
            snap = cache.snapshot()
            plan = snap.row_plan(all_hashes)
            if plan is None:
                snap.close()
                snap = None
        if plan is not None:
            kind, rows_map = plan
            dim = cache.dim
            if kind == "range":
                def get_range(lo, hi):
                    return snap.get_range(lo, hi).astype(np.float32)

                def fetch_rows(rows):
                    return snap.get_rows(rows).astype(np.float32)
            else:
                def get_range(lo, hi):
                    return snap.get_rows(rows_map[lo:hi]).astype(
                        np.float32)

                def fetch_rows(rows):
                    return snap.get_rows(rows_map[rows]).astype(
                        np.float32)
        else:
            # encode now (warming the cache when given) and keep the
            # embeddings as the row store; device-resident for the
            # device backends so chunk loads are zero-copy slices
            embs = np.asarray(
                self.encode_corpus(all_hashes, corpus_v.texts(), cache),
                np.float32)
            dim = embs.shape[1]

            def get_range(lo, hi):
                return embs[lo:hi]

            arr = (jnp.asarray(embs) if device_resident and on_device
                   else embs)

            def fetch_rows(rows):
                return arr[rows]

        from repro.index.ivf import corpus_digest

        # the cache generation is part of the digest: a mutated corpus
        # invalidates the persisted permutation (rebuild) instead of
        # silently loading a layout over a different row set
        digest = corpus_digest(all_hashes, seed=a.ivf_seed,
                               train_steps=a.ivf_train_steps,
                               train_batch=a.ivf_train_batch,
                               generation=snap.key if snap else None)
        index_dir = (os.path.join(cache.path, f"ivf_k{k}")
                     if cache is not None else None)
        index = None
        if index_dir is not None:
            from repro.index import IVFIndex
            index = IVFIndex.load(index_dir, expect_n=n_docs,
                                  expect_dim=dim, expect_clusters=k,
                                  expect_digest=digest)
        if index is None:
            from repro.index import IVFIndex
            index = IVFIndex.build(get_range, n_docs, k, seed=a.ivf_seed,
                                   train_steps=a.ivf_train_steps,
                                   train_batch=a.ivf_train_batch)
            if index_dir is not None:
                index.save(index_dir, digest=digest)
        return IVFPreparedCorpus(all_hashes, n_docs, fetch_rows, index,
                                 a.ivf_nprobe,
                                 generation=snap.key if snap else None,
                                 snapshot=snap)

    def prepare_cache_corpus(self, cache: EmbeddingCache,
                             generation=None) -> "PreparedCorpus":
        """Prepare the cache's *own* live document set for search — the
        live-serving entry point: the corpus is whatever is live in the
        pinned snapshot (adds/updates/deletes included), not an external
        id list.  ``generation`` accepts a ``(generation, epoch)`` key
        (e.g. the agreed key from a :class:`GenerationMismatch`) to pin
        a specific earlier view.  Chunk loads stream live rows straight
        off the snapshot's mmap — preparation is O(live-set) index work,
        no encoding — so swapping to a new generation between serve
        micro-batches is cheap."""
        snap = cache.snapshot(generation)
        if self.args.index_impl == "ivf" and snap.n_live > 0:
            return self._prepare_ivf_snapshot(cache, snap)

        def load_chunk(lo: int, hi: int):
            return snap.get_range(lo, hi).astype(np.float32)

        return PreparedCorpus(snap.ids, snap.n_live, load_chunk,
                              generation=snap.key, snapshot=snap)

    def _prepare_ivf_snapshot(self, cache: EmbeddingCache,
                              snap) -> "IVFPreparedCorpus":
        """IVF preparation over a pinned snapshot's live rows (the
        live-serving counterpart of :meth:`_prepare_ivf`)."""
        import os

        from repro.index import IVFIndex
        from repro.index.ivf import corpus_digest

        a = self.args
        n_docs = snap.n_live
        k = int(min(a.ivf_nclusters, n_docs))

        def get_range(lo, hi):
            return snap.get_range(lo, hi).astype(np.float32)

        def fetch_rows(rows):
            return snap.get_rows(rows).astype(np.float32)

        digest = corpus_digest(snap.ids, seed=a.ivf_seed,
                               train_steps=a.ivf_train_steps,
                               train_batch=a.ivf_train_batch,
                               generation=snap.key)
        index_dir = os.path.join(cache.path, f"ivf_k{k}")
        index = IVFIndex.load(index_dir, expect_n=n_docs,
                              expect_dim=cache.dim, expect_clusters=k,
                              expect_digest=digest)
        if index is None:
            index = IVFIndex.build(get_range, n_docs, k, seed=a.ivf_seed,
                                   train_steps=a.ivf_train_steps,
                                   train_batch=a.ivf_train_batch)
            index.save(index_dir, digest=digest)
        return IVFPreparedCorpus(snap.ids, n_docs, fetch_rows, index,
                                 a.ivf_nprobe, generation=snap.key,
                                 snapshot=snap)

    @staticmethod
    def _with_coverage(items, search_out):
        """Wrap ``items`` as a SearchOutcome when the driver's result
        carried coverage metadata (resilient gather); plain tuple
        otherwise — existing call sites keep unpacking unchanged."""
        coverage = getattr(search_out, "coverage", None)
        if coverage is None:
            return tuple(items)
        from repro.core.faults import SearchOutcome
        return SearchOutcome(items, coverage=coverage,
                             degraded=search_out.degraded)

    def search_prepared(self, queries, prepared: "PreparedCorpus",
                        topk: int | None = None,
                        deadline_s: float | None = None):
        """:meth:`search` against an already-prepared corpus."""
        topk = topk or self.args.topk
        on_device = self.args.score_impl != "numpy"
        q_view = self._corpus_view(queries)
        q_emb = self._encode_texts(q_view.texts(), True, device=on_device)
        driver = self.make_driver()
        sized, load_chunk, to_ids = prepared.round_for(q_emb)
        out = driver.search(q_emb, sized, load_chunk, topk,
                            deadline_s=deadline_s,
                            generation=prepared.generation)
        vals, pos = out
        return self._with_coverage(
            (np.asarray(q_view.id_hashes), to_ids(pos), vals), out)

    def search_texts(self, texts: Sequence[str],
                     prepared: "PreparedCorpus", topk: int | None = None,
                     min_batch_dim: int = 8,
                     deadline_s: float | None = None):
        """Raw-text query search against a prepared corpus — the serve
        backends' entry point (no query-id hashing; requests demux by
        position).  Returns ``(doc_id_hashes (Q, k), scores (Q, k))``
        (a ``SearchOutcome`` with per-query coverage under a resilient
        gather)."""
        topk = topk or self.args.topk
        on_device = self.args.score_impl != "numpy"
        q_emb = self._encode_texts(list(texts), True, device=on_device,
                                   min_batch_dim=min_batch_dim)
        driver = self.make_driver()
        sized, load_chunk, to_ids = prepared.round_for(q_emb)
        out = driver.search(q_emb, sized, load_chunk, topk,
                            deadline_s=deadline_s,
                            generation=prepared.generation)
        vals, pos = out
        return self._with_coverage((to_ids(pos), vals), out)

    def search(self, queries, corpus, topk: int | None = None,
               cache: EmbeddingCache | None = None):
        """Dense retrieval: -> (qid_hashes, doc_id_hashes (Q,k), scores).

        ``queries`` and ``corpus`` are ``{raw_id: text}`` dicts or any
        lazy :class:`~repro.data.views.DatasetView` composition (filter /
        map / select / concat / interleave) — views stream per chunk
        through the driver, so e.g. a ``ConcatView`` corpus is scored
        without the combined corpus ever existing in memory.

        Device-side top-k tracks int32 global corpus *positions*; they are
        mapped back to id hashes here on the host (JAX is 32-bit by
        default — 63-bit hashes would truncate on device).
        """
        return self.search_prepared(queries,
                                    self.prepare_corpus(corpus, cache),
                                    topk)

    # -- public API ---------------------------------------------------------------
    def evaluate(self, queries, corpus,
                 qrels: dict[str, dict[str, float]],
                 cache: EmbeddingCache | None = None) -> dict:
        """Metrics for one (queries, corpus, qrels) scenario.

        ``queries``/``corpus`` may be dicts or lazy views; ``qrels`` may
        be keyed by raw ids or by stable hashes (``stable_id_hash`` is
        the identity on already-hashed int ids).
        """
        out = self.search(queries, corpus, cache=cache)
        q_hashes, run_ids, _ = out
        qrels_h = {
            stable_id_hash(q): {stable_id_hash(d): float(g)
                                for d, g in docs.items()}
            for q, docs in qrels.items()}
        report = compute_metrics(self.args.metrics, run_ids, q_hashes,
                                 qrels_h)
        coverage = getattr(out, "coverage", None)
        if coverage is not None and getattr(out, "degraded", False):
            # a degraded (partially-recovered) search: record how much
            # of the corpus the rankings actually saw, so eval numbers
            # from a faulted run are never mistaken for full-coverage
            report["coverage"] = float(np.asarray(coverage).mean())
            report["degraded"] = True
        return report

    def evaluate_suite(self, scenarios: dict[str, dict], *,
                       combined: bool = True,
                       cache: EmbeddingCache | None = None,
                       out_dir: str | None = None,
                       suite_name: str = "evalsuite") -> dict:
        """Evaluate N datasets — per-dataset AND as one combined corpus.

        ``scenarios`` maps a dataset name to ``{"queries", "corpus",
        "qrels"}`` (dicts or views).  The combined pass concatenates the
        query and corpus *views* (``ConcatView``) and unions the qrels,
        so queries are scored against the union of all corpora without
        the union ever being built on disk or in RAM.  Dataset id
        spaces must be disjoint (namespace your ids per dataset, e.g.
        via ``view.map(..., rekey=True)``) — collisions raise.

        One shared ``cache`` (keyed by stable doc-id hash) serves every
        per-dataset pass and the combined pass.  Runs single- or
        multi-node with zero code changes: under a gather transport
        every worker computes identical tables and only worker 0 writes
        ``{out_dir}/{suite_name}.json`` / ``.md``.
        """
        results: dict[str, dict] = {}
        for name, sc in scenarios.items():
            results[name] = self.evaluate(sc["queries"], sc["corpus"],
                                          sc["qrels"], cache=cache)
        if combined and len(scenarios) > 1:
            q_views = [self._corpus_view(sc["queries"])
                       for sc in scenarios.values()]
            c_views = [self._corpus_view(sc["corpus"])
                       for sc in scenarios.values()]
            for kind, views in (("query", q_views), ("doc", c_views)):
                all_h = np.concatenate(
                    [np.asarray(v.id_hashes) for v in views])
                if len(np.unique(all_h)) != len(all_h):
                    raise ValueError(
                        f"duplicate {kind} ids across suite datasets — "
                        f"namespace ids per dataset (e.g. "
                        f"view.map(..., rekey=True)) before combining")
            merged_qrels: dict = {}
            for sc in scenarios.values():
                merged_qrels.update(sc["qrels"])
            results["combined"] = self.evaluate(
                ConcatView(*q_views), ConcatView(*c_views), merged_qrels,
                cache=cache)
        if out_dir is not None and self.process_index == 0:
            import json
            import os
            os.makedirs(out_dir, exist_ok=True)
            payload = {"suite": suite_name, "metrics": self.args.metrics,
                       "datasets": [n for n in scenarios],
                       "results": results}
            with open(os.path.join(out_dir, f"{suite_name}.json"),
                      "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            with open(os.path.join(out_dir, f"{suite_name}.md"), "w") as f:
                f.write(format_metrics_table(results))
        return results

    def mine_hard_negatives(self, queries, corpus,
                            qrels: dict[str, dict[str, float]],
                            depth: int | None = None,
                            exclude_positives: bool = True,
                            output_path: str | None = None,
                            cache: EmbeddingCache | None = None):
        """Top-ranked non-positives per query -> negative qrel triplets."""
        depth = depth or self.args.topk
        q_ids = self._corpus_view(queries).raw_ids()
        q_hashes, run_ids, scores = self.search(queries, corpus, topk=depth,
                                                cache=cache)
        corpus_v = self._corpus_view(corpus)
        hashes = np.asarray(corpus_v.id_hashes)
        hash_to_raw = dict(zip(hashes.tolist(), corpus_v.raw_ids()))
        out = select_hard_negatives(q_ids, run_ids, scores, qrels,
                                    hash_to_raw, exclude_positives)
        # every worker computes the identical merged triplets (allgather
        # semantics), so only worker 0 writes: W workers racing one
        # shared-FS path would tear or duplicate the file
        if output_path and self.process_index == 0:
            with open(output_path, "w") as f:
                for q, d, s in out:
                    f.write(f"{q}\t{d}\t{s}\n")
        return out
