"""Fair sharding: throughput-weighted shard sizes (paper §3.5).

Mixing devices with different throughput (or pods with stragglers) stalls
the fast ones under equal sharding.  ``FairSharder`` keeps an EMA of
per-worker throughput and splits each round's items proportionally, so all
workers finish together.  Also used for straggler mitigation: a slow
worker's share shrinks on the next round.

The EMA commits **per round**: ``update`` buffers observations and only
folds them into the EMA once every worker has reported the round.  Shard
bounds therefore stay frozen while a round is in flight — essential when
one sharder instance is shared by W workers (``SimulatedCluster``,
``ShardedSearchDriver``) that partition at different wall-clock times;
an immediately-applied EMA would hand late-partitioning workers
*different* bounds than early ones, silently overlapping or dropping
corpus slices.

On a real cluster each process holds its own replica and only observes
its own rank, so the search driver exchanges observations through the
gather transport (``ProcessAllGather.exchange_observations``) — every
replica then commits the identical complete round and all processes
keep computing identical bounds.
"""

from __future__ import annotations

import threading
import time

import numpy as np


class GenerationMismatch(RuntimeError):
    """Raised by :meth:`FairSharder.acquire` when this worker's pinned
    corpus generation disagrees with the round's agreed generation (the
    first acquirer's key wins).  The round is *not* consumed: the caller
    re-prepares its corpus at :attr:`agreed` (e.g.
    ``cache.snapshot(agreed)``) and re-acquires the same round."""

    def __init__(self, round_no: int, agreed, mine):
        super().__init__(
            f"round {round_no}: this worker is pinned to generation "
            f"{mine} but the round agreed on {agreed}; re-prepare at "
            f"the agreed generation and re-acquire")
        self.round_no = round_no
        self.agreed = agreed
        self.mine = mine


class ShardAborted(RuntimeError):
    """A sibling worker died mid-round (or a round wait timed out); this
    worker's wait was released.  Secondary casualty — cluster runners
    filter it in favor of the original error (like
    ``threading.BrokenBarrierError``).  The message carries real
    diagnostics: how many rounds committed and which workers the
    blocking round is still waiting on."""


class FairSharder:
    # acquire_bounds gives up after this long waiting for the previous
    # round to commit — a missing sibling report means a worker died
    ACQUIRE_TIMEOUT_S = 300.0

    def __init__(self, n_workers: int, alpha: float = 0.5,
                 min_share: float = 0.01):
        self.n = n_workers
        self.alpha = alpha
        self.min_share = min_share
        self.throughput = np.ones(n_workers, np.float64)
        # round-buffered observations, keyed per round:
        # round -> {worker: items/s} (None = reported with no timing
        # signal: an empty shard, or an absolved/recovered worker)
        self._pending: dict[int, dict[int, float | None]] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._committed = 0                  # rounds folded into the EMA
        self._issued = [0] * n_workers       # rounds begun, per worker
        # round -> agreed corpus generation key (first acquirer wins)
        self._round_gen: dict[int, object] = {}
        self._abort_exc: BaseException | None = None
        self._dead: set[int] = set()

    def shares(self, total_items: int) -> list[int]:
        """Split ``total_items`` proportionally to throughput.

        Invariants: shares are non-negative and sum to ``total_items``
        exactly; since ``frac`` is normalized, the floor() pass leaves a
        remainder in ``[0, n]`` (``n`` only reachable through float
        round-off in the normalization) which goes to the fastest
        workers, one item each.  ``total_items < n`` is legal: most
        floors are 0 and the remainder pass hands single items to the
        fastest workers, leaving the rest with empty (contiguous)
        bounds.

        Workers reported dead (:meth:`mark_dead`) get an exact-zero
        share — ``min_share`` applies to *live* workers only — so the
        next round's partition covers the corpus with survivors alone.
        """
        assert total_items >= 0, total_items
        with self._lock:
            w = np.maximum(self.throughput, 1e-9).copy()
            dead = set(self._dead)
        if len(dead) >= self.n:
            raise ShardAborted(
                f"all {self.n} workers are dead; no survivor left to "
                f"shard {total_items} items across")
        live = np.array([wk not in dead for wk in range(self.n)])
        w[~live] = 0.0
        frac = np.zeros(self.n, np.float64)
        lf = np.maximum(w[live] / w[live].sum(), self.min_share)
        frac[live] = lf / lf.sum()
        sizes = np.floor(frac * total_items).astype(int)
        rem = int(total_items - sizes.sum())
        # a remainder beyond n means frac was not normalized — the old
        # `order[i % n]` round-robin would silently paper over that
        assert 0 <= rem <= self.n, (
            f"floor remainder {rem} outside [0, {self.n}] "
            f"(total_items={total_items}, frac sum={frac.sum()!r})")
        live_order = [int(i) for i in np.argsort(-w, kind="stable")
                      if live[i]]
        for i in range(rem):
            sizes[live_order[i % len(live_order)]] += 1
        return sizes.tolist()

    def bounds(self, total_items: int,
               boundaries=None) -> list[tuple[int, int]]:
        """Contiguous ``[lo, hi)`` per worker covering ``total_items``.

        ``boundaries`` (optional, sorted, starting at 0 and ending at
        ``total_items``) restricts where cuts may land: each
        proportional cut point snaps to the nearest allowed boundary.
        The IVF search space passes its cluster edges here so every
        worker's shard is a run of *whole* clusters — shards stay
        contiguous permutation slices instead of slivers of every
        cluster.  Snapped cuts are forced monotone, so shards still
        partition ``[0, total_items)`` exactly (a slow worker may end
        up with an empty shard when its share is smaller than the
        cluster granularity).
        """
        sizes = self.shares(total_items)
        ends = np.cumsum(sizes)
        if boundaries is not None and total_items > 0:
            bnd = np.asarray(boundaries, np.int64)
            # snap each interior cut to the nearest cluster edge;
            # maximum.accumulate keeps the cut sequence monotone
            idx = np.searchsorted(bnd, ends[:-1])
            idx = np.clip(idx, 1, len(bnd) - 1)
            below = bnd[idx - 1]
            above = bnd[idx]
            snapped = np.where(ends[:-1] - below <= above - ends[:-1],
                               below, above)
            snapped = np.maximum.accumulate(snapped)
            ends = np.concatenate([snapped, ends[-1:]])
        starts = np.concatenate([[0], ends[:-1]])
        return list(zip(starts.tolist(), ends.tolist()))

    def _round_diagnostics(self) -> str:
        """Lock held.  Which round is blocking and who hasn't reported."""
        bucket = self._pending.get(self._committed, {})
        missing = [wk for wk in range(self.n)
                   if wk not in self._dead and wk not in bucket]
        parts = [f"rounds 0..{self._committed - 1} committed"
                 if self._committed else "no round committed yet",
                 f"round {self._committed} still pending reports from "
                 f"workers {missing}"]
        if self._dead:
            parts.append(f"dead workers: {sorted(self._dead)}")
        return "; ".join(parts)

    def acquire(self, worker: int, total_items: int, boundaries=None,
                generation=None) -> tuple[int, list[tuple[int, int]]]:
        """Round-versioned partition: ``(round_no, bounds)``.

        A worker's r-th call blocks until rounds ``0..r-1`` have all
        committed, so every worker reads the *same* EMA state for the
        same logical round.  The plain ``bounds()`` read is only safe
        when something else already orders rounds across workers (the
        sync path's gather barrier); with ``search_async`` a fast
        worker's report can commit a round *between* two workers'
        partition reads for the next one, silently splitting the corpus
        two different ways in a single round.

        Never blocks when rounds are already ordered (sync path, or
        ``n == 1``) — the wait condition is satisfied on entry.

        The returned ``round_no`` is the sharder-global round this
        partition belongs to — the key the fault-tolerant gather and
        round-tagged :meth:`update` use, and stable even when the caller
        constructs a fresh driver per round (the serve cluster backend).

        ``generation`` (optional, any comparable key — the cache's
        ``(generation, epoch)``) makes the round *generation-agreed*:
        the first acquirer's key becomes the round's generation, and a
        later acquirer pinned to a different one gets
        :class:`GenerationMismatch` without consuming the round — it
        re-prepares at the agreed key and re-acquires, so all W workers
        of a round provably score the same corpus snapshot even while a
        writer mutates the cache between rounds.
        """
        with self._cv:
            r = self._issued[worker]
            self._issued[worker] += 1
            deadline = time.monotonic() + self.ACQUIRE_TIMEOUT_S
            while self._committed < r and self._abort_exc is None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise ShardAborted(
                        f"worker {worker} waited "
                        f"{self.ACQUIRE_TIMEOUT_S}s for round {r - 1} "
                        f"to commit: {self._round_diagnostics()}")
                self._cv.wait(remaining)
            if self._abort_exc is not None:
                raise ShardAborted(
                    f"sharder aborted while worker {worker} waited for "
                    f"round {r}: {self._round_diagnostics()}"
                ) from self._abort_exc
            if generation is not None:
                agreed = self._round_gen.setdefault(r, generation)
                if agreed != generation:
                    # roll the issue back: the round was not consumed —
                    # the caller re-acquires it at the agreed generation
                    self._issued[worker] -= 1
                    raise GenerationMismatch(r, agreed, generation)
        # safe outside the lock: round r cannot commit (and move the
        # EMA) until THIS worker reports it, which happens only after
        # the caller scores the slice these bounds describe
        return r, self.bounds(total_items, boundaries)

    def acquire_bounds(self, worker: int, total_items: int,
                       boundaries=None) -> list[tuple[int, int]]:
        """:meth:`acquire` without the round number (legacy callers)."""
        return self.acquire(worker, total_items, boundaries)[1]

    def abort(self, exc: BaseException | None = None) -> None:
        """Release workers blocked in :meth:`acquire` when a sibling
        dies mid-round (mirrors the gather transports' abort)."""
        with self._cv:
            self._abort_exc = exc if exc is not None else RuntimeError(
                "aborted")
            self._cv.notify_all()

    def mark_dead(self, worker: int) -> None:
        """Remove ``worker`` from the cluster: it gets exact-zero shares
        from now on (see :meth:`shares`) and rounds stop waiting for its
        reports — any round blocked solely on it commits immediately.
        Unlike :meth:`abort`, survivors keep running."""
        with self._cv:
            self._dead.add(worker)
            self._try_commit_locked()
            self._cv.notify_all()

    def absolve(self, worker: int, round_no: int) -> None:
        """Count ``worker`` as having reported ``round_no`` without a
        throughput observation — used when its shard was recovered by a
        survivor (or given up) so the round can commit without it.  A
        no-op for already-committed rounds."""
        with self._cv:
            if round_no < self._committed:
                return
            self._pending.setdefault(round_no, {}).setdefault(worker,
                                                              None)
            self._try_commit_locked()

    def update(self, worker: int, items: int, seconds: float,
               round_no: int | None = None):
        """Report one worker's round observation.

        The observation is buffered per round; once every *live* worker
        has reported (or been absolved for) the oldest uncommitted
        round, its observations fold into the EMA atomically and the
        round commits.  (With ``n == 1`` this is an immediate update.)
        A worker with an empty shard reports ``items == 0`` and counts
        toward round completion without moving its EMA.

        ``round_no`` tags the observation with the round it belongs to
        (from :meth:`acquire`).  Without it, the report lands on the
        earliest uncommitted round this worker hasn't reported — the
        pre-fault-tolerance behavior.  Reports for already-committed
        rounds (a stalled straggler finishing after its shard was
        recovered) are dropped.
        """
        with self._cv:
            if round_no is None:
                round_no = self._committed
                while worker in self._pending.get(round_no, {}):
                    round_no += 1
            if round_no < self._committed:
                return                      # recovered behind its back
            bucket = self._pending.setdefault(round_no, {})
            if items > 0 and seconds > 0:
                bucket[worker] = items / seconds
            else:
                bucket.setdefault(worker, None)
            self._try_commit_locked()

    def _try_commit_locked(self) -> None:
        """Commit every leading round whose live workers all reported."""
        while True:
            needed = [wk for wk in range(self.n) if wk not in self._dead]
            if not needed:
                return                      # cluster fully dead
            bucket = self._pending.get(self._committed)
            if bucket is None or any(wk not in bucket for wk in needed):
                return
            for wk, obs in bucket.items():
                if obs is not None and wk not in self._dead:
                    self.throughput[wk] = (
                        self.alpha * obs
                        + (1 - self.alpha) * self.throughput[wk])
            del self._pending[self._committed]
            self._round_gen.pop(self._committed, None)
            self._committed += 1
            self._cv.notify_all()
