"""Fair sharding: throughput-weighted shard sizes (paper §3.5).

Mixing devices with different throughput (or pods with stragglers) stalls
the fast ones under equal sharding.  ``FairSharder`` keeps an EMA of
per-worker throughput and splits each round's items proportionally, so all
workers finish together.  Also used for straggler mitigation: a slow
worker's share shrinks on the next round.

The EMA commits **per round**: ``update`` buffers observations and only
folds them into the EMA once every worker has reported the round.  Shard
bounds therefore stay frozen while a round is in flight — essential when
one sharder instance is shared by W workers (``SimulatedCluster``,
``ShardedSearchDriver``) that partition at different wall-clock times;
an immediately-applied EMA would hand late-partitioning workers
*different* bounds than early ones, silently overlapping or dropping
corpus slices.

On a real cluster each process holds its own replica and only observes
its own rank, so the search driver exchanges observations through the
gather transport (``ProcessAllGather.exchange_observations``) — every
replica then commits the identical complete round and all processes
keep computing identical bounds.
"""

from __future__ import annotations

import threading

import numpy as np


class FairSharder:
    def __init__(self, n_workers: int, alpha: float = 0.5,
                 min_share: float = 0.01):
        self.n = n_workers
        self.alpha = alpha
        self.min_share = min_share
        self.throughput = np.ones(n_workers, np.float64)
        # round-buffered observations: worker -> items/s (None = reported
        # with no timing signal, e.g. an empty shard)
        self._pending: dict[int, float | None] = {}
        self._lock = threading.Lock()

    def shares(self, total_items: int) -> list[int]:
        """Split ``total_items`` proportionally to throughput.

        Invariants: shares are non-negative and sum to ``total_items``
        exactly; since ``frac`` is normalized, the floor() pass leaves a
        remainder in ``[0, n]`` (``n`` only reachable through float
        round-off in the normalization) which goes to the fastest
        workers, one item each.  ``total_items < n`` is legal: most
        floors are 0 and the remainder pass hands single items to the
        fastest workers, leaving the rest with empty (contiguous)
        bounds.
        """
        assert total_items >= 0, total_items
        with self._lock:
            w = np.maximum(self.throughput, 1e-9)
        frac = np.maximum(w / w.sum(), self.min_share)
        frac = frac / frac.sum()
        sizes = np.floor(frac * total_items).astype(int)
        rem = int(total_items - sizes.sum())
        # a remainder beyond n means frac was not normalized — the old
        # `order[i % n]` round-robin would silently paper over that
        assert 0 <= rem <= self.n, (
            f"floor remainder {rem} outside [0, {self.n}] "
            f"(total_items={total_items}, frac sum={frac.sum()!r})")
        order = np.argsort(-w, kind="stable")
        for i in range(rem):
            sizes[order[i % self.n]] += 1
        return sizes.tolist()

    def bounds(self, total_items: int) -> list[tuple[int, int]]:
        sizes = self.shares(total_items)
        ends = np.cumsum(sizes)
        starts = ends - sizes
        return list(zip(starts.tolist(), ends.tolist()))

    def update(self, worker: int, items: int, seconds: float):
        """Report one worker's round observation.

        The observation is buffered; once all ``n`` workers have
        reported the round, every buffered observation folds into the
        EMA atomically and the round resets.  (With ``n == 1`` this is
        an immediate update.)  A worker with an empty shard reports with
        ``items == 0`` and counts toward round completion without moving
        its EMA.
        """
        with self._lock:
            if items > 0 and seconds > 0:
                self._pending[worker] = items / seconds
            else:
                self._pending.setdefault(worker, None)
            if len(self._pending) < self.n:
                return
            for wk, obs in self._pending.items():
                if obs is not None:
                    self.throughput[wk] = (
                        self.alpha * obs
                        + (1 - self.alpha) * self.throughput[wk])
            self._pending.clear()
