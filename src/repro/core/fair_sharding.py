"""Fair sharding: throughput-weighted shard sizes (paper §3.5).

Mixing devices with different throughput (or pods with stragglers) stalls
the fast ones under equal sharding.  ``FairSharder`` keeps an EMA of
per-worker throughput and splits each round's items proportionally, so all
workers finish together.  Also used for straggler mitigation: a slow
worker's share shrinks on the next round.
"""

from __future__ import annotations

import numpy as np


class FairSharder:
    def __init__(self, n_workers: int, alpha: float = 0.5,
                 min_share: float = 0.01):
        self.n = n_workers
        self.alpha = alpha
        self.min_share = min_share
        self.throughput = np.ones(n_workers, np.float64)

    def shares(self, total_items: int) -> list[int]:
        w = np.maximum(self.throughput, 1e-9)
        frac = np.maximum(w / w.sum(), self.min_share)
        frac = frac / frac.sum()
        sizes = np.floor(frac * total_items).astype(int)
        # distribute the remainder to the fastest workers
        rem = total_items - sizes.sum()
        order = np.argsort(-w)
        for i in range(rem):
            sizes[order[i % self.n]] += 1
        return sizes.tolist()

    def bounds(self, total_items: int) -> list[tuple[int, int]]:
        sizes = self.shares(total_items)
        ends = np.cumsum(sizes)
        starts = ends - sizes
        return list(zip(starts.tolist(), ends.tolist()))

    def update(self, worker: int, items: int, seconds: float):
        if seconds <= 0 or items <= 0:
            return
        obs = items / seconds
        self.throughput[worker] = (
            self.alpha * obs + (1 - self.alpha) * self.throughput[worker])
