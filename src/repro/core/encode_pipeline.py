"""Recompile-free bucketed encode pipeline (paper §3.5 "no overhead").

The online-regime encoder wall has three parts, each addressed here:

  * **shape churn** — padding every batch to its own longest length
    compiles one XLA executable per distinct ``(B, L)`` shape, so a
    varied-length corpus compiles O(corpus / batch) times.  The pipeline
    sorts texts by token length and pads each fixed-batch-dim batch to
    the smallest rung of a geometric **bucket ladder**
    (:func:`bucket_ladder`), so total encoder compiles are bounded by
    the ladder size, and padding FLOPs track the text lengths instead of
    the per-batch maximum.  The original text order is restored on
    output — bucketing is invisible to callers.
  * **serial host tokenization** — :meth:`EncodePipeline.stream`
    tokenizes up to ``encode_pipeline_depth`` windows ahead of the
    device encode stage (bounded queue), so host tokenization overlaps
    device compute; each call runs :meth:`HashTokenizer.
    batch_encode_ids` (unique-token ``np.unique`` path) fanned over a
    ``tokenizer_workers`` pool — the fan-out parallelizes GIL-releasing
    tokenizers (e.g. duck-typed Rust HF tokenizers); for the
    pure-Python GIL-bound HashTokenizer the overlap is the win.
  * **host round-trips** — the jitted encode step donates its token
    buffers (accelerator backends; CPU skips the no-op donation) and
    its output can stay device-resident
    (``device=True``), flowing straight into
    ``ShardedSearchDriver``'s superchunk executor via
    :class:`PipelineChunkSource` (the driver's pull-based
    ``open_slice`` chunk-source contract) with no d2h+h2d per chunk.

Rankings are unchanged: bucketing only regroups rows and pads with
exactly-masked zeros, and every batch row is encoded independently.
"""

from __future__ import annotations

from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import HashTokenizer, pad_token_rows


def bucket_ladder(max_len: int, n_buckets: int = 6,
                  multiple: int = 8) -> tuple[int, ...]:
    """Geometric padded-length ladder: ``multiple`` ... ``max_len``.

    Rungs are multiples of ``multiple`` (SIMD/sublane alignment — also
    why padding with exact zeros keeps reductions bitwise stable across
    rungs), strictly increasing, and the top rung is exactly
    ``max_len`` (the tokenizer truncates there, so longer pads are
    waste).  At most ``n_buckets`` rungs; duplicates from rounding
    collapse.
    """
    max_len = max(int(max_len), 1)
    multiple = max(int(multiple), 1)
    if n_buckets <= 1 or max_len <= multiple:
        return (max_len,)
    rungs = []
    for i in range(n_buckets):
        frac = (max_len / multiple) ** (i / (n_buckets - 1))
        rung = -(-int(round(multiple * frac)) // multiple) * multiple
        rungs.append(min(rung, max_len))
    rungs[-1] = max_len
    return tuple(sorted(set(rungs)))


class EncodePipeline:
    """Parallel tokenize -> shape-bucketed batches -> donated jit encode.

    Parameters
    ----------
    encode_fn : ``(params, {"tokens", "mask"}) -> (B, d)`` pure encoder.
    tokenizer : :class:`HashTokenizer` (or duck-type with
        ``batch_encode_ids`` and ``pad_id``).
    append_eos / pad_to_multiple : collator tokenization settings.
    buckets : ladder rung count (compile bound per ``max_len``).
    batch_size : fixed batch dim; ragged tails pad up with masked rows.
    tokenizer_workers : host tokenization threads (<=1 = inline).
    depth : windows tokenized ahead of device encode in
        :meth:`stream` (0 = synchronous).
    """

    def __init__(self, encode_fn: Callable, tokenizer: HashTokenizer, *,
                 append_eos: bool = False, pad_to_multiple: int = 8,
                 buckets: int = 6, batch_size: int = 32,
                 tokenizer_workers: int = 2, depth: int = 2):
        self.tokenizer = tokenizer
        self.append_eos = append_eos
        self.pad_to_multiple = max(pad_to_multiple, 1)
        self.buckets = buckets
        self.batch_size = max(batch_size, 1)
        self.tokenizer_workers = max(tokenizer_workers, 1)
        self.depth = max(depth, 0)
        self.stats = {"compiles": 0, "batches": 0, "tokens_real": 0,
                      "tokens_padded": 0, "windows": 0}
        self._ladders: dict[int, tuple[int, ...]] = {}

        def _traced(params, tokens, mask):
            # trace-time side effect: runs once per (B, L) shape — the
            # real compile count, not a proxy
            self.stats["compiles"] += 1
            return encode_fn(params, {"tokens": tokens, "mask": mask})

        # donate the token buffers so accelerator backends can release
        # them for reuse mid-computation; on CPU an int32 (B, L) buffer
        # can never serve the float32 (B, d) output, so donation is pure
        # warning noise — skip it
        donate = () if jax.default_backend() == "cpu" else (1, 2)
        self._jit = jax.jit(_traced, donate_argnums=donate)

    # -- stage 1: host tokenization -------------------------------------------
    def tokenize(self, texts: Sequence[str], max_len: int,
                 fmt: Callable[[str], str] | None = None
                 ) -> list[list[int]]:
        """Token-id rows for ``texts``, fanned over the tokenizer pool."""
        texts = [fmt(t) for t in texts] if fmt is not None else list(texts)
        if (self.tokenizer_workers <= 1
                or len(texts) < 4 * self.tokenizer_workers):
            return self.tokenizer.batch_encode_ids(texts, max_len,
                                                   self.append_eos)
        step = -(-len(texts) // self.tokenizer_workers)
        # a per-call pool (like stream()'s tokenize-ahead pool): spawn
        # cost is microseconds against a window of tokenization, and no
        # idle threads outlive the call
        with ThreadPoolExecutor(self.tokenizer_workers,
                                thread_name_prefix="tokenize") as pool:
            parts = list(pool.map(
                lambda lo: self.tokenizer.batch_encode_ids(
                    texts[lo: lo + step], max_len, self.append_eos),
                range(0, len(texts), step)))
        return [row for part in parts for row in part]

    # -- stage 2: shape bucketing ---------------------------------------------
    def ladder(self, max_len: int) -> tuple[int, ...]:
        lad = self._ladders.get(max_len)
        if lad is None:
            lad = bucket_ladder(max_len, self.buckets, self.pad_to_multiple)
            self._ladders[max_len] = lad
        return lad

    def _fit(self, length: int, ladder: tuple[int, ...]) -> int:
        for rung in ladder:
            if rung >= length:
                return rung
        return ladder[-1]

    def _batch_dim(self, n: int, batch_size: int,
                   min_batch: int = 8) -> int:
        """Fixed batch dim: ``batch_size`` once the input covers it; a
        power-of-two below it for one-shot small inputs (still a bounded
        shape set — log2(batch_size) dims at most).  ``min_batch`` is the
        floor of that power-of-two ladder: the serve frontend passes 1 so
        a deadline-flushed single query encodes as (1, L) instead of
        padding to (8, L) — batch rows beyond ``n`` are exact-zero
        masked either way, so the choice never changes output rows."""
        if n >= batch_size:
            return batch_size
        b = max(1, min(min_batch, batch_size))
        while b < n:
            b <<= 1
        return min(b, batch_size)

    # -- stage 3: donated device encode ---------------------------------------
    def _encode_window(self, params, enc: list[list[int]], max_len: int,
                       device: bool, batch_size: int,
                       min_batch_dim: int = 8):
        """Encode one window of token rows; output rows restored to the
        window's original order (device- or host-resident)."""
        n = len(enc)
        if n == 0:
            return (jnp.empty((0, 0), jnp.float32) if device
                    else np.empty((0, 0), np.float32))
        ladder = self.ladder(max_len)
        b = self._batch_dim(n, batch_size, min_batch_dim)
        lengths = np.fromiter((len(e) for e in enc), np.int64, count=n)
        order = np.argsort(lengths, kind="stable")
        parts, perm = [], []
        for lo in range(0, n, b):
            idx = order[lo: lo + b]
            rows = [enc[i] for i in idx]
            rung = self._fit(max(lengths[idx].max(), 1), ladder)
            toks, mask = pad_token_rows(rows, rung, self.tokenizer.pad_id,
                                        n_rows=b)
            out = self._jit(params, toks, mask)
            parts.append(out[: len(idx)])
            perm.append(idx)
            self.stats["batches"] += 1
            self.stats["tokens_real"] += int(lengths[idx].sum())
            self.stats["tokens_padded"] += b * rung
        inverse = np.empty(n, np.int64)
        inverse[np.concatenate(perm)] = np.arange(n)
        self.stats["windows"] += 1
        if device:
            return jnp.concatenate(parts)[jnp.asarray(inverse)]
        return np.concatenate([np.asarray(p) for p in parts])[inverse]

    # -- public API -----------------------------------------------------------
    def encode(self, params, texts: Sequence[str], max_len: int, *,
               fmt: Callable[[str], str] | None = None,
               device: bool = False, batch_size: int | None = None,
               min_batch_dim: int = 8):
        """One-shot ordered encode of ``texts`` -> (N, d).

        ``min_batch_dim`` floors the power-of-two batch-dim ladder for
        inputs smaller than ``batch_size`` (see :meth:`_batch_dim`); the
        serve frontend passes 1 to keep single-query micro-batch latency
        proportional to one row, not eight."""
        enc = self.tokenize(texts, max_len, fmt)
        return self._encode_window(params, enc, max_len, device,
                                   batch_size or self.batch_size,
                                   min_batch_dim)

    def stream(self, params, texts: Sequence[str], *, lo: int, hi: int,
               chunk_size: int, max_len: int,
               fmt: Callable[[str], str] | None = None,
               device: bool = False):
        """Yield ``(offset, (chunk, d) embeddings)`` over ``texts[lo:hi)``
        in original order, ``chunk_size`` rows at a time.

        Texts are processed in windows (several chunks each, so length
        sorting has room to work); window ``w + 1`` tokenizes on a
        background thread while window ``w`` encodes on device — the
        bounded-queue host/device overlap, ``depth`` windows deep.
        """
        window = max(chunk_size, self.batch_size) * 8
        spans = [(s, min(s + window, hi)) for s in range(lo, hi, window)]
        if not spans:
            return

        def tok(span):
            return self.tokenize(texts[span[0]: span[1]], max_len, fmt)

        def emit(span, enc):
            ws, we = span
            embs = self._encode_window(params, enc, max_len, device,
                                       self.batch_size)
            for off in range(ws, we, chunk_size):
                yield off, embs[off - ws: min(off - ws + chunk_size,
                                              we - ws)]

        if self.depth == 0 or len(spans) == 1:
            for span in spans:
                yield from emit(span, tok(span))
            return
        with ThreadPoolExecutor(self.depth,
                                thread_name_prefix="tokenize-ahead") as ex:
            pending = deque(ex.submit(tok, span)
                            for span in spans[: self.depth])
            for i, span in enumerate(spans):
                enc = pending.popleft().result()
                if self.depth + i < len(spans):
                    pending.append(ex.submit(tok, spans[self.depth + i]))
                yield from emit(span, enc)

    def jit_cache_size(self) -> int | None:
        """Compiled-executable count straight from jax (when exposed)."""
        cache_size = getattr(self._jit, "_cache_size", None)
        return cache_size() if callable(cache_size) else None


class PipelineChunkSource:
    """Pull-based pipeline view for ``ShardedSearchDriver``.

    The driver duck-types its ``load_chunk`` argument: an object with
    ``open_slice(lo, hi, chunk_size)`` is asked for an ordered
    ``(offset, embeddings)`` iterator over its shard slice — the
    pipeline keeps tokenization overlapped behind the scenes and
    (``device=True``) hands back device-resident chunks that the
    superchunk executor stacks without a host round-trip.
    """

    def __init__(self, pipeline: EncodePipeline, params,
                 texts: Sequence[str], max_len: int, *,
                 fmt: Callable[[str], str] | None = None,
                 device: bool = False):
        self.pipeline = pipeline
        self.params = params
        self.texts = texts
        self.max_len = max_len
        self.fmt = fmt
        self.device = device

    def open_slice(self, lo: int, hi: int, chunk_size: int):
        return self.pipeline.stream(
            self.params, self.texts, lo=lo, hi=hi, chunk_size=chunk_size,
            max_len=self.max_len, fmt=self.fmt, device=self.device)
