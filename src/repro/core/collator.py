"""RetrievalCollator: tokenize + batch (paper §3.2.2)."""

from __future__ import annotations

import numpy as np

from repro.core.config import DataArguments
from repro.data.tokenizer import HashTokenizer


class RetrievalCollator:
    def __init__(self, args: DataArguments, tokenizer: HashTokenizer,
                 append_eos: bool | None = None):
        self.args = args
        self.tokenizer = tokenizer
        self.append_eos = (args.append_eos if append_eos is None
                           else append_eos)

    def _encode(self, texts, max_len):
        return self.tokenizer.batch_encode(
            texts, max_len, self.append_eos, self.args.pad_to_multiple)

    def __call__(self, features: list[dict]) -> dict:
        queries = [f["query"] for f in features]
        passages = [p for f in features for p in f["passages"]]
        q_tok, q_mask = self._encode(queries, self.args.query_max_len)
        p_tok, p_mask = self._encode(passages, self.args.passage_max_len)
        batch = {
            "query": {"tokens": q_tok, "mask": q_mask},
            "passage": {"tokens": p_tok, "mask": p_mask},
        }
        if "labels" in features[0]:
            batch["labels"] = np.stack([f["labels"] for f in features])
        return batch

    def max_len_for(self, is_query: bool) -> int:
        """The side's own token budget — queries must not silently
        inherit the passage budget.  Single source of truth for every
        encode entry point (``encode_texts``, the evaluator, the encode
        pipeline)."""
        return (self.args.query_max_len if is_query
                else self.args.passage_max_len)

    def encode_texts(self, texts: list[str], max_len: int | None = None,
                     is_query: bool = False):
        """Tokenize free-standing texts; ``max_len`` defaults to the
        side's own budget (see :meth:`max_len_for`)."""
        if max_len is None:
            max_len = self.max_len_for(is_query)
        toks, mask = self._encode(texts, max_len)
        return {"tokens": toks, "mask": mask}
