"""ShardedSearchDriver: the multi-node search engine (paper §3.5).

The paper's claim — "the same script runs on any number of nodes, and
inference time decreases linearly with the number of available nodes" —
is implemented here as a coordinator/worker driver that every search
entry point (``RetrievalEvaluator.search``, ``mine_hard_negatives``,
``launch.serve``, ``benchmarks.bench_multinode``) instantiates:

  * **partition** — the coordinator splits ``[0, n_docs)`` across workers
    with :class:`~repro.core.fair_sharding.FairSharder` (throughput EMA,
    updated after every round, so stragglers shrink next round);
  * **stream**    — each worker pulls its slice in ``chunk_size`` chunks
    through a caller-supplied ``load_chunk(lo, hi)`` (cache read / encode
    / h2d) with **double-buffered async prefetch**: chunk ``i+1``'s load
    overlaps chunk ``i``'s scoring on the worker's main thread;
  * **score**     — a pluggable backend (``SCORE_BACKENDS``) folds each
    chunk into a local :class:`FastResultHeapq` (Q, k) state;
  * **reduce**    — per-worker states merge through a
    :class:`ShardGather` transport via ``FastResultHeapq.merge_arrays``:
    an ``O(Q·k·W)`` reduction, never ``O(Q·N)``.

Transports: :class:`ProcessAllGather` (real multi-node via
``jax.distributed``) and ``repro.launch.distributed.InMemoryAllGather``
(W real drivers in one process — tests/benchmarks) are interchangeable;
all of them merge rank states in rank order, so every worker computes an
identical merged ranking.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fair_sharding import FairSharder
from repro.core.result_heap import FastResultHeapq

# -- score backends -----------------------------------------------------------
#
# A backend folds one corpus-embedding chunk into the running heap:
#   backend(q_emb, chunk_embs, id_offset, heap, k)
# where id_offset is the chunk's global corpus position (int32 positions
# on device; the host maps positions back to 63-bit id hashes).

_matmul_jit = jax.jit(lambda q, d: q @ d.T)


def _score_numpy(q_emb, embs, id_offset: int, heap: FastResultHeapq,
                 k: int) -> None:
    positions = np.arange(id_offset, id_offset + embs.shape[0],
                          dtype=np.int32)
    heap.update(np.asarray(q_emb) @ np.asarray(embs).T, positions)


def _score_jax(q_emb, embs, id_offset: int, heap: FastResultHeapq,
               k: int) -> None:
    scores = _matmul_jit(jnp.asarray(q_emb), jnp.asarray(embs))
    positions = jnp.arange(id_offset, id_offset + embs.shape[0],
                           dtype=jnp.int32)
    heap.update(scores, positions)


def _score_pallas_fused(q_emb, embs, id_offset: int, heap: FastResultHeapq,
                        k: int) -> None:
    from repro.kernels import ops as kops
    vals, ids = kops.fused_score_topk(jnp.asarray(q_emb), jnp.asarray(embs),
                                      k, id_offset=id_offset)
    heap.merge_arrays(vals, ids)


SCORE_BACKENDS: dict[str, Callable] = {
    "numpy": _score_numpy,
    "jax": _score_jax,
    "pallas_fused": _score_pallas_fused,
}


def get_score_backend(name: str) -> Callable:
    try:
        return SCORE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown score_impl {name!r}; expected one of "
            f"{sorted(SCORE_BACKENDS)}") from None


# -- shard-state transports ---------------------------------------------------


@runtime_checkable
class ShardGather(Protocol):
    """Reduces per-worker (Q, k) heap states to one merged state.

    ``merge`` must return the *same* merged ranking on every worker
    (allgather semantics), and must merge rank states in rank order so
    tie-breaking is deterministic across transports.
    """

    def merge(self, heap: FastResultHeapq,
              worker_index: int) -> FastResultHeapq: ...


class ProcessAllGather:
    """Real multi-node transport over ``jax.distributed``.

    Every process contributes its local (Q, k) state through
    ``multihost_utils.process_allgather``; each then merges all W states
    in rank order — the O(Q·k·W) cross-node reduction.  The merged heap
    keeps the local heap's impl so this transport is interchangeable
    with ``launch.distributed.InMemoryAllGather``.
    """

    def merge(self, heap: FastResultHeapq,
              worker_index: int) -> FastResultHeapq:
        from jax.experimental import multihost_utils
        vals, ids = heap.finalize()
        all_v = np.asarray(
            multihost_utils.process_allgather(jnp.asarray(vals)))
        all_i = np.asarray(
            multihost_utils.process_allgather(jnp.asarray(ids)))
        merged = FastResultHeapq(vals.shape[0], heap.k, impl=heap.impl)
        for p in range(all_v.shape[0]):
            merged.merge_arrays(all_v[p], all_i[p])
        return merged

    def exchange_observations(self, worker_index: int, items: int,
                              seconds: float) -> list[tuple[int, int,
                                                            float]]:
        """Allgather every worker's round observation so each process's
        local ``FairSharder`` replica commits the identical round (a
        process reporting only its own rank would leave the round
        incomplete forever and freeze the EMA)."""
        from jax.experimental import multihost_utils
        mine = jnp.asarray([float(worker_index), float(items), seconds],
                           jnp.float32)
        everyone = np.asarray(multihost_utils.process_allgather(mine))
        return [(int(rank), int(n), float(secs))
                for rank, n, secs in everyone]


class MergeFnGather:
    """Adapter for a plain ``heap -> heap`` merge callable (the
    evaluator's legacy ``shard_merge_fn`` injection point)."""

    def __init__(self, fn: Callable[[FastResultHeapq], FastResultHeapq]):
        self.fn = fn

    def merge(self, heap: FastResultHeapq,
              worker_index: int) -> FastResultHeapq:
        return self.fn(heap)


# -- the driver ---------------------------------------------------------------

ChunkLoader = Callable[[int, int], "np.ndarray | jax.Array"]


class ShardedSearchDriver:
    """One worker's view of a W-worker sharded dense search.

    Parameters
    ----------
    n_workers / worker_index : cluster shape and this worker's rank.
    sharder : shared :class:`FairSharder`; pass the *same* instance to
        all drivers of a cluster so the throughput EMA state is global.
    score_impl / heap_impl : backend names (see ``SCORE_BACKENDS`` and
        ``FastResultHeapq``).
    chunk_size : corpus items per streamed chunk.
    prefetch : double-buffer chunk loads (chunk ``i+1``'s cache-read /
        encode / h2d overlaps chunk ``i``'s scoring).  Never changes
        results — chunks are still scored in order — only overlap.
    gather : :class:`ShardGather` transport; ``None`` means local-only
        (the single-worker instantiation).
    """

    def __init__(self, *, n_workers: int = 1, worker_index: int = 0,
                 sharder: FairSharder | None = None,
                 score_impl: str = "jax", heap_impl: str = "jax",
                 chunk_size: int = 32, prefetch: bool = True,
                 gather: ShardGather | None = None):
        if not 0 <= worker_index < n_workers:
            raise ValueError(
                f"worker_index {worker_index} outside [0, {n_workers})")
        self.n_workers = n_workers
        self.worker_index = worker_index
        self.sharder = sharder if sharder is not None else FairSharder(
            n_workers)
        self.score_impl = score_impl
        self.heap_impl = heap_impl
        self.chunk_size = chunk_size
        self.prefetch = prefetch
        self.gather = gather
        # per-round observability (bench_multinode, serve logging)
        self.stats: dict = {}

    # -- coordinator ----------------------------------------------------------
    def partition(self, n_docs: int) -> list[tuple[int, int]]:
        """All workers' ``[lo, hi)`` corpus bounds for this round."""
        return self.sharder.bounds(n_docs)

    # -- worker ---------------------------------------------------------------
    def _pipelined_chunks(self, lo: int, hi: int, load_chunk: ChunkLoader):
        """Yield ``(offset, embeddings)`` for this worker's slice.

        With ``prefetch`` on, a single loader thread keeps exactly one
        chunk in flight ahead of scoring (double buffering): while the
        caller scores chunk ``i``, chunk ``i+1`` is being cache-read /
        encoded / copied to device.  Loads stay serialized with each
        other (one loader thread), so cache writes need no ordering
        logic here.
        """
        bounds = [(off, min(off + self.chunk_size, hi))
                  for off in range(lo, hi, self.chunk_size)]
        if not self.prefetch or len(bounds) <= 1:
            for off, end in bounds:
                yield off, load_chunk(off, end)
            return
        with ThreadPoolExecutor(max_workers=1,
                                thread_name_prefix="chunk-prefetch") as ex:
            fut = ex.submit(load_chunk, *bounds[0])
            for i, (off, _) in enumerate(bounds):
                embs = fut.result()
                if i + 1 < len(bounds):
                    fut = ex.submit(load_chunk, *bounds[i + 1])
                yield off, embs

    def search(self, q_emb, n_docs: int, load_chunk: ChunkLoader,
               topk: int):
        """Run this worker's encode→score→local-top-k round, then reduce.

        Returns the merged ``(scores (Q, k), positions (Q, k))`` —
        identical on every worker when a gather transport is set.
        Positions are global corpus offsets; ``-1`` marks empty slots.
        """
        n_queries = q_emb.shape[0]
        backend = get_score_backend(self.score_impl)
        heap = FastResultHeapq(n_queries, topk, impl=self.heap_impl)
        lo, hi = self.partition(n_docs)[self.worker_index]
        n_chunks = 0
        t0 = time.monotonic()
        for off, embs in self._pipelined_chunks(lo, hi, load_chunk):
            backend(q_emb, embs, off, heap, topk)
            n_chunks += 1
        seconds = time.monotonic() - t0
        # Report the round.  A shared sharder (SimulatedCluster) hears
        # every worker directly; with per-process sharder replicas (real
        # multi-node) the transport must exchange observations or no
        # replica would ever see a complete round.
        reports = [(self.worker_index, hi - lo, seconds)]
        exchange = getattr(self.gather, "exchange_observations", None)
        if self.n_workers > 1 and exchange is not None:
            reports = exchange(self.worker_index, hi - lo, seconds)
        for rank, items, secs in reports:
            self.sharder.update(rank, items, secs)
        self.stats = {"lo": lo, "hi": hi, "items": hi - lo,
                      "chunks": n_chunks, "seconds": seconds}
        if self.n_workers > 1 and self.gather is not None:
            heap = self.gather.merge(heap, self.worker_index)
        return heap.finalize()
