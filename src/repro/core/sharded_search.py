"""ShardedSearchDriver: the multi-node search engine (paper §3.5).

The paper's claim — "the same script runs on any number of nodes, and
inference time decreases linearly with the number of available nodes" —
is implemented here as a coordinator/worker driver that every search
entry point (``RetrievalEvaluator.search``, ``mine_hard_negatives``,
``launch.serve``, ``benchmarks.bench_multinode``) instantiates:

  * **partition** — the coordinator splits ``[0, n_docs)`` across workers
    with :class:`~repro.core.fair_sharding.FairSharder` (throughput EMA,
    updated after every round, so stragglers shrink next round);
  * **stream**    — each worker pulls its slice in ``chunk_size`` chunks
    through a caller-supplied ``load_chunk(lo, hi)`` (cache read / encode
    / h2d) with **double-buffered async prefetch**: chunk ``i+1``'s load
    overlaps chunk ``i``'s scoring on the worker's main thread;
  * **score**     — a pluggable backend (``SCORE_BACKENDS``) folds each
    chunk into a local :class:`FastResultHeapq` (Q, k) state;
  * **reduce**    — per-worker states merge through a
    :class:`ShardGather` transport via ``FastResultHeapq.merge_arrays``:
    an ``O(Q·k·W)`` reduction, never ``O(Q·N)``.

Transports: :class:`ProcessAllGather` (real multi-node via
``jax.distributed``) and ``repro.launch.distributed.InMemoryAllGather``
(W real drivers in one process — tests/benchmarks) are interchangeable;
all of them merge rank states in rank order, so every worker computes an
identical merged ranking.
"""

from __future__ import annotations

import math
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fair_sharding import FairSharder
from repro.core.faults import (FaultInjector, InjectedTransportDrop,
                               SearchOutcome)
from repro.core.result_heap import FastResultHeapq

# -- score backends -----------------------------------------------------------
#
# A backend folds one corpus-embedding chunk into the running heap:
#   backend(q_emb, chunk_embs, id_offset, heap, k)
# where id_offset is the chunk's global corpus position (int32 positions
# on device; the host maps positions back to 63-bit id hashes).

_matmul_jit = jax.jit(lambda q, d: q @ d.T)


def _score_numpy(q_emb, embs, id_offset: int, heap: FastResultHeapq,
                 k: int) -> None:
    positions = np.arange(id_offset, id_offset + embs.shape[0],
                          dtype=np.int32)
    heap.update(np.asarray(q_emb) @ np.asarray(embs).T, positions)


def _score_jax(q_emb, embs, id_offset: int, heap: FastResultHeapq,
               k: int) -> None:
    scores = _matmul_jit(jnp.asarray(q_emb), jnp.asarray(embs))
    positions = jnp.arange(id_offset, id_offset + embs.shape[0],
                           dtype=jnp.int32)
    heap.update(scores, positions)


def _score_pallas_fused(q_emb, embs, id_offset: int, heap: FastResultHeapq,
                        k: int) -> None:
    from repro.kernels import ops as kops
    vals, ids = kops.fused_score_topk(jnp.asarray(q_emb), jnp.asarray(embs),
                                      k, id_offset=id_offset)
    heap.merge_arrays(vals, ids)


SCORE_BACKENDS: dict[str, Callable] = {
    "numpy": _score_numpy,
    "jax": _score_jax,
    "pallas_fused": _score_pallas_fused,
}


def get_score_backend(name: str) -> Callable:
    try:
        return SCORE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown score_impl {name!r}; expected one of "
            f"{sorted(SCORE_BACKENDS)}") from None


# -- shard-state transports ---------------------------------------------------


@runtime_checkable
class ShardGather(Protocol):
    """Reduces per-worker (Q, k) heap states to one merged state.

    ``merge`` must return the *same* merged ranking on every worker
    (allgather semantics), and must merge rank states in rank order so
    tie-breaking is deterministic across transports.
    """

    def merge(self, heap: FastResultHeapq,
              worker_index: int) -> FastResultHeapq: ...


class ProcessAllGather:
    """Real multi-node transport over ``jax.distributed``.

    Every process contributes its local (Q, k) state through
    ``multihost_utils.process_allgather``; each then merges all W states
    in rank order — the O(Q·k·W) cross-node reduction.  The merged heap
    keeps the local heap's impl so this transport is interchangeable
    with ``launch.distributed.InMemoryAllGather``.
    """

    def merge(self, heap: FastResultHeapq,
              worker_index: int) -> FastResultHeapq:
        from jax.experimental import multihost_utils
        vals, ids = heap.finalize()
        all_v = np.asarray(
            multihost_utils.process_allgather(jnp.asarray(vals)))
        all_i = np.asarray(
            multihost_utils.process_allgather(jnp.asarray(ids)))
        merged = FastResultHeapq(vals.shape[0], heap.k, impl=heap.impl)
        for p in range(all_v.shape[0]):
            merged.merge_arrays(all_v[p], all_i[p])
        return merged

    def exchange_observations(self, worker_index: int, items: int,
                              seconds: float) -> list[tuple[int, int,
                                                            float]]:
        """Allgather every worker's round observation so each process's
        local ``FairSharder`` replica commits the identical round (a
        process reporting only its own rank would leave the round
        incomplete forever and freeze the EMA)."""
        from jax.experimental import multihost_utils
        mine = jnp.asarray([float(worker_index), float(items), seconds],
                           jnp.float32)
        everyone = np.asarray(multihost_utils.process_allgather(mine))
        return [(int(rank), int(n), float(secs))
                for rank, n, secs in everyone]


class MergeFnGather:
    """Adapter for a plain ``heap -> heap`` merge callable (the
    evaluator's legacy ``shard_merge_fn`` injection point)."""

    def __init__(self, fn: Callable[[FastResultHeapq], FastResultHeapq]):
        self.fn = fn

    def merge(self, heap: FastResultHeapq,
              worker_index: int) -> FastResultHeapq:
        return self.fn(heap)


# -- superchunk autotune ------------------------------------------------------
#
# The superchunk executor folds S streamed chunks into ONE jitted
# lax.scan dispatch (kernels.ops.superchunk_update).  How large S should
# be is a machine property: the ratio of per-dispatch overhead (Python +
# jit call + executable launch) to per-chunk device compute.  We measure
# both once per (shape, backend) key with a quick warmup — a no-op jit
# round-trip for the dispatch cost, a single-step scan for the per-chunk
# cost — and size S so dispatch overhead is ~5% of superchunk work.

_NOOP_DISPATCH_S: float | None = None
_AUTOTUNE_CACHE: dict[tuple, int] = {}


def _noop_dispatch_seconds() -> float:
    """Per-call overhead of dispatching a trivial jitted function."""
    global _NOOP_DISPATCH_S
    if _NOOP_DISPATCH_S is None:
        f = jax.jit(lambda x: x + 1)
        x = jnp.zeros((8, 8), jnp.float32)
        f(x).block_until_ready()
        best = math.inf
        for _ in range(5):
            t0 = time.perf_counter()
            f(x).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        _NOOP_DISPATCH_S = best
    return _NOOP_DISPATCH_S


def autotune_superchunk_size(n_queries: int, dim: int, chunk_size: int,
                             k: int, score_impl: str, merge_impl: str,
                             *, overhead_target: float = 0.05,
                             floor: int = 8, ceiling: int = 256) -> int:
    """Pick S so per-superchunk dispatch overhead is ~``overhead_target``
    of its device work.  Cached per (shape, backend) key; the warmup
    costs one small scan compile + a few microsecond-scale timed calls.
    """
    key = (n_queries, dim, chunk_size, k, score_impl, merge_impl,
           jax.default_backend())
    if key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]
    from repro.kernels import ops as kops
    rows = n_queries + (-n_queries) % 8
    # deterministic synthetic data (values are irrelevant to the timing)
    q = (jnp.arange(max(rows * dim, 1), dtype=jnp.float32)
         .reshape(rows, dim) % 7.0)
    tile = (jnp.arange(chunk_size * dim, dtype=jnp.float32)
            .reshape(1, chunk_size, dim) % 5.0)
    offs = jnp.zeros(1, jnp.int32)
    nvs = jnp.full(1, chunk_size, jnp.int32)

    def one_step(v, i):
        return kops.superchunk_update(v, i, q, tile, offs, nvs, k=k,
                                      score=score_impl, merge=merge_impl)

    v = jnp.full((rows, k), -jnp.inf, jnp.float32)
    i = jnp.full((rows, k), -1, jnp.int32)
    v, i = one_step(v, i)                     # compile
    jax.block_until_ready((v, i))
    per_chunk = math.inf
    for _ in range(3):
        t0 = time.perf_counter()
        v, i = one_step(v, i)
        jax.block_until_ready((v, i))
        per_chunk = min(per_chunk, time.perf_counter() - t0)
    dispatch = _noop_dispatch_seconds()
    compute = max(per_chunk - dispatch, 1e-7)
    s = int(math.ceil(dispatch / (overhead_target * compute)))
    s = max(floor, min(ceiling, s))
    _AUTOTUNE_CACHE[key] = s
    return s


# -- the driver ---------------------------------------------------------------

# legacy pull contract: (lo, hi) -> embeddings.  Objects exposing
# ``open_slice(lo, hi, chunk_size)`` (chunk sources, e.g. the bucketed
# encode pipeline) are accepted wherever a ChunkLoader is.
ChunkLoader = Callable[[int, int], "np.ndarray | jax.Array"]


class ShardedSearchDriver:
    """One worker's view of a W-worker sharded dense search.

    Parameters
    ----------
    n_workers / worker_index : cluster shape and this worker's rank.
    sharder : shared :class:`FairSharder`; pass the *same* instance to
        all drivers of a cluster so the throughput EMA state is global.
    score_impl / heap_impl : backend names (see ``SCORE_BACKENDS`` and
        ``FastResultHeapq``).
    chunk_size : corpus items per streamed chunk.
    prefetch : double-buffer chunk loads (chunk ``i+1``'s cache-read /
        encode / h2d overlaps chunk ``i``'s scoring).  Never changes
        results — chunks are still scored in order — only overlap.
    gather : :class:`ShardGather` transport; ``None`` means local-only
        (the single-worker instantiation).
    superchunk_size : chunks folded into one jitted scan dispatch
        (device backends only).  ``0`` = autotune from a warmup
        measurement; ``1`` = disable (one dispatch per chunk, the
        pre-superchunk behavior); ``N > 1`` = fixed.  Host backends
        (``score_impl='numpy'`` / ``heap_impl='python'``) always stream
        per-chunk.  Never changes results — the scan replays the exact
        per-chunk merge sequence on device.
    superchunk_max_mb : cap on the stacked (S, C, d) tile so autotuned
        or configured S can't blow device memory.
    fault_injector : optional :class:`repro.core.faults.FaultInjector`
        consulted at the chunk-load and gather fault points (chaos
        tests, ``serve --chaos``).  ``None`` = no injection.
    round_deadline_s / max_shard_retries / retry_backoff_s : recovery
        knobs forwarded to a resilient gather (one exposing
        ``merge_resilient``): how long a round waits for a silent
        worker before reassigning its shard, how many rescore attempts
        an orphaned shard gets, and the exponential-backoff base
        between attempts.  Ignored by barrier-style transports.
    """

    def __init__(self, *, n_workers: int = 1, worker_index: int = 0,
                 sharder: FairSharder | None = None,
                 score_impl: str = "jax", heap_impl: str = "jax",
                 chunk_size: int = 32, prefetch: bool = True,
                 gather: ShardGather | None = None,
                 superchunk_size: int = 0, superchunk_max_mb: int = 64,
                 fault_injector: FaultInjector | None = None,
                 round_deadline_s: float = 30.0,
                 max_shard_retries: int = 2,
                 retry_backoff_s: float = 0.05):
        if not 0 <= worker_index < n_workers:
            raise ValueError(
                f"worker_index {worker_index} outside [0, {n_workers})")
        if superchunk_size < 0:
            raise ValueError(
                f"superchunk_size must be >= 0, got {superchunk_size}")
        self.n_workers = n_workers
        self.worker_index = worker_index
        self.sharder = sharder if sharder is not None else FairSharder(
            n_workers)
        self.score_impl = score_impl
        self.heap_impl = heap_impl
        self.chunk_size = chunk_size
        self.prefetch = prefetch
        self.gather = gather
        self.superchunk_size = superchunk_size
        self.superchunk_max_mb = superchunk_max_mb
        self.fault_injector = fault_injector
        self.round_deadline_s = round_deadline_s
        self.max_shard_retries = max_shard_retries
        self.retry_backoff_s = retry_backoff_s
        # per-round observability (bench_multinode, serve logging)
        self.stats: dict = {}
        # round counter for the single-worker path (W>1 uses the
        # sharder-global round from FairSharder.acquire)
        self._local_round = 0
        # lazy single-thread executor for search_async reduces; one
        # thread serializes merges in submission order (determinism)
        self._reduce_pool: ThreadPoolExecutor | None = None

    # -- coordinator ----------------------------------------------------------
    def partition(self, n_docs) -> list[tuple[int, int]]:
        """All workers' ``[lo, hi)`` corpus bounds for this round.

        ``n_docs`` is a document count or any sized corpus object — in
        particular a lazy ``repro.data.views.DatasetView`` composition,
        which is partitioned positionally without ever materializing it.
        A sized object may expose ``partition_boundaries`` (sorted cut
        points covering ``[0, len)``, e.g. the IVF search space's
        cluster edges); shard cuts then snap to those boundaries so
        every worker's slice stays a run of whole clusters.
        """
        boundaries = getattr(n_docs, "partition_boundaries", None)
        if not isinstance(n_docs, (int, np.integer)):
            n_docs = len(n_docs)
        return self.sharder.bounds(int(n_docs), boundaries)

    # -- worker ---------------------------------------------------------------
    def _pipelined_chunks(self, lo: int, hi: int, load_chunk: ChunkLoader):
        """Yield ``(offset, embeddings)`` for this worker's slice.

        ``load_chunk`` is either the legacy ``(lo, hi) -> embeddings``
        callable, or a **chunk source** — an object with
        ``open_slice(lo, hi, chunk_size)`` returning an ordered
        ``(offset, embeddings)`` iterator (e.g.
        ``core.encode_pipeline.PipelineChunkSource``).  A source runs
        its own host/device overlap (background tokenize, bucketed
        encode), so the driver's prefetch thread stands down for it.

        With ``prefetch`` on (legacy callables), a single loader thread
        keeps exactly one chunk in flight ahead of scoring (double
        buffering): while the caller scores chunk ``i``, chunk ``i+1``
        is being cache-read / encoded / copied to device.  Loads stay
        serialized with each other (one loader thread), so cache writes
        need no ordering logic here.
        """
        open_slice = getattr(load_chunk, "open_slice", None)
        if open_slice is not None:
            if hi > lo:
                yield from open_slice(lo, hi, self.chunk_size)
            return
        bounds = [(off, min(off + self.chunk_size, hi))
                  for off in range(lo, hi, self.chunk_size)]
        if not self.prefetch or len(bounds) <= 1:
            for off, end in bounds:
                yield off, load_chunk(off, end)
            return
        with ThreadPoolExecutor(max_workers=1,
                                thread_name_prefix="chunk-prefetch") as ex:
            fut = ex.submit(load_chunk, *bounds[0])
            for i, (off, _) in enumerate(bounds):
                embs = fut.result()
                if i + 1 < len(bounds):
                    fut = ex.submit(load_chunk, *bounds[i + 1])
                yield off, embs

    # -- superchunk scan executor ---------------------------------------------
    def _resolve_superchunk_size(self, n_queries: int, dim: int,
                                 k: int) -> int:
        """Effective S for this search (config / autotune / memory cap)."""
        if self.superchunk_size == 1:
            return 1
        merge = "pallas" if self.heap_impl == "pallas" else "jax"
        s = (self.superchunk_size if self.superchunk_size > 1 else
             autotune_superchunk_size(n_queries, dim, self.chunk_size, k,
                                      self.score_impl, merge))
        # budget what actually uploads: compiled backends lane-align the
        # chunk axis to 128 (see superchunk_update), so a chunk_size=32
        # tile occupies 4x its nominal bytes on device
        from repro.kernels.ops import _default_interpret
        c = (self.chunk_size if _default_interpret()
             else self.chunk_size + (-self.chunk_size) % 128)
        tile_bytes = max(1, c * max(dim, 1) * 4)
        cap = max(1, (self.superchunk_max_mb << 20) // tile_bytes)
        return max(1, min(s, cap))

    def _chunk_iter(self, lo: int, hi: int, load_chunk: ChunkLoader,
                    round_no: int, phase: str):
        """The streamed chunk iterator, with the chunk-level fault point
        (injected crashes / stalls) applied before each chunk is
        scored."""
        chunks = self._pipelined_chunks(lo, hi, load_chunk)
        if self.fault_injector is None:
            return chunks

        def faulty():
            try:
                for ci, (off, embs) in enumerate(chunks):
                    self.fault_injector.on_chunk(self.worker_index,
                                                 round_no, ci, phase)
                    yield off, embs
            finally:
                # an injected crash abandons the iteration mid-slice;
                # close the pipeline generator NOW so its prefetch
                # executor shuts down instead of lingering until GC
                close = getattr(chunks, "close", None)
                if close is not None:
                    close()
        return faulty()

    def _search_superchunk(self, q_emb, heap: FastResultHeapq, chunks,
                           topk: int, s: int) -> int:
        """Stream the slice through one-dispatch-per-superchunk scans.

        Accumulates S loaded chunks (prefetch thread unchanged), stacks
        them into an (S, C, d) tile — ONE host->device upload per
        superchunk when chunks arrive as numpy — and folds the tile into
        the donated device-resident (Q, k) state via a single jitted
        lax.scan (``kernels.ops.superchunk_update``).  Returns the
        number of scan dispatches.
        """
        from repro.kernels import ops as kops
        n_q, dim = q_emb.shape
        c = self.chunk_size
        merge = "pallas" if self.heap_impl == "pallas" else "jax"
        pad_rows = (-n_q) % 8
        if isinstance(q_emb, np.ndarray):
            qp = np.pad(q_emb, ((0, pad_rows), (0, 0))) if pad_rows \
                else q_emb
        else:
            qp = jnp.pad(q_emb, ((0, pad_rows), (0, 0))) if pad_rows \
                else q_emb
        state_v = jnp.full((n_q + pad_rows, topk), -jnp.inf, jnp.float32)
        state_i = jnp.full((n_q + pad_rows, topk), -1, jnp.int32)
        dispatches = 0

        def flush(buf):
            nonlocal state_v, state_i, dispatches
            offs = np.zeros(s, np.int32)
            nvs = np.zeros(s, np.int32)
            for si, (off, embs) in enumerate(buf):
                offs[si] = off
                nvs[si] = embs.shape[0]
            if all(isinstance(e, np.ndarray) for _, e in buf):
                tile = np.zeros((s, c, dim), np.float32)
                for si, (_, embs) in enumerate(buf):
                    tile[si, :embs.shape[0]] = embs
            else:           # device-resident chunks (online encode path)
                parts = []
                for _, embs in buf:
                    e = jnp.asarray(embs, jnp.float32)
                    if e.shape[0] < c:
                        e = jnp.pad(e, ((0, c - e.shape[0]), (0, 0)))
                    parts.append(e)
                parts += [jnp.zeros((c, dim), jnp.float32)] * (s - len(buf))
                tile = jnp.stack(parts)
            state_v, state_i = kops.superchunk_update(
                state_v, state_i, qp, tile, offs, nvs, k=topk,
                score=self.score_impl, merge=merge)
            dispatches += 1

        buf: list = []
        for off, embs in chunks:
            buf.append((off, embs))
            if len(buf) == s:
                flush(buf)
                buf = []
        if buf:
            flush(buf)
        heap.adopt_state(state_v[:n_q], state_i[:n_q])
        return dispatches

    def _score_range(self, q_emb, lo: int, hi: int,
                     load_chunk: ChunkLoader, topk: int, round_no: int,
                     phase: str = "load"):
        """Score one ``[lo, hi)`` corpus range into a fresh heap.

        The single scoring implementation for both the worker's own
        shard (``phase='load'``) and a survivor rescoring an orphaned
        sibling shard (``phase='retry'``) — same chunking, same
        executor, same kernels, so a recovered shard's state is bitwise
        what the dead owner would have produced.  Returns ``(heap,
        dispatches, executor, superchunk_size)``.
        """
        n_queries = q_emb.shape[0]
        heap = FastResultHeapq(n_queries, topk, impl=self.heap_impl)
        scan_ok = (self.score_impl in ("jax", "pallas_fused")
                   and self.heap_impl in ("jax", "pallas") and hi > lo)
        s = (self._resolve_superchunk_size(n_queries, q_emb.shape[1], topk)
             if scan_ok else 1)
        chunks = self._chunk_iter(lo, hi, load_chunk, round_no, phase)
        if scan_ok and s > 1:
            executor = "superchunk"
            dispatches = self._search_superchunk(q_emb, heap, chunks,
                                                 topk, s)
        else:
            executor = "per_chunk"
            backend = get_score_backend(self.score_impl)
            dispatches = 0
            for off, embs in chunks:
                backend(q_emb, embs, off, heap, topk)
                dispatches += 1
        return heap, dispatches, executor, s

    def _rescore_shard(self, q_emb, lo: int, hi: int,
                       load_chunk: ChunkLoader, topk: int,
                       round_no: int):
        """Recovery callback for the resilient gather: re-run the
        scoring phase over an orphaned sibling shard and return its
        finalized ``(vals, ids)`` state."""
        heap, _, _, _ = self._score_range(q_emb, lo, hi, load_chunk,
                                          topk, round_no, phase="retry")
        return heap.finalize()

    def _score_local(self, q_emb, n_docs, load_chunk: ChunkLoader,
                     topk: int, deadline_s: float | None = None,
                     generation=None):
        """The scoring phase of one round: stream this worker's shard
        slice into a **fresh** local (Q, k) heap and report the round's
        throughput observation.  Every call builds its own
        ``FastResultHeapq`` — donated device buffers are never shared
        between rounds, so a previous round's state may still be merging
        (``search_async``) while this round scores.  Returns ``(heap,
        round_ctx)`` — the context the reduce phase needs for resilient
        merging (round number, the round's full bounds, and a rescore
        callback for orphaned sibling shards)."""
        n_queries = q_emb.shape[0]
        boundaries = getattr(n_docs, "partition_boundaries", None)
        if not isinstance(n_docs, (int, np.integer)):
            n_docs = len(n_docs)
        if self.n_workers > 1:
            # round-versioned partition: with async reduces, workers'
            # scoring phases are no longer barrier-ordered, so a plain
            # bounds() read could straddle an EMA commit and split the
            # corpus differently on different ranks within one round.
            # The sharder-global round number also keys the resilient
            # gather and the round-tagged EMA report — stable even when
            # the caller builds a fresh driver per round (serve).
            # ``generation`` (a prepared corpus's snapshot key) makes
            # the round generation-agreed: a GenerationMismatch raised
            # here propagates before any scoring, the caller re-prepares
            # at the agreed key and retries the same round.
            round_no, bounds = self.sharder.acquire(
                self.worker_index, int(n_docs), boundaries,
                generation=generation)
        else:
            round_no = self._local_round
            self._local_round += 1
            bounds = self.sharder.bounds(int(n_docs), boundaries)
        lo, hi = bounds[self.worker_index]
        n_chunks = -(-max(hi - lo, 0) // self.chunk_size)
        t0 = time.monotonic()
        heap, dispatches, executor, s = self._score_range(
            q_emb, lo, hi, load_chunk, topk, round_no)
        seconds = time.monotonic() - t0
        # Report the round.  A shared sharder (SimulatedCluster) hears
        # every worker directly; with per-process sharder replicas (real
        # multi-node) the transport must exchange observations or no
        # replica would ever see a complete round.
        reports = [(self.worker_index, hi - lo, seconds)]
        exchange = getattr(self.gather, "exchange_observations", None)
        if self.n_workers > 1 and exchange is not None:
            reports = exchange(self.worker_index, hi - lo, seconds)
        for rank, items, secs in reports:
            self.sharder.update(rank, items, secs, round_no=round_no)
        self.stats = {"lo": lo, "hi": hi, "items": hi - lo,
                      "chunks": n_chunks, "seconds": seconds,
                      "executor": executor, "superchunk_size": s,
                      "dispatch_rounds": dispatches, "round": round_no}
        ctx = {
            "round_no": round_no,
            "bounds": bounds,
            "deadline_s": deadline_s,
            "rescore": lambda rlo, rhi: self._rescore_shard(
                q_emb, rlo, rhi, load_chunk, topk, round_no),
        }
        return heap, ctx

    def _reduce(self, heap: FastResultHeapq, ctx: dict | None = None):
        """The reduce phase: cross-worker gather/merge + host finalize.

        With a resilient gather (one exposing ``merge_resilient``) the
        merge recovers orphaned sibling shards and the result is a
        :class:`~repro.core.faults.SearchOutcome` carrying per-query
        coverage; barrier transports return the plain finalized tuple.
        """
        if self.n_workers > 1 and self.gather is not None:
            round_no = ctx["round_no"] if ctx is not None else None
            resilient = getattr(self.gather, "merge_resilient", None)
            if resilient is not None and ctx is not None:
                dropped = False
                if self.fault_injector is not None:
                    try:
                        self.fault_injector.on_gather(self.worker_index,
                                                      round_no)
                    except InjectedTransportDrop:
                        # this worker's state is lost in flight; it
                        # stays alive and joins the recovery instead
                        dropped = True
                vals, ids, coverage = resilient(
                    heap, self.worker_index, round_no, ctx["bounds"],
                    ctx["rescore"], dropped=dropped,
                    round_deadline_s=self.round_deadline_s,
                    max_retries=self.max_shard_retries,
                    backoff_s=self.retry_backoff_s,
                    deadline_s=ctx["deadline_s"])
                return SearchOutcome(
                    (vals, ids), coverage=coverage,
                    degraded=bool((coverage < 1.0).any()))
            if self.fault_injector is not None and round_no is not None:
                # a drop against a barrier transport propagates: the
                # legacy abort-the-round behavior
                self.fault_injector.on_gather(self.worker_index, round_no)
            heap = self.gather.merge(heap, self.worker_index)
        return heap.finalize()

    def search(self, q_emb, n_docs, load_chunk: ChunkLoader,
               topk: int, deadline_s: float | None = None,
               generation=None):
        """Run this worker's encode→score→local-top-k round, then reduce.

        ``n_docs`` may be an int or a sized corpus object (e.g. a lazy
        ``DatasetView``) — the FairSharder partitions it positionally.
        Returns the merged ``(scores (Q, k), positions (Q, k))`` —
        identical on every worker when a gather transport is set.
        Positions are global corpus offsets; ``-1`` marks empty slots.

        ``deadline_s`` (resilient gather only) bounds how long the
        reduce phase may spend recovering orphaned shards; past it the
        round resolves partial — a ``SearchOutcome`` with ``degraded``
        set and per-query ``coverage`` < 1 — instead of raising.

        ``generation`` (optional snapshot key, W > 1 only) pins the
        round to one corpus generation via the sharder's agreement —
        see :meth:`FairSharder.acquire`.  A
        :class:`~repro.core.fair_sharding.GenerationMismatch` raises
        before any scoring or reporting, so the caller can re-prepare
        and call again for the same round.
        """
        heap, ctx = self._score_local(q_emb, n_docs, load_chunk, topk,
                                      deadline_s, generation)
        return self._reduce(heap, ctx)

    def search_async(self, q_emb, n_docs, load_chunk: ChunkLoader,
                     topk: int, deadline_s: float | None = None,
                     generation=None) -> Future:
        """Like :meth:`search`, but the reduce phase (shard gather/merge
        + host finalize) runs on a driver-owned background thread and the
        merged ``(scores, positions)`` come back as a Future.

        The scoring phase still runs synchronously on the caller's
        thread, so by the time this returns the caller may start the
        *next* round's scoring while this round's merge is in flight —
        the round-pipelined regime behind ``launch.serve``'s continuous
        batching and the W=4 scaling-efficiency fix (the per-round
        O(Q·k·W) merge used to serialize after every round's scoring).
        Reduces are serialized in submission order on one thread, so
        results — and the gather transport's rank-order merge — are
        bitwise identical to the synchronous path.
        """
        heap, ctx = self._score_local(q_emb, n_docs, load_chunk, topk,
                                      deadline_s, generation)
        if self._reduce_pool is None:
            self._reduce_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="shard-reduce")
        return self._reduce_pool.submit(self._reduce, heap, ctx)

    def close(self) -> None:
        """Drain and shut down the async-reduce thread (no-op when
        :meth:`search_async` was never used)."""
        if self._reduce_pool is not None:
            self._reduce_pool.shutdown(wait=True)
            self._reduce_pool = None
