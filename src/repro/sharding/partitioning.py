"""Logical-axis partitioning rules -> concrete ``PartitionSpec``s.

Parameters and activations are annotated with *logical* axis names
("vocab", "heads", "ffn", "experts", "batch", "kv_seq", ...).  At lowering
time the rules below resolve each logical axis to a mesh axis, guarded by
divisibility: jit input shardings must divide the dimension evenly (GSPMD
does not pad *inputs*), so a logical axis whose size is not divisible by
its mesh axis falls back to replication.  This keeps every
(arch x shape x mesh) cell compilable while preserving the intended
sharding wherever the architecture's dimensions allow it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Default logical -> mesh-axis mapping.  "batch"-like axes span the
# data-parallel axes (pod composes with data so adding pods scales DP);
# "model"-like axes carry tensor/expert parallelism.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # data-parallel axes
    "batch": ("pod", "data"),
    "corpus": ("pod", "data"),          # corpus shards at inference
    "candidates": ("pod", "data"),      # recsys retrieval candidates
    "nodes": ("pod", "data"),           # GNN node tables
    "edges": ("pod", "data"),           # GNN edge lists
    "kv_seq": ("pod", "data"),          # long-context decode: shard the KV cache
    # model-parallel axes
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "expert_ffn": ("model",),
    "embed_rows": ("model",),           # recsys embedding-table rows
    "embed": ("model",),                # d_model sharding of embedding tables
    # replicated
    "layers": (),
    "d_model": (),
    "pos": (),
    "dense": (),
}


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Resolves logical axis names against a concrete mesh."""

    rules: Mapping[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def with_overrides(self, **overrides: tuple[str, ...]) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(overrides)
        return AxisRules(merged)

    def mesh_axes_for(self, logical: str | None, mesh: Mesh) -> tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical, ())
        # Drop mesh axes that do not exist on this mesh (e.g. "pod" on the
        # single-pod mesh).
        return tuple(a for a in axes if a in mesh.shape)

    def spec_for(
        self,
        logical_axes: Sequence[str | None],
        dims: Sequence[int],
        mesh: Mesh,
    ) -> P:
        """PartitionSpec for an array with the given logical axes & shape.

        Applies the divisibility guard per-dimension: if the dim size is not
        divisible by the product of the mapped mesh axes, the dim is
        replicated instead.
        """
        assert len(logical_axes) == len(dims), (logical_axes, dims)
        entries: list[Any] = []
        used: set[str] = set()
        for logical, dim in zip(logical_axes, dims):
            axes = self.mesh_axes_for(logical, mesh)
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                entries.append(None)
                continue
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if size <= 1 or dim % size != 0:
                # Try a prefix of the axes (e.g. shard on "pod" only).
                ok: tuple[str, ...] = ()
                for i in range(len(axes) - 1, 0, -1):
                    sub = axes[:i]
                    sz = int(np.prod([mesh.shape[a] for a in sub]))
                    if sz > 1 and dim % sz == 0:
                        ok = sub
                        break
                axes = ok
            if not axes:
                entries.append(None)
            else:
                used.update(axes)
                entries.append(axes if len(axes) > 1 else axes[0])
        return P(*entries)

    def sharding_for(
        self,
        logical_axes: Sequence[str | None],
        dims: Sequence[int],
        mesh: Mesh,
    ) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(logical_axes, dims, mesh))


def tree_pspecs(
    abstract_tree: Any,
    logical_tree: Any,
    mesh: Mesh,
    rules: AxisRules | None = None,
) -> Any:
    """Map a pytree of ShapeDtypeStructs + logical axes to PartitionSpecs."""
    rules = rules or AxisRules()

    def resolve(leaf: jax.ShapeDtypeStruct, axes: Sequence[str | None]) -> P:
        return rules.spec_for(axes, leaf.shape, mesh)

    return jax.tree.map(
        resolve, abstract_tree, logical_tree,
        is_leaf=lambda x: isinstance(x, (tuple, list)) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(abstract_tree, logical_tree, mesh, rules=None):
    rules = rules or AxisRules()
    specs = tree_pspecs(abstract_tree, logical_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes present on this mesh (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_parallelism(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


def model_parallelism(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


def local_mesh() -> Mesh:
    """A mesh over whatever devices exist (tests / single host runs)."""
    from repro.sharding import make_mesh
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
