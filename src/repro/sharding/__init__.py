"""Sharding utilities: mesh construction + logical-axis partitioning.

``make_mesh`` is the version-tolerant mesh constructor: newer JAX
releases accept (and some sharding passes want) ``axis_types``, while
older releases have neither ``jax.sharding.AxisType`` nor the
``axis_types`` kwarg on ``jax.make_mesh``.  All mesh construction in the
repo goes through here so the JAX version is probed in exactly one place.
"""

from __future__ import annotations

import inspect
from typing import Sequence

import jax

_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              *, axis_types=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` wrapper tolerant of pre-``AxisType`` JAX.

    When the installed JAX supports axis types, every axis defaults to
    ``AxisType.Auto`` (the sharding behaviour older releases implement
    unconditionally); otherwise the kwarg is dropped.
    """
    shape = tuple(shape)
    axes = tuple(axes)
    if not _MAKE_MESH_TAKES_AXIS_TYPES:
        return jax.make_mesh(shape, axes)
    if axis_types is None and hasattr(jax.sharding, "AxisType"):
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)
