"""Trove-JAX: a multi-pod dense-retrieval framework (paper: ir-trove).

``from repro import *`` mirrors the paper's ``from trove import *``.

Exports resolve lazily (PEP 562) so that ``python -m repro.launch.dryrun``
can set XLA_FLAGS before anything imports jax.
"""

import importlib

_EXPORTS = {
    "RetrievalCollator": "repro.core.collator",
    "DataArguments": "repro.core.config",
    "EvaluationArguments": "repro.core.config",
    "MaterializedQRelConfig": "repro.core.config",
    "ModelArguments": "repro.core.config",
    "RetrievalTrainingArguments": "repro.core.config",
    "parse_cli": "repro.core.config",
    "BinaryDataset": "repro.core.datasets",
    "EncodingDataset": "repro.core.datasets",
    "MultiLevelDataset": "repro.core.datasets",
    "EmbeddingCache": "repro.core.embedding_cache",
    "RetrievalEvaluator": "repro.core.evaluator",
    "MaterializedQRel": "repro.core.materialized_qrel",
    "IRMetrics": "repro.core.metrics",
    "compute_metrics": "repro.core.metrics",
    "FastResultHeapq": "repro.core.result_heap",
    "FairSharder": "repro.core.fair_sharding",
    "ShardedSearchDriver": "repro.core.sharded_search",
    "SimulatedCluster": "repro.launch.distributed",
    "register_loader": "repro.data.loaders",
    "HashTokenizer": "repro.data.tokenizer",
    "DefaultEncoder": "repro.models.encoder",
    "PretrainedEncoder": "repro.models.encoder",
    "get_encoder": "repro.models.encoder",
    "RetrievalLoss": "repro.models.losses",
    "get_loss": "repro.models.losses",
    "BiEncoderRetriever": "repro.models.retriever",
    "GradedBiEncoderRetriever": "repro.models.retriever",
    "PretrainedRetriever": "repro.models.retriever",
    "RetrievalTrainer": "repro.training.trainer",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return __all__
