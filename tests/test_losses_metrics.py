import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import IRMetrics, compute_metrics
from repro.models.losses import (LOSS_REGISTRY, BCELoss, InfoNCELoss,
                                 KLDivergenceLoss, ListNetLoss,
                                 RetrievalLoss, WassersteinLoss, get_loss)


def test_registry_aliases():
    for alias in ("infonce", "kl", "ws", "listnet", "bce"):
        assert alias in LOSS_REGISTRY
        assert isinstance(get_loss(alias), RetrievalLoss)


def test_custom_loss_autoregisters():
    class MyLoss(RetrievalLoss):
        _alias = "my_test_loss"

        def __call__(self, scores, labels):
            return jnp.float32(0.0)

    assert isinstance(get_loss("my_test_loss"), MyLoss)


def test_infonce_perfect_scores():
    scores = jnp.eye(4) * 100.0
    labels = jnp.arange(4)
    assert float(InfoNCELoss()(scores, labels)) < 1e-3
    # uniform scores -> log(P)
    uniform = jnp.zeros((4, 4))
    np.testing.assert_allclose(
        float(InfoNCELoss()(uniform, labels)), np.log(4), rtol=1e-5)


def test_kl_zero_when_matched():
    labels = jnp.asarray([[3.0, 1.0, 0.0, -1.0]])
    tgt = np.asarray([3, 1, 0, 0], np.float64)
    tgt = tgt / tgt.sum()
    # scores = log target (masked) gives ~0 KL
    scores = jnp.asarray([[np.log(tgt[0]), np.log(tgt[1]), -30.0, 0.0]])
    val = float(KLDivergenceLoss()(scores, labels))
    assert val < 0.02


def test_wasserstein_orders():
    labels = jnp.asarray([[3.0, 2.0, 1.0, 0.0]])
    good = jnp.asarray([[9.0, 6.0, 3.0, 0.0]])
    bad = jnp.asarray([[0.0, 3.0, 6.0, 9.0]])
    assert float(WassersteinLoss()(good, labels)) < float(
        WassersteinLoss()(bad, labels))


def test_losses_differentiable():
    labels = jnp.asarray([[3.0, 2.0, 0.0, -1.0], [1.0, 0.0, 2.0, -1.0]])
    scores = jnp.asarray(np.random.default_rng(0).normal(size=(2, 4)),
                         jnp.float32)
    for loss in (KLDivergenceLoss(), WassersteinLoss(), ListNetLoss()):
        g = jax.grad(lambda s: loss(s, labels))(scores)
        assert np.isfinite(np.asarray(g)).all()
    g = jax.grad(lambda s: BCELoss()(s[:, 0], jnp.asarray([1.0, 0.0])))(
        scores)
    assert np.isfinite(np.asarray(g)).all()


# -- metrics -----------------------------------------------------------------

def test_compute_metrics_hand_example():
    # 1 query, relevant docs {1: grade 2, 3: grade 1}; run = [3, 2, 1]
    run = np.asarray([[3, 2, 1]])
    qrels = {0: {1: 2.0, 3: 1.0}}
    m = compute_metrics(("ndcg@3", "mrr@3", "recall@3", "map@3"),
                        run, np.asarray([0]), qrels)
    # rels of run = [1, 0, 2] -> dcg = 1/log2(2) + 3/log2(4) = 1 + 1.5
    dcg = 1.0 + 3.0 / 2.0
    idcg = 3.0 + 1.0 / np.log2(3)
    np.testing.assert_allclose(m["ndcg@3"], dcg / idcg, rtol=1e-6)
    np.testing.assert_allclose(m["mrr@3"], 1.0, rtol=1e-6)    # rank 1 hit
    np.testing.assert_allclose(m["recall@3"], 1.0, rtol=1e-6)
    np.testing.assert_allclose(m["map@3"], (1 / 1 + 2 / 3) / 2, rtol=1e-6)


def test_metrics_zero_relevant_query():
    """A query with zero relevant qrels (the combined-suite path can
    produce them after filtering) contributes recall/map 0 — never a
    0/0 division or a NaN in the mean."""
    run = np.asarray([[3, 2, 1], [3, 2, 1]])
    qrels = {0: {3: 1.0},          # one hit at rank 1
             1: {}}                # present, but nothing relevant
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # any divide warning fails
        m = compute_metrics(("recall@3", "map@3", "ndcg@3", "mrr@3"),
                            run, np.asarray([0, 1]), qrels)
    for v in m.values():
        assert np.isfinite(v)
    np.testing.assert_allclose(m["recall@3"], 0.5, rtol=1e-6)
    # a query absent from qrels entirely behaves the same way
    m2 = compute_metrics(("recall@3",), run, np.asarray([0, 7]), {0: {3: 1.0}})
    np.testing.assert_allclose(m2["recall@3"], 0.5, rtol=1e-6)


def test_metrics_bounds(rng):
    run = rng.integers(0, 50, size=(10, 10)).astype(np.int64)
    qrels = {q: {int(d): 1.0 for d in rng.integers(0, 50, 3)}
             for q in range(10)}
    m = compute_metrics(("ndcg@10", "mrr@10", "recall@10"), run,
                        np.arange(10), qrels)
    for v in m.values():
        assert 0.0 <= v <= 1.0


def test_irmetrics_rerank():
    scores = np.asarray([[0.9, 0.1, 0.5], [0.2, 0.8, 0.1]])
    labels = np.asarray([[2.0, 0.0, 1.0], [0.0, 3.0, -1.0]])
    m = IRMetrics(("ndcg@3", "mrr@3"))(scores, labels)
    # both queries rank their best doc first -> perfect
    np.testing.assert_allclose(m["ndcg@3"], 1.0, rtol=1e-6)
    np.testing.assert_allclose(m["mrr@3"], 1.0, rtol=1e-6)
    # padding (-1) is excluded from the ranking entirely: the real
    # relevant doc ranks first even though the pad slot scored higher
    m2 = IRMetrics(("mrr@3",))(np.asarray([[1.0, 0.5]]),
                               np.asarray([[-1.0, 1.0]]))
    np.testing.assert_allclose(m2["mrr@3"], 1.0)
