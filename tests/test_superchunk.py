"""Superchunk scan executor: one-dispatch-per-superchunk streaming search.

The scan path (``kernels.ops.superchunk_update`` driven by
``ShardedSearchDriver._search_superchunk``) must reproduce the per-chunk
dispatch path bit for bit for every device score_impl × heap_impl combo,
across ragged tails, padded final superchunks, empty shards, and the
prefetch pipeline — while collapsing the dispatch count to
ceil(chunks / S).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.sharded_search import (ShardedSearchDriver,
                                       autotune_superchunk_size)
from repro.kernels import ops

SCAN_SCORE_IMPLS = ("jax", "pallas_fused")
SCAN_HEAP_IMPLS = ("jax", "pallas")


@pytest.fixture()
def synth():
    rng = np.random.default_rng(11)
    q = rng.normal(size=(9, 16)).astype(np.float32)
    docs = rng.normal(size=(230, 16)).astype(np.float32)
    return q, docs


def _oracle(q, docs, k):
    full = q @ docs.T
    pos = np.argsort(-full, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(full, pos, 1), pos


@pytest.mark.parametrize("heap_impl", SCAN_HEAP_IMPLS)
@pytest.mark.parametrize("score_impl", SCAN_SCORE_IMPLS)
def test_scan_matches_oracle(synth, score_impl, heap_impl):
    """chunk=37 leaves a ragged tail; S=3 leaves a padded final group."""
    q, docs = synth
    driver = ShardedSearchDriver(score_impl=score_impl,
                                 heap_impl=heap_impl, chunk_size=37,
                                 superchunk_size=3)
    vals, pos = driver.search(q, docs.shape[0],
                              lambda lo, hi: docs[lo:hi], 10)
    ref_vals, ref_pos = _oracle(q, docs, 10)
    assert driver.stats["executor"] == "superchunk"
    np.testing.assert_array_equal(pos, ref_pos)
    np.testing.assert_allclose(vals, ref_vals, rtol=1e-5)


@pytest.mark.parametrize("heap_impl", SCAN_HEAP_IMPLS)
@pytest.mark.parametrize("score_impl", SCAN_SCORE_IMPLS)
def test_scan_bitwise_equals_per_chunk(synth, score_impl, heap_impl):
    """superchunk_size=1 is the pre-superchunk per-chunk dispatch path;
    the scan must return the identical (ids bitwise) ranking."""
    q, docs = synth
    outs = {}
    for s in (1, 4):
        d = ShardedSearchDriver(score_impl=score_impl,
                                heap_impl=heap_impl, chunk_size=23,
                                superchunk_size=s)
        outs[s] = d.search(q, docs.shape[0],
                           lambda lo, hi: docs[lo:hi], 7)
        assert d.stats["executor"] == ("per_chunk" if s == 1
                                       else "superchunk")
    np.testing.assert_array_equal(outs[1][1], outs[4][1])
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=1e-5,
                               atol=1e-6)


def test_scan_dispatch_counts(synth):
    """ceil(230/32) = 8 chunks fold into ceil(8/4) = 2 scan dispatches."""
    q, docs = synth
    driver = ShardedSearchDriver(score_impl="jax", chunk_size=32,
                                 superchunk_size=4)
    driver.search(q, docs.shape[0], lambda lo, hi: docs[lo:hi], 5)
    assert driver.stats["chunks"] == 8
    assert driver.stats["dispatch_rounds"] == 2
    assert driver.stats["superchunk_size"] == 4
    per_chunk = ShardedSearchDriver(score_impl="jax", chunk_size=32,
                                    superchunk_size=1)
    per_chunk.search(q, docs.shape[0], lambda lo, hi: docs[lo:hi], 5)
    assert per_chunk.stats["dispatch_rounds"] == 8


def test_scan_with_prefetch_identical(synth):
    q, docs = synth
    outs = {}
    for prefetch in (False, True):
        d = ShardedSearchDriver(score_impl="jax", chunk_size=23,
                                superchunk_size=4, prefetch=prefetch)
        outs[prefetch] = d.search(q, docs.shape[0],
                                  lambda lo, hi: docs[lo:hi], 7)
    np.testing.assert_array_equal(outs[True][1], outs[False][1])
    np.testing.assert_array_equal(outs[True][0], outs[False][0])


def test_scan_device_resident_chunks(synth):
    """The online-encode regime hands the driver jax arrays, not numpy;
    the stacking path must keep them device-side and stay correct."""
    q, docs = synth
    d = ShardedSearchDriver(score_impl="jax", chunk_size=37,
                            superchunk_size=3)
    vals, pos = d.search(q, docs.shape[0],
                         lambda lo, hi: jnp.asarray(docs[lo:hi]), 10)
    _, ref_pos = _oracle(q, docs, 10)
    np.testing.assert_array_equal(pos, ref_pos)


def test_numpy_and_python_backends_stay_per_chunk(synth):
    q, docs = synth
    for score_impl, heap_impl in (("numpy", "jax"), ("jax", "python")):
        d = ShardedSearchDriver(score_impl=score_impl,
                                heap_impl=heap_impl, chunk_size=32,
                                superchunk_size=16)
        _, pos = d.search(q, docs.shape[0], lambda lo, hi: docs[lo:hi], 5)
        assert d.stats["executor"] == "per_chunk"
        _, ref_pos = _oracle(q, docs, 5)
        np.testing.assert_array_equal(pos, ref_pos)


def test_autotune_in_range_and_cached():
    s1 = autotune_superchunk_size(9, 16, 32, 10, "jax", "jax")
    s2 = autotune_superchunk_size(9, 16, 32, 10, "jax", "jax")
    assert 8 <= s1 <= 256
    assert s1 == s2                       # memoized per (shape, backend)


def test_memory_cap_bounds_superchunk():
    """A configured S that would blow the tile budget is clamped."""
    d = ShardedSearchDriver(score_impl="jax", chunk_size=1024,
                            superchunk_size=10_000, superchunk_max_mb=4)
    cap = (4 << 20) // (1024 * 64 * 4)
    assert d._resolve_superchunk_size(8, 64, 10) == cap


# -- zero-length corpus slices (FairSharder emits them legitimately) ----------


def test_fused_score_topk_empty_corpus():
    """n=0 must return a clean (-inf, -1) state, not a zero-size grid."""
    q = np.zeros((3, 8), np.float32)
    vals, ids = ops.fused_score_topk(q, np.zeros((0, 8), np.float32), 5)
    assert vals.shape == (3, 5) and ids.shape == (3, 5)
    assert (np.asarray(vals) == -np.inf).all()
    assert (np.asarray(ids) == -1).all()


@pytest.mark.parametrize("score_impl", SCAN_SCORE_IMPLS)
def test_empty_shards_through_driver(synth, score_impl):
    """total_items < n_workers: some shards are empty; every rank of the
    cluster must still return the W=1 ranking (regression through
    ShardedSearchDriver.search for the device backends)."""
    from repro.launch.distributed import SimulatedCluster
    q, docs = synth
    docs = docs[:3]
    single = ShardedSearchDriver(score_impl=score_impl, chunk_size=8)
    ref_vals, ref_pos = single.search(q, 3, lambda lo, hi: docs[lo:hi], 5)
    cluster = SimulatedCluster(4)
    drivers = [ShardedSearchDriver(
        n_workers=4, worker_index=rank, sharder=cluster.sharder,
        score_impl=score_impl, chunk_size=8, gather=cluster.gather)
        for rank in range(4)]
    outs = cluster.run(
        lambda rank: drivers[rank].search(q, 3,
                                          lambda lo, hi: docs[lo:hi], 5))
    for vals, pos in outs:
        np.testing.assert_array_equal(pos, ref_pos)
        np.testing.assert_allclose(vals, ref_vals, rtol=1e-5)
    assert (ref_pos[:, 3:] == -1).all()   # k=5 > 3 docs: clean empty tail


def test_empty_corpus_through_driver():
    d = ShardedSearchDriver(score_impl="jax", superchunk_size=4)
    vals, pos = d.search(np.zeros((2, 4), np.float32), 0,
                         lambda lo, hi: np.zeros((0, 4), np.float32), 3)
    assert (pos == -1).all() and (vals == -np.inf).all()


# -- scan-friendly kernel entries ---------------------------------------------


def test_superchunk_update_traced_offsets_no_recompile():
    """Offsets and valid counts ride the scan xs: two superchunks with
    different offsets must hit the same compiled executable."""
    rng = np.random.default_rng(0)
    q = rng.normal(size=(8, 16)).astype(np.float32)
    tile = rng.normal(size=(4, 32, 16)).astype(np.float32)
    v = jnp.full((8, 5), -jnp.inf, jnp.float32)
    i = jnp.full((8, 5), -1, jnp.int32)
    v, i = ops.superchunk_update(
        v, i, q, tile, np.arange(0, 128, 32, dtype=np.int32),
        np.full(4, 32, np.int32), k=5)
    before = (ops._superchunk_scan_jit._cache_size()
              if hasattr(ops._superchunk_scan_jit, "_cache_size")
              else None)
    v, i = ops.superchunk_update(
        v, i, q, tile, np.arange(1000, 1128, 32, dtype=np.int32),
        np.full(4, 32, np.int32), k=5)
    if before is not None:
        assert ops._superchunk_scan_jit._cache_size() == before


def test_superchunk_update_masks_padded_steps():
    """Steps with n_valid=0 (padded final group) must contribute nothing,
    even though their zero embeddings would otherwise score 0 > -inf."""
    rng = np.random.default_rng(1)
    q = rng.normal(size=(8, 16)).astype(np.float32)
    docs = -np.abs(rng.normal(size=(32, 16))).astype(np.float32)
    tile = np.zeros((3, 32, 16), np.float32)
    tile[0] = docs
    offs = np.array([0, 0, 0], np.int32)
    nvs = np.array([32, 0, 0], np.int32)
    v = jnp.full((8, 5), -jnp.inf, jnp.float32)
    i = jnp.full((8, 5), -1, jnp.int32)
    v, i = ops.superchunk_update(v, i, q, tile, offs, nvs, k=5)
    _, ref_pos = _oracle_like(q, docs, 5)
    np.testing.assert_array_equal(np.asarray(i), ref_pos)


def _oracle_like(q, docs, k):
    full = q @ docs.T
    pos = np.argsort(-full, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(full, pos, 1), pos
