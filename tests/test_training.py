import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training import grad_compression as gc
from repro.training.fault_tolerance import Heartbeat, resilient_loop
from repro.training.optimizer import (OptimizerConfig, clip_by_global_norm,
                                      make_optimizer, schedule)


# -- optimizers ---------------------------------------------------------------

def _quadratic_descends(opt_name, steps=60, lr=0.1):
    cfg = OptimizerConfig(name=opt_name, learning_rate=lr, weight_decay=0.0)
    init, update = make_optimizer(cfg)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]),
              "m": jnp.ones((4, 130)) * 2.0}    # matrix leaf (factored path)
    state = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    l0 = float(loss(params))
    for t in range(steps):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params, jnp.asarray(t))
    return l0, float(loss(params))


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizer_descends(opt):
    l0, l1 = _quadratic_descends(opt)
    assert l1 < l0 * 0.05, (opt, l0, l1)


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5)


def test_schedule_warmup_cosine():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                          total_steps=100)
    assert float(schedule(cfg, jnp.asarray(0))) < 0.2
    assert float(schedule(cfg, jnp.asarray(9))) > 0.9
    assert float(schedule(cfg, jnp.asarray(99))) < 0.2


# -- checkpointing -------------------------------------------------------------

def _state(seed=0):
    k = jax.random.key(seed)
    return {"step": jnp.asarray(7, jnp.int32),
            "params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"mu": {"w": jnp.ones((8, 8)), "b": jnp.zeros(8)}}}


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    path = ckpt.save_checkpoint(str(tmp_path), 7, state)
    restored = ckpt.restore_checkpoint(path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert ckpt.checkpoint_step(path) == 7


def test_latest_checkpoint_ordering(tmp_path):
    for step in (5, 20, 10):
        ckpt.save_checkpoint(str(tmp_path), step, _state())
    assert ckpt.latest_checkpoint(str(tmp_path)).endswith("step_00000020")


def test_manager_gc_and_async(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), save_every=1, keep=2,
                                 async_save=True)
    for step in range(5):
        mgr.save(step, _state())
    mgr.wait()
    dirs = sorted(os.listdir(tmp_path))
    assert len(dirs) == 2 and dirs[-1] == "step_00000004"


def test_elastic_restore_resharding(tmp_path):
    """Restore under a different sharding (elastic scaling after node
    loss): values must be identical regardless of topology."""
    state = _state()
    path = ckpt.save_checkpoint(str(tmp_path), 1, state)
    from repro.sharding import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, state)
    restored = ckpt.restore_checkpoint(path, state, shardings)
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.asarray(state["params"]["w"]))


def test_corrupt_save_not_picked_up(tmp_path):
    ckpt.save_checkpoint(str(tmp_path), 1, _state())
    # a crashed mid-save leaves only a tmp dir / partial dir w/o manifest
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt.latest_checkpoint(str(tmp_path)).endswith("step_00000001")


# -- gradient compression --------------------------------------------------------

def test_int8_quant_roundtrip_error(rng):
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = gc.quantize_int8(x)
    err = np.abs(np.asarray(gc.dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_error_feedback_converges():
    """EF-int8 SGD reaches the optimum a plain-int8 SGD would circle."""
    w = jnp.asarray([1.0, -1.0, 0.5])
    target = jnp.asarray([0.3, 0.7, -0.2])
    ef = jnp.zeros(3)
    lr = 0.2
    for _ in range(150):
        g = w - target
        g_ef = g + ef
        q, s = gc.quantize_int8(g_ef)
        deq = gc.dequantize_int8(q, s)
        ef = g_ef - deq
        w = w - lr * deq
    np.testing.assert_allclose(np.asarray(w), np.asarray(target),
                               atol=5e-3)


def test_wire_bytes():
    params = {"w": jnp.zeros((10, 10))}
    assert gc.wire_bytes(params, "none") == 400
    assert gc.wire_bytes(params, "bf16") == 200
    assert gc.wire_bytes(params, "int8") == 100


# -- fault tolerance ---------------------------------------------------------------

def test_resilient_loop_restores():
    calls = []

    def step(i):
        calls.append(i)
        if i == 3 and calls.count(3) == 1:
            raise RuntimeError("simulated node failure")

    def on_failure(exc):
        return 2        # "restored from checkpoint at step 2"

    final = resilient_loop(step, 0, 6, on_failure, max_failures=2)
    assert final == 6
    assert calls.count(3) == 2     # re-executed after restore


def test_resilient_loop_gives_up():
    def step(i):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        resilient_loop(step, 0, 3, lambda e: 0, max_failures=2)


def test_heartbeat_writes(tmp_path):
    path = str(tmp_path / "hb.json")
    with Heartbeat(path, interval=100) as hb:
        hb.update(5)
    import json
    assert json.load(open(path))["step"] == 5
