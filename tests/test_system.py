"""End-to-end behaviour tests: the paper's full workflow on CPU.

train (random negs) -> evaluate -> mine hard negatives -> verify the
round trip, exercising MaterializedQRel, datasets, collator, trainer,
evaluator, mining, metrics and the heap together.
"""

import os

import jax
import jax.numpy as jnp
import pytest

from repro import (BinaryDataset, DataArguments, EvaluationArguments,
                   HashTokenizer, MaterializedQRelConfig, ModelArguments,
                   RetrievalCollator, RetrievalEvaluator,
                   RetrievalTrainingArguments, BiEncoderRetriever,
                   RetrievalTrainer)
from repro.models.transformer import LMConfig

# full train->evaluate->mine round trip: minutes of CPU work
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def system(tmp_path_factory):
    from repro.data.synthetic import make_retrieval_dataset
    work = str(tmp_path_factory.mktemp("sys"))
    queries, corpus, qrels = make_retrieval_dataset(
        work, n_queries=32, n_docs=128, n_topics=8)
    data_args = DataArguments(group_size=2, vocab_size=512,
                              query_max_len=12, passage_max_len=32)
    cfg = LMConfig(name="sys", n_layers=2, d_model=32, n_heads=4,
                   n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=512,
                   dtype=jnp.float32, pooling="mean", remat=False)
    retr = BiEncoderRetriever.from_model_args(
        ModelArguments(temperature=0.05), cfg)
    coll = RetrievalCollator(data_args, HashTokenizer(512))
    pos = MaterializedQRelConfig(
        min_score=1, qrel_path=f"{work}/qrels/train.tsv",
        query_path=f"{work}/queries.jsonl",
        corpus_path=f"{work}/corpus.jsonl")
    ds = BinaryDataset(data_args, retr.format_query, retr.format_passage,
                       pos, pos, cache_root=f"{work}/cache")
    args = RetrievalTrainingArguments(
        output_dir=f"{work}/run", max_steps=50, learning_rate=3e-3,
        warmup_steps=5, per_device_batch_size=16, checkpoint_every=25,
        log_every=10)
    trainer = RetrievalTrainer(retr, args, coll, ds)
    state = trainer.train()
    return dict(work=work, queries=queries, corpus=corpus, qrels=qrels,
                retr=retr, coll=coll, state=state, trainer=trainer,
                data_args=data_args, pos=pos)


def test_training_reduces_loss(system):
    logs = system["trainer"].logs
    assert logs[-1]["loss"] < logs[0]["loss"] * 0.8


def test_trained_model_beats_random(system):
    ev_args = EvaluationArguments(topk=10, metrics=("ndcg@10", "recall@10"))
    trained = RetrievalEvaluator(ev_args, system["retr"], system["coll"],
                                 system["state"]["params"])
    m_trained = trained.evaluate(system["queries"], system["corpus"],
                                 system["qrels"])
    rand_params = system["retr"].init_params(jax.random.key(123))
    randm = RetrievalEvaluator(ev_args, system["retr"], system["coll"],
                               rand_params)
    m_rand = randm.evaluate(system["queries"], system["corpus"],
                            system["qrels"])
    assert m_trained["ndcg@10"] > m_rand["ndcg@10"]


def test_mining_roundtrip(system):
    ev = RetrievalEvaluator(EvaluationArguments(topk=8),
                            system["retr"], system["coll"],
                            system["state"]["params"])
    path = os.path.join(system["work"], "mined.tsv")
    mined = ev.mine_hard_negatives(system["queries"], system["corpus"],
                                   system["qrels"], depth=8,
                                   output_path=path)
    assert len(mined) > 0 and os.path.exists(path)
    # the mined file is loadable as a qrel source for retraining
    neg = MaterializedQRelConfig(
        qrel_path=path, group_random_k=1,
        query_path=f"{system['work']}/queries.jsonl",
        corpus_path=f"{system['work']}/corpus.jsonl")
    ds = BinaryDataset(system["data_args"], system["retr"].format_query,
                       system["retr"].format_passage, system["pos"], neg,
                       cache_root=f"{system['work']}/cache")
    item = ds[0]
    assert len(item["passages"]) == 2


def test_checkpoint_restart_continues(system):
    """Same output_dir: a new trainer resumes from the final checkpoint
    and does not retrain from scratch."""
    args = RetrievalTrainingArguments(
        output_dir=f"{system['work']}/run", max_steps=50,
        per_device_batch_size=16, checkpoint_every=25, log_every=50)
    ds = BinaryDataset(system["data_args"], system["retr"].format_query,
                       system["retr"].format_passage, system["pos"],
                       system["pos"], cache_root=f"{system['work']}/cache")
    tr = RetrievalTrainer(system["retr"], args, system["coll"], ds)
    state = tr.train()
    assert int(state["step"]) >= 50
