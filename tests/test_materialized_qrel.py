import numpy as np

from repro.core.config import DataArguments, MaterializedQRelConfig
from repro.core.datasets import BinaryDataset, MultiLevelDataset
from repro.core.materialized_qrel import MaterializedQRel
from repro.data.table import stable_id_hash


def _cfg(data, **kw):
    d = data["dir"]
    return MaterializedQRelConfig(
        qrel_path=f"{d}/qrels/train.tsv", query_path=f"{d}/queries.jsonl",
        corpus_path=f"{d}/corpus.jsonl", **kw)


def _naive_groups(data, min_score=None, max_score=None, new_label=None):
    """Reference implementation: load everything, group in dicts."""
    groups = {}
    for line in open(f"{data['dir']}/qrels/train.tsv"):
        q, doc, s = line.split("\t")
        s = float(s)
        if min_score is not None and s < min_score:
            continue
        if max_score is not None and s > max_score:
            continue
        if new_label is not None:
            s = new_label
        groups.setdefault(q, {})[doc] = s
    return groups


def test_groups_match_naive(retrieval_data, tmp_path):
    m = MaterializedQRel(_cfg(retrieval_data), str(tmp_path))
    naive = _naive_groups(retrieval_data)
    assert len(m) == len(naive)
    for q, docs in naive.items():
        dids, scores = m.group(stable_id_hash(q))
        assert {int(d) for d in dids} == {stable_id_hash(d) for d in docs}


def test_min_score_filter(retrieval_data, tmp_path):
    m = MaterializedQRel(_cfg(retrieval_data, min_score=2), str(tmp_path))
    naive = _naive_groups(retrieval_data, min_score=2)
    qids = {q for q, docs in naive.items() if docs}
    assert len(m) == len(qids)
    for q in qids:
        _, scores = m.group(stable_id_hash(q))
        assert (scores >= 2).all()


def test_relabel(retrieval_data, tmp_path):
    m = MaterializedQRel(_cfg(retrieval_data, min_score=1, new_label=3),
                         str(tmp_path))
    for q in list(retrieval_data["qrels"])[:5]:
        _, scores = m.group(stable_id_hash(q))
        assert (scores == 3).all()


def test_transform_fn(retrieval_data, tmp_path):
    m = MaterializedQRel(
        _cfg(retrieval_data, transform_fn=lambda s: s * 10), str(tmp_path))
    q = list(retrieval_data["qrels"])[0]
    _, scores = m.group(stable_id_hash(q))
    assert set(np.unique(scores)).issubset({10.0, 20.0, 30.0})


def test_filter_fn(retrieval_data, tmp_path):
    m = MaterializedQRel(
        _cfg(retrieval_data, filter_fn=lambda q, d, s: s >= 1),
        str(tmp_path))
    naive = _naive_groups(retrieval_data, min_score=1)
    assert len(m) == len([q for q, d in naive.items() if d])


def test_group_random_k_deterministic(retrieval_data, tmp_path):
    m = MaterializedQRel(_cfg(retrieval_data, group_random_k=2),
                         str(tmp_path))
    q = stable_id_hash(list(retrieval_data["qrels"])[0])
    d1, _ = m.group(q)
    d2, _ = m.group(q)
    assert len(d1) <= 2
    np.testing.assert_array_equal(d1, d2)   # seeded => stable


def test_lazy_text_access(retrieval_data, tmp_path):
    m = MaterializedQRel(_cfg(retrieval_data), str(tmp_path))
    q = list(retrieval_data["queries"])[0]
    assert m.query_text(stable_id_hash(q)) == retrieval_data["queries"][q]
    d = list(retrieval_data["corpus"])[0]
    assert retrieval_data["corpus"][d] in m.doc_text(stable_id_hash(d))


def test_binary_dataset_structure(retrieval_data, tmp_path):
    pos = _cfg(retrieval_data, min_score=1)
    neg = _cfg(retrieval_data, group_random_k=1)
    args = DataArguments(group_size=3)
    ds = BinaryDataset(args, str.upper, lambda t: t, pos, neg,
                       str(tmp_path))
    item = ds[0]
    assert item["query"].isupper()
    assert len(item["passages"]) == 3
    # first passage is a known positive for this query
    qrels = retrieval_data["qrels"]


def test_multilevel_dedup_and_padding(retrieval_data, tmp_path):
    src = _cfg(retrieval_data)
    relabeled = _cfg(retrieval_data, min_score=1, new_label=3)
    ds = MultiLevelDataset(DataArguments(group_size=8), lambda t: t,
                           lambda t: t, [src, relabeled], str(tmp_path))
    item = ds[0]
    assert len(item["passages"]) == 8
    labels = item["labels"]
    assert labels.shape == (8,)
    # dedup keeps max label: relabeled-to-3 should win
    assert labels[0] == 3
    # padding labels are -1
    assert (labels >= -1).all()
    # labels sorted descending (before padding)
    valid = labels[labels >= 0]
    assert (np.diff(valid) <= 0).all()


def test_combined_sources_union(retrieval_data, tmp_path):
    a = _cfg(retrieval_data, max_score=1)
    b = _cfg(retrieval_data, min_score=2)
    m_all = MaterializedQRel(_cfg(retrieval_data), str(tmp_path))
    ds = MultiLevelDataset(DataArguments(group_size=4), lambda t: t,
                           lambda t: t, [a, b], str(tmp_path))
    assert len(ds) == len(m_all)


def test_distinct_lambdas_get_distinct_group_caches(retrieval_data,
                                                    tmp_path):
    """Regression: ``_config_key`` used to key callbacks by ``__name__``,
    so two different lambdas (both ``"<lambda>"``) silently shared one
    cached grouped-qrel dir — the second filter got the first's groups."""
    keep_all = MaterializedQRel(
        _cfg(retrieval_data, filter_fn=lambda q, d, s: True),
        str(tmp_path))
    keep_none = MaterializedQRel(
        _cfg(retrieval_data, filter_fn=lambda q, d, s: False),
        str(tmp_path))
    assert len(keep_all) == len(_naive_groups(retrieval_data))
    assert len(keep_none) == 0


def test_closure_parameterized_lambdas_not_conflated(retrieval_data,
                                                     tmp_path):
    """Same bytecode, different closure cells -> different caches."""
    def at_least(t):
        return lambda q, d, s: s >= t

    m1 = MaterializedQRel(_cfg(retrieval_data, filter_fn=at_least(1)),
                          str(tmp_path))
    m2 = MaterializedQRel(_cfg(retrieval_data, filter_fn=at_least(99)),
                          str(tmp_path))
    assert len(m1) == len(_naive_groups(retrieval_data, min_score=1))
    assert len(m2) == 0
    # identical lambda re-definition still hits the same cache dir
    from repro.core.materialized_qrel import _config_key
    assert _config_key(_cfg(retrieval_data, filter_fn=at_least(1))) == \
        _config_key(_cfg(retrieval_data, filter_fn=at_least(1)))


def test_binary_dataset_drops_empty_positive_queries(retrieval_data,
                                                     tmp_path):
    """Regression: a query whose positive groups are all empty at access
    time (e.g. ``group_random_k=0``) used to survive ``__init__`` and
    blow up with IndexError mid-epoch; now it's dropped up front."""
    half_qrels = str(tmp_path / "half.tsv")
    qids = list(retrieval_data["qrels"])
    with open(half_qrels, "w") as f:
        for q in qids[: len(qids) // 2]:
            for d, s in retrieval_data["qrels"][q].items():
                f.write(f"{q}\t{d}\t{int(s)}\n")
    d = retrieval_data["dir"]
    pos_half = MaterializedQRelConfig(
        qrel_path=half_qrels, query_path=f"{d}/queries.jsonl",
        corpus_path=f"{d}/corpus.jsonl")
    # contributes every query id, but with empty groups
    pos_empty = _cfg(retrieval_data, group_random_k=0)
    neg = _cfg(retrieval_data, group_random_k=2)
    ds = BinaryDataset(DataArguments(group_size=2), lambda t: t,
                       lambda t: t, [pos_half, pos_empty], neg,
                       str(tmp_path))
    assert len(ds) == len(qids) // 2
    for i in range(len(ds)):            # no IndexError on any item
        assert ds[i]["passages"]
    all_empty = BinaryDataset(DataArguments(group_size=2), lambda t: t,
                              lambda t: t, [pos_empty], neg,
                              str(tmp_path))
    assert len(all_empty) == 0
