import os
import sys

# tests run on the single real CPU device — dry-run meshes are exercised
# in subprocesses with their own XLA_FLAGS (see test_dryrun_mini.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import make_retrieval_dataset


@pytest.fixture(scope="session")
def tiny_lm_cfg():
    from repro.models.transformer import LMConfig
    return LMConfig(name="tiny", n_layers=2, d_model=32, n_heads=4,
                    n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=257,
                    dtype=jnp.float32, pooling="mean", remat=False)


@pytest.fixture(scope="session")
def tiny_retriever(tiny_lm_cfg):
    from repro.core.config import ModelArguments
    from repro.models.retriever import BiEncoderRetriever
    return BiEncoderRetriever.from_model_args(
        ModelArguments(temperature=0.05), tiny_lm_cfg)


@pytest.fixture(scope="session")
def tiny_params(tiny_retriever):
    return tiny_retriever.init_params(jax.random.key(0))


@pytest.fixture(scope="session")
def retrieval_data(tmp_path_factory):
    root = tmp_path_factory.mktemp("data")
    queries, corpus, qrels = make_retrieval_dataset(
        str(root), n_queries=24, n_docs=96, n_topics=8)
    return {"dir": str(root), "queries": queries, "corpus": corpus,
            "qrels": qrels}


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


# the search/serve stack's worker threads all carry these name prefixes;
# anything still alive after a test leaked out of a driver/frontend/
# cluster that should have been drained on exit
_STACK_THREAD_PREFIXES = ("serve-dispatch", "shard-reduce",
                          "chunk-prefetch", "sim-worker", "heartbeat")


@pytest.fixture(autouse=True)
def no_stack_thread_leaks():
    """Every test must leave the stack's thread pool empty: stray
    dispatcher / reduce / prefetch / worker / heartbeat threads from one
    test would serialize behind (or deadlock with) the next test's
    cluster."""
    import threading
    import time

    before = set(threading.enumerate())
    yield
    deadline = time.monotonic() + 2.0
    while True:
        leaked = [t for t in threading.enumerate()
                  if t not in before and t.is_alive()
                  and t.name.startswith(_STACK_THREAD_PREFIXES)]
        if not leaked or time.monotonic() > deadline:
            break
        time.sleep(0.02)
    assert not leaked, (
        f"stack threads leaked past the test: "
        f"{[t.name for t in leaked]}")
