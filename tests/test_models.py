import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, recsys
from repro.models import transformer as tfm


def _mk(name="t", **kw):
    base = dict(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=8, d_ff=64, vocab_size=101, dtype=jnp.float32,
                remat=False)
    base.update(kw)
    return tfm.LMConfig(name=name, **base)


def _toks(cfg, b=3, s=10, seed=1):
    toks = jax.random.randint(jax.random.key(seed), (b, s), 3,
                              cfg.vocab_size)
    return toks, jnp.ones((b, s), jnp.int32)


def test_encode_normalized(tiny_lm_cfg, tiny_params):
    toks, mask = _toks(tiny_lm_cfg)
    emb = tfm.encode(tiny_lm_cfg, tiny_params, toks, mask)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(emb), axis=-1), 1.0, rtol=1e-5)


def test_padding_invariance():
    """Extending padding must not change the embedding (mask semantics)."""
    cfg = _mk(pooling="mean")
    params = tfm.init_params(cfg, jax.random.key(0))
    toks, mask = _toks(cfg, b=2, s=8)
    toks_p = jnp.pad(toks, ((0, 0), (0, 4)))
    mask_p = jnp.pad(mask, ((0, 0), (0, 4)))
    e1 = tfm.encode(cfg, params, toks, mask)
    e2 = tfm.encode(cfg, params, toks_p, mask_p)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-5)


def test_causality():
    """Changing a future token must not change past hidden states."""
    cfg = _mk()
    params = tfm.init_params(cfg, jax.random.key(0))
    toks, mask = _toks(cfg, b=1, s=8)
    h1, _ = tfm.forward_hidden(cfg, params, toks, mask)
    toks2 = toks.at[0, 7].set((toks[0, 7] + 1) % cfg.vocab_size)
    h2, _ = tfm.forward_hidden(cfg, params, toks2, mask)
    np.testing.assert_allclose(np.asarray(h1[:, :7]),
                               np.asarray(h2[:, :7]), atol=1e-5)
    assert np.abs(np.asarray(h1[:, 7] - h2[:, 7])).max() > 1e-6


@pytest.mark.parametrize("kw", [
    dict(),
    dict(qkv_bias=True, norm="layernorm", activation="gelu"),
    dict(moe=True, n_experts=4, top_k=2, moe_d_ff=32, moe_every=1,
         capacity_factor=8.0),
    dict(moe=True, n_experts=4, top_k=1, moe_d_ff=32, moe_every=2,
         n_shared_experts=1, capacity_factor=8.0),
])
def test_decode_matches_forward(kw):
    """KV-cache decode reproduces the full forward logits exactly
    (capacity_factor high enough that MoE drops nothing)."""
    cfg = _mk(**kw)
    params = tfm.init_params(cfg, jax.random.key(0))
    toks, mask = _toks(cfg, b=2, s=9)
    hid, _ = tfm.forward_hidden(cfg, params, toks, mask)
    full = np.asarray(tfm.lm_logits(cfg, params, hid))
    cache = tfm.init_cache(cfg, 2, 9)
    outs = []
    for t in range(9):
        lg, cache = tfm.decode_step(cfg, params, cache, toks[:, t])
        outs.append(np.asarray(lg))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full, rtol=2e-2, atol=2e-4)


def test_scan_equals_unrolled():
    for kw in (dict(), dict(moe=True, n_experts=4, top_k=2, moe_d_ff=32,
                            moe_every=2, n_shared_experts=1)):
        cfg_s = _mk(**kw)
        cfg_u = dataclasses.replace(cfg_s, scan_layers=False)
        params = tfm.init_params(cfg_s, jax.random.key(0))
        toks, mask = _toks(cfg_s)
        h_s, _ = tfm.forward_hidden(cfg_s, params, toks, mask)
        h_u, _ = tfm.forward_hidden(cfg_u, params, toks, mask)
        np.testing.assert_allclose(np.asarray(h_s), np.asarray(h_u),
                                   atol=1e-5)


def test_chunked_attention_equals_plain():
    cfg = _mk(attn_chunk=0)
    cfg_c = dataclasses.replace(cfg, attn_chunk=4)
    params = tfm.init_params(cfg, jax.random.key(0))
    toks, mask = _toks(cfg, b=2, s=16)
    h1, _ = tfm.forward_hidden(cfg, params, toks, mask)
    h2, _ = tfm.forward_hidden(cfg_c, params, toks, mask)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


def test_moe_capacity_drops_counted():
    cfg = _mk(moe=True, n_experts=4, top_k=1, moe_d_ff=32, moe_every=1,
              capacity_factor=0.25)
    params = tfm.init_params(cfg, jax.random.key(0))
    toks, mask = _toks(cfg)
    h, aux = tfm.forward_hidden(cfg, params, toks, mask)
    assert np.isfinite(np.asarray(h)).all()
    assert float(aux) > 0        # load-balance loss active


# -- GNN ----------------------------------------------------------------------

def test_gnn_full_graph_permutation_equivariance(rng):
    cfg = gnn.SAGEConfig(d_feat=6, d_hidden=8)
    params = gnn.init_params(cfg, jax.random.key(0))
    n, e = 10, 30
    x = jnp.asarray(rng.normal(size=(n, 6)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    z = gnn.forward_full(cfg, params, x, src, dst)
    perm = rng.permutation(n)
    inv = np.argsort(perm)
    z_p = gnn.forward_full(cfg, params, x[perm],
                           jnp.asarray(inv[np.asarray(src)]),
                           jnp.asarray(inv[np.asarray(dst)]))
    np.testing.assert_allclose(np.asarray(z_p), np.asarray(z)[perm],
                               atol=1e-5)


def test_gnn_minibatch_shapes(rng):
    cfg = gnn.SAGEConfig(d_feat=6, d_hidden=8)
    params = gnn.init_params(cfg, jax.random.key(0))
    f0 = jnp.asarray(rng.normal(size=(5, 6)).astype(np.float32))
    f1 = jnp.asarray(rng.normal(size=(5, 3, 6)).astype(np.float32))
    f2 = jnp.asarray(rng.normal(size=(5, 3, 2, 6)).astype(np.float32))
    z = gnn.forward_minibatch(cfg, params, f0, f1, f2)
    assert z.shape == (5, 8)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(z), axis=-1),
                               1.0, rtol=1e-5)


def test_gnn_batched_graphs_mask(rng):
    cfg = gnn.SAGEConfig(d_feat=4, d_hidden=8)
    params = gnn.init_params(cfg, jax.random.key(0))
    x = jnp.asarray(rng.normal(size=(2, 5, 4)).astype(np.float32))
    edges = jnp.asarray(rng.integers(0, 5, (2, 6, 2)).astype(np.int32))
    emask = jnp.ones((2, 6), jnp.int32).at[1, 3:].set(0)
    nmask = jnp.ones((2, 5), jnp.int32).at[1, 4:].set(0)
    z = gnn.forward_batched_graphs(cfg, params, x, edges, emask, nmask)
    assert z.shape == (2, 8)
    assert np.isfinite(np.asarray(z)).all()


# -- recsys ---------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["deepfm", "wide_deep", "autoint", "bst"])
def test_recsys_forward_and_grads(kind, rng):
    cfg = recsys.RecSysConfig(
        name=kind, kind=kind, vocab_sizes=(32,) * 5, embed_dim=8,
        mlp_dims=(16, 8), seq_len=4, n_profile_fields=2, n_attn_layers=2,
        d_attn=8)
    params = recsys.init_params(cfg, jax.random.key(0))
    offs = recsys.field_offsets(cfg.vocab_sizes)
    if kind == "bst":
        batch = {"hist": jnp.asarray(rng.integers(0, 32, (6, 4)), jnp.int32),
                 "target": jnp.asarray(rng.integers(0, 32, (6,)), jnp.int32),
                 "profile": jnp.asarray(
                     offs[1] + rng.integers(0, 32, (6, 2)), jnp.int32)}
    else:
        idx = np.stack([offs[f] + rng.integers(0, 32, 6)
                        for f in range(5)], 1)
        batch = {"sparse_idx": jnp.asarray(idx, jnp.int32)}
    logits = recsys.forward(cfg, params, batch)
    assert logits.shape == (6,)
    labels = jnp.asarray(rng.integers(0, 2, 6), jnp.float32)

    def loss(p):
        lg = recsys.forward(cfg, p, batch)
        return jnp.mean(jnp.maximum(lg, 0) - lg * labels
                        + jnp.log1p(jnp.exp(-jnp.abs(lg))))

    g = jax.grad(loss)(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat)
    # embedding table receives gradient
    assert np.abs(np.asarray(g["table"])).sum() > 0


def test_recsys_retrieval_scores_match_forward(rng):
    cfg = recsys.RecSysConfig(name="deepfm", kind="deepfm",
                              vocab_sizes=(16,) * 4, embed_dim=4,
                              mlp_dims=(8,))
    params = recsys.init_params(cfg, jax.random.key(0))
    offs = recsys.field_offsets(cfg.vocab_sizes)
    user = jnp.asarray(
        np.stack([offs[f] + rng.integers(0, 16, 1) for f in (1, 2, 3)], 1),
        jnp.int32)
    cands = jnp.asarray(offs[0] + np.arange(5), jnp.int32)
    scores = recsys.retrieval_scores(
        cfg, params, {"user_idx": user, "cand_idx": cands})
    # manual: forward each candidate
    for i in range(5):
        idx = jnp.concatenate([cands[i:i + 1, None], user], axis=1)
        lone = recsys.forward(cfg, params, {"sparse_idx": idx})
        np.testing.assert_allclose(float(scores[i]), float(lone[0]),
                                   rtol=1e-5)


def test_embedding_bag_matches_manual(rng):
    table = jnp.asarray(rng.normal(size=(20, 4)).astype(np.float32))
    idx = jnp.asarray([0, 3, 3, 7], jnp.int32)
    bags = jnp.asarray([0, 0, 1, 1], jnp.int32)
    out = recsys.embedding_bag(table, idx, bags, 2)
    want0 = np.asarray(table[0] + table[3])
    want1 = np.asarray(table[3] + table[7])
    np.testing.assert_allclose(np.asarray(out[0]), want0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), want1, rtol=1e-6)
