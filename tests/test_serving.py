"""Continuous-batching serve frontend (core.serving + launch.serve).

Pins the contract of ROADMAP item 1: concurrent submitters through the
micro-batching frontend get results identical to solo
``RetrievalEvaluator.search`` calls per query (ids bitwise, scores
allclose — the repo's cross-impl convention) across the ``score_impl``
× W ∈ {1, 2} matrix; the deadline flush fires for a lone queued query;
admission control never drops an accepted request; shutdown drains the
queue; and ``launch.serve`` measures steady-state latencies (the old
warm-up lie) over exactly-``--batch``-query requests (the old
truncating slice).
"""

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.core.evaluator import RetrievalEvaluator
from repro.core.serving import (ClusterServeBackend, EvaluatorServeBackend,
                                ServeClosedError, ServeFrontend,
                                ServeOverloadError)
from repro.core.sharded_search import ShardedSearchDriver
from repro.data.table import stable_id_hash
from repro.data.tokenizer import HashTokenizer
from repro.launch.distributed import SimulatedCluster

pytestmark = pytest.mark.serving


# -- frontend mechanics (trivial callable backend, no encoder) ----------------


def _echo_backend(delay=0.0):
    """Backend whose ids encode (query index within batch) — demux order
    is checkable without a model.  Texts are 'q<i>' strings."""

    def run(texts, topk):
        if delay:
            time.sleep(delay)
        qnum = np.asarray([int(t[1:]) for t in texts])
        ids = qnum[:, None] * 100 + np.arange(topk)[None, :]
        return ids, ids.astype(np.float32)

    return run


def test_demux_routes_rows_to_the_right_request():
    with ServeFrontend(_echo_backend(), topk=3, max_batch=8,
                       max_wait_ms=20) as fe:
        futs = {i: fe.submit(f"q{i}") for i in range(20)}
        for i, f in futs.items():
            ids, vals = f.result(timeout=10)
            assert ids.shape == (1, 3)
            np.testing.assert_array_equal(ids[0], i * 100 + np.arange(3))
    assert fe.stats["completed"] == 20
    assert fe.stats["queries"] == 20            # pad rows not counted


def test_small_batch_requests_coalesce_and_demux():
    with ServeFrontend(_echo_backend(), topk=2, max_batch=8,
                       max_wait_ms=20) as fe:
        f1 = fe.submit(["q3", "q5", "q7"])
        f2 = fe.submit("q9")
        f3 = fe.submit({"a": "q1", "b": "q2"})
        ids1, _ = f1.result(10)
        assert ids1.shape == (3, 2)
        np.testing.assert_array_equal(ids1[:, 0], [300, 500, 700])
        np.testing.assert_array_equal(f2.result(10)[0][:, 0], [900])
        np.testing.assert_array_equal(f3.result(10)[0][:, 0], [100, 200])


def test_deadline_flush_fires_for_a_single_queued_query():
    """A lone query must not wait for max_batch company: the deadline
    flushes it after max_wait_ms."""
    with ServeFrontend(_echo_backend(), topk=2, max_batch=64,
                       max_wait_ms=30) as fe:
        t0 = time.monotonic()
        ids, _ = fe.submit("q4").result(timeout=10)
        dt = time.monotonic() - t0
        np.testing.assert_array_equal(ids[0], [400, 401])
    assert fe.stats["flush_deadline"] == 1
    assert fe.stats["batches"] == 1
    assert dt < 5.0                      # deadline, not forever


def test_full_flush_does_not_wait_for_deadline():
    """max_batch queries queued -> flush immediately (reason 'full'),
    far before a long deadline."""
    with ServeFrontend(_echo_backend(), topk=2, max_batch=4,
                       max_wait_ms=10_000) as fe:
        futs = [fe.submit(f"q{i}") for i in range(4)]
        t0 = time.monotonic()
        for f in futs:
            f.result(timeout=10)
        assert time.monotonic() - t0 < 5.0
    assert fe.stats["flush_full"] >= 1


def test_oversized_batch_splits_on_request_boundary():
    """A request that would overflow the forming micro-batch is carried
    whole into the next one — requests are never split."""
    with ServeFrontend(_echo_backend(), topk=2, max_batch=4,
                       max_wait_ms=10) as fe:
        futs = [fe.submit(["q1", "q2", "q3"]),
                fe.submit(["q4", "q5", "q6"]),
                fe.submit(["q7", "q8"])]
        for f in futs:
            f.result(timeout=10)
        assert fe.stats["queries"] == 8
        assert fe.stats["max_batch_seen"] <= 4


def test_overload_rejects_fast_but_never_drops_accepted():
    accepted, rejected = [], []
    lock = threading.Lock()
    fe = ServeFrontend(_echo_backend(delay=0.02), topk=2, max_batch=1,
                       max_wait_ms=0, max_queue=2)

    def client(i):
        try:
            f = fe.submit(f"q{i}")
        except ServeOverloadError:
            with lock:
                rejected.append(i)
            return
        with lock:
            accepted.append((i, f))

    with ThreadPoolExecutor(8) as pool:
        list(pool.map(client, range(24)))
    fe.close()
    assert rejected, "overload never triggered — queue bound not enforced"
    assert accepted, "every request rejected"
    # every accepted request resolved with its own correct rows
    for i, f in accepted:
        ids, _ = f.result(timeout=0)     # must already be done post-close
        np.testing.assert_array_equal(ids[0], [i * 100, i * 100 + 1])
    assert fe.stats["accepted"] == len(accepted) == fe.stats["completed"]
    assert fe.stats["rejected"] == len(rejected)


def test_close_drains_queue_then_refuses_new_requests():
    fe = ServeFrontend(_echo_backend(delay=0.01), topk=2, max_batch=2,
                       max_wait_ms=0, max_queue=64)
    futs = [fe.submit(f"q{i}") for i in range(10)]
    fe.close()                           # must drain all 10, then stop
    for i, f in enumerate(futs):
        ids, _ = f.result(timeout=0)
        assert ids[0][0] == i * 100
    assert fe.stats["completed"] == 10
    with pytest.raises(ServeClosedError):
        fe.submit("q0")
    fe.close()                           # idempotent


def test_backend_error_propagates_to_every_request_future():
    def boom(texts, topk):
        raise RuntimeError("backend down")

    with ServeFrontend(boom, topk=2, max_batch=4, max_wait_ms=5) as fe:
        futs = [fe.submit(f"q{i}") for i in range(3)]
        for f in futs:
            with pytest.raises(RuntimeError, match="backend down"):
                f.result(timeout=10)
    assert fe.stats["failed"] == 3


# -- construction-time validation ---------------------------------------------


@pytest.mark.parametrize("kwargs", (
    {"topk": 0}, {"topk": -3}, {"max_batch": 0}, {"max_wait_ms": -1.0},
    {"max_queue": 0},
))
def test_frontend_rejects_bad_knobs(kwargs):
    with pytest.raises(ValueError):
        ServeFrontend(_echo_backend(), **kwargs)


def test_frontend_rejects_backend_without_entry_point():
    with pytest.raises(ValueError, match="backend"):
        ServeFrontend(object())


@pytest.mark.parametrize("kwargs", (
    {"topk": 0}, {"topk": -1}, {"serve_max_batch": 0},
    {"serve_max_wait_ms": -0.5}, {"serve_max_queue": 0},
    {"score_impl": "torch"}, {"heap_impl": "cuda"},
    {"encode_batch_size": 0}, {"superchunk_max_mb": 0},
))
def test_evaluation_arguments_reject_bad_knobs(kwargs):
    with pytest.raises(ValueError):
        EvaluationArguments(**kwargs)


def test_evaluation_arguments_error_names_the_knob():
    with pytest.raises(ValueError, match="score_impl"):
        EvaluationArguments(score_impl="torch")
    with pytest.raises(ValueError, match="topk"):
        EvaluationArguments(topk=0)


def test_result_heap_rejects_unknown_impl_and_bad_k():
    from repro.core.result_heap import FastResultHeapq
    with pytest.raises(ValueError, match="impl"):
        FastResultHeapq(4, 3, impl="torch")
    with pytest.raises(ValueError, match="k must"):
        FastResultHeapq(4, 0)


def test_empty_and_oversized_requests_rejected_at_submit():
    with ServeFrontend(_echo_backend(), topk=2, max_batch=4,
                       max_wait_ms=0) as fe:
        with pytest.raises(ValueError, match="empty"):
            fe.submit([])
        with pytest.raises(ValueError, match="exceeds max_batch"):
            fe.submit([f"q{i}" for i in range(5)])


# -- driver async reduce ------------------------------------------------------


def test_search_async_matches_sync_over_pipelined_rounds():
    rng = np.random.default_rng(3)
    q = rng.normal(size=(6, 16)).astype(np.float32)
    docs = rng.normal(size=(150, 16)).astype(np.float32)
    load = lambda lo, hi: docs[lo:hi]
    sync = ShardedSearchDriver(score_impl="numpy", chunk_size=40)
    ref = sync.search(q, 150, load, 7)
    drv = ShardedSearchDriver(score_impl="numpy", chunk_size=40)
    futs = [drv.search_async(q, 150, load, 7) for _ in range(4)]
    for f in futs:                       # rounds overlap reduce w/ score
        vals, pos = f.result(timeout=30)
        np.testing.assert_array_equal(pos, ref[1])
        np.testing.assert_allclose(vals, ref[0], rtol=1e-6)
    drv.close()
    drv.close()                          # idempotent


def test_search_async_matches_sync_across_cluster_rounds():
    """W=2 drivers each running R pipelined rounds: round r's gather
    merge (on the reduce thread) overlaps round r+1's scoring, and every
    round still reproduces the sync result on every rank."""
    rng = np.random.default_rng(5)
    q = rng.normal(size=(5, 16)).astype(np.float32)
    docs = rng.normal(size=(130, 16)).astype(np.float32)
    load = lambda lo, hi: docs[lo:hi]
    single = ShardedSearchDriver(score_impl="numpy", chunk_size=32)
    ref_vals, ref_pos = single.search(q, 130, load, 6)
    cluster = SimulatedCluster(2)
    drivers = [ShardedSearchDriver(
        n_workers=2, worker_index=rank, sharder=cluster.sharder,
        score_impl="numpy", chunk_size=32, gather=cluster.gather)
        for rank in range(2)]

    def worker(rank):
        futs = [drivers[rank].search_async(q, 130, load, 6)
                for _ in range(3)]
        return [f.result(timeout=60) for f in futs]

    outs = cluster.run(worker)
    for rank in range(2):
        drivers[rank].close()
        for vals, pos in outs[rank]:
            np.testing.assert_array_equal(pos, ref_pos)
            np.testing.assert_allclose(vals, ref_vals, rtol=1e-5,
                                       atol=1e-6)


# -- evaluator-backed frontend: the score_impl × W matrix ---------------------


@pytest.fixture(scope="module")
def serve_env(tiny_retriever, tiny_params, retrieval_data,
              tmp_path_factory):
    """Solo per-query reference runs + a shared warm cache."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    cache = EmbeddingCache(str(tmp_path_factory.mktemp("svcache") / "c"),
                           dim=32)

    def make(score_impl, rank=0, world=1, gather=None, sharder=None):
        return RetrievalEvaluator(
            EvaluationArguments(topk=5, encode_batch_size=20,
                                score_impl=score_impl,
                                serve_max_batch=8, serve_max_wait_ms=4.0),
            tiny_retriever, coll, tiny_params,
            process_index=rank, process_count=world,
            gather=gather, sharder=sharder)

    queries = retrieval_data["queries"]
    corpus = retrieval_data["corpus"]
    ref = make("numpy")
    ref.search(queries, corpus, cache=cache)    # warm the cache
    # solo reference: one evaluator.search PER QUERY — what a lone
    # client would get without the frontend
    solo = {}
    for qid, text in queries.items():
        qh, ids, vals = ref.search({qid: text}, corpus, cache=cache)
        assert qh[0] == stable_id_hash(qid)
        solo[qid] = (ids[0], vals[0])
    return {"make": make, "cache": cache, "solo": solo,
            "queries": queries, "corpus": corpus}


def _make_frontend(env, score_impl, world):
    if world == 1:
        ev = env["make"](score_impl)
        return ServeFrontend.from_evaluator(ev, env["corpus"],
                                            env["cache"])
    cluster = SimulatedCluster(world)
    evs = [env["make"](score_impl, rank, world, cluster.gather,
                       cluster.sharder) for rank in range(world)]
    return ServeFrontend.from_cluster(evs, cluster, env["corpus"],
                                      [env["cache"]] * world)


@pytest.mark.parametrize("world", (1, 2))
@pytest.mark.parametrize("score_impl", ("numpy", "jax", "pallas_fused"))
def test_concurrent_submitters_match_solo_search(serve_env, score_impl,
                                                 world):
    """6 submitter threads racing through the frontend get, per query,
    the solo-search result: ids bitwise, scores allclose (the repo's
    cross-impl convention — coalescing changes the GEMM batch shape, so
    low-bit BLAS drift is expected and bounded, rankings are not)."""
    fe = _make_frontend(serve_env, score_impl, world)
    queries = serve_env["queries"]
    out = {}
    lock = threading.Lock()

    def client(item):
        qid, text = item
        ids, vals = fe.submit(text).result(timeout=120)
        with lock:
            out[qid] = (ids[0], vals[0])

    try:
        with ThreadPoolExecutor(6) as pool:
            list(pool.map(client, list(queries.items())))
    finally:
        fe.close()
    assert fe.stats["completed"] == len(queries)
    for qid, (ref_ids, ref_vals) in serve_env["solo"].items():
        ids, vals = out[qid]
        np.testing.assert_array_equal(ids, ref_ids, err_msg=qid)
        np.testing.assert_allclose(vals, ref_vals, rtol=1e-5, atol=1e-6,
                                   err_msg=qid)


def test_mixed_size_requests_match_solo_search(serve_env):
    """Single-query and small-batch requests coalesced into the same
    micro-batches all demux to their solo-search rows."""
    fe = _make_frontend(serve_env, "jax", 1)
    qids = list(serve_env["queries"])
    texts = serve_env["queries"]
    try:
        f_batch = fe.submit([texts[q] for q in qids[:3]])
        f_single = [fe.submit(texts[q]) for q in qids[3:8]]
        ids3, vals3 = f_batch.result(timeout=120)
        for j, qid in enumerate(qids[:3]):
            ref_ids, ref_vals = serve_env["solo"][qid]
            np.testing.assert_array_equal(ids3[j], ref_ids)
            np.testing.assert_allclose(vals3[j], ref_vals, rtol=1e-5,
                                       atol=1e-6)
        for qid, f in zip(qids[3:8], f_single):
            ids, vals = f.result(timeout=120)
            ref_ids, ref_vals = serve_env["solo"][qid]
            np.testing.assert_array_equal(ids[0], ref_ids)
            np.testing.assert_allclose(vals[0], ref_vals, rtol=1e-5,
                                       atol=1e-6)
    finally:
        fe.close()


def test_from_evaluator_defaults_come_from_args(serve_env):
    ev = serve_env["make"]("numpy")
    fe = ServeFrontend.from_evaluator(ev, serve_env["corpus"],
                                      serve_env["cache"])
    try:
        assert fe.topk == ev.args.topk == 5
        assert fe.max_batch == ev.args.serve_max_batch == 8
        assert fe.max_wait_s == pytest.approx(
            ev.args.serve_max_wait_ms / 1e3)
    finally:
        fe.close()


# -- launch.serve measurement regressions -------------------------------------


@pytest.fixture(scope="module")
def serve_main_stats(tmp_path_factory):
    """One shared --smoke run of the serve driver (wrap-around batch:
    5 does not divide the 64 synthetic queries)."""
    from repro.launch import serve
    data_dir = str(tmp_path_factory.mktemp("serve_main"))
    return serve.main([
        "--smoke", "--data-dir", data_dir, "--n-requests", "6",
        "--batch", "5", "--concurrency", "3", "--workers", "1",
        "--max-batch", "8", "--max-wait-ms", "2", "--topk", "7"])


def test_serve_main_steady_state_latencies(serve_main_stats):
    """The old loop timed the corpus-encoding warm-up as 'request 0'
    (~80x the steady state).  With the explicit warm pass, request 0 is
    a steady-state sample: within ~2x of request 1 (3x allowed for
    scheduler jitter at ms scale)."""
    lat = serve_main_stats["latencies_ms"]
    assert len(lat) == 6
    assert lat[0] <= 3.0 * lat[1] + 1.0, lat
    assert serve_main_stats["warm_s"] > 0
    # warm-up work really happened outside the timed loop
    assert max(lat) / 1e3 < serve_main_stats["warm_s"]


def test_serve_main_requests_carry_exactly_batch_queries(serve_main_stats):
    """6 requests × 5 queries over 64 ids wraps around instead of
    truncating (the old `q_ids[lo:lo+batch]` bug); main asserts each
    response has exactly (batch, topk) rows, so completing 6 requests
    proves it."""
    fs = serve_main_stats["frontend"]
    # 6 timed requests + the warm rung ladder (1+2+4+8), real rows only
    assert fs["queries"] == 6 * 5 + 15
    assert fs["completed"] == 6 + 4
    assert serve_main_stats["qps"] > 0


def test_backend_classes_validate_world_size(serve_env):
    cluster = SimulatedCluster(2)
    with pytest.raises(ValueError, match="world"):
        ClusterServeBackend([serve_env["make"]("numpy")], cluster,
                            serve_env["corpus"])


def test_evaluator_backend_closes_driver(serve_env):
    ev = serve_env["make"]("numpy")
    backend = EvaluatorServeBackend(ev, serve_env["corpus"],
                                    serve_env["cache"])
    ids, vals = None, None
    fut = backend.begin([next(iter(serve_env["queries"].values()))], 5)
    ids, vals = fut.result(timeout=60)
    assert ids.shape == (1, 5)
    backend.close()
    backend.close()                      # idempotent
