from repro.core.config import (DataArguments, ModelArguments,
                               RetrievalTrainingArguments, parse_cli)


def test_parse_cli_multiple_dataclasses():
    train, model, data = parse_cli(
        RetrievalTrainingArguments, ModelArguments, DataArguments,
        argv=["--learning_rate", "5e-4", "--loss=ws",
              "--group_size", "4", "--max_steps", "77",
              "--async_checkpoint", "false"])
    assert train.learning_rate == 5e-4
    assert train.max_steps == 77
    assert train.async_checkpoint is False
    assert model.loss == "ws"
    assert data.group_size == 4


def test_parse_cli_defaults_untouched():
    model = parse_cli(ModelArguments, argv=[])
    assert model == ModelArguments()


def test_parse_cli_tuple_field():
    from repro.core.config import EvaluationArguments
    ev = parse_cli(EvaluationArguments,
                   argv=["--metrics", "ndcg@10,mrr@5"])
    assert ev.metrics == ("ndcg@10", "mrr@5")
