"""Lazy dataset-view algebra vs an eagerly materialized oracle.

Every combinator (filter / map / select / concat / interleave) and
nested compositions thereof must agree with the obvious eager
implementation — rows, ids, and end-to-end search rankings bitwise —
while materializing only touched rows.
"""

import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.evaluator import RetrievalEvaluator
from repro.data.table import stable_id_hash
from repro.data.tokenizer import HashTokenizer
from repro.data.views import (ConcatView, DatasetView, FilterView,
                              InterleaveView, MapView, RecordsView,
                              SelectView, TableView, ViewTexts, as_view,
                              row_text)

from tests._hypothesis_shim import given, settings, st


def recs(n, prefix="r", start=0):
    return [{"_id": f"{prefix}{start + i}", "text": f"text {prefix} {i} "
             + "x" * (i % 7)} for i in range(n)]


def eager(view: DatasetView) -> list[dict]:
    """The oracle: materialize everything."""
    return [view.row(i) for i in range(len(view))]


def assert_matches(view, expected_rows):
    """View == eager reference on every access surface."""
    assert len(view) == len(expected_rows)
    assert eager(view) == expected_rows
    assert view.rows(0, len(view)) == expected_rows
    want_ids = [r.get("_id") for r in expected_rows]
    np.testing.assert_array_equal(
        view.id_hashes, [stable_id_hash(i) for i in want_ids])
    assert view.raw_ids() == want_ids
    assert list(view.texts()) == [row_text(r) for r in expected_rows]
    for i in (0, len(expected_rows) - 1):
        if expected_rows:
            assert view.get(want_ids[i]) == expected_rows[i]
            assert view.index_of(want_ids[i]) == i
            assert want_ids[i] in view
    assert "no-such-id" not in view


# -- single combinators vs oracle ---------------------------------------------


def test_records_leaf_roundtrip():
    r = recs(13)
    assert_matches(RecordsView(r), r)


def test_dict_leaf_matches_mapping():
    d = {f"k{i}": f"v{i}" for i in range(9)}
    v = as_view(d)
    assert_matches(v, [{"_id": k, "text": t} for k, t in d.items()])
    assert v.raw_ids() == list(d)


def test_filter_matches_eager():
    r = recs(31)
    pred = lambda rec: len(rec["text"]) % 3 == 0          # noqa: E731
    assert_matches(RecordsView(r).filter(pred),
                   [x for x in r if pred(x)])


def test_filter_is_lazy_until_first_access():
    calls = []

    def pred(rec):
        calls.append(rec["_id"])
        return True

    v = RecordsView(recs(8)).filter(pred)
    w = ConcatView(v, RecordsView(recs(3, "o")))   # composing stays free
    assert calls == []
    assert len(w) == 11                            # first access scans once
    assert len(calls) == 8
    len(w)
    assert len(calls) == 8                         # index is cached


def test_map_matches_eager():
    r = recs(17)
    fn = lambda rec: {**rec, "text": rec["text"].upper()}  # noqa: E731
    v = RecordsView(r).map(fn)
    assert_matches(v, [fn(x) for x in r])


def test_map_rekey_recomputes_hashes():
    r = recs(6)
    fn = lambda rec: {**rec, "_id": "ns-" + rec["_id"]}    # noqa: E731
    v = RecordsView(r).map(fn, rekey=True)
    assert_matches(v, [fn(x) for x in r])
    assert v.index_of("ns-r3") == 3
    # without rekey, ids are answered from the parent
    np.testing.assert_array_equal(
        RecordsView(r).map(fn).id_hashes, RecordsView(r).id_hashes)


def test_select_positions_ids_mask_negative():
    r = recs(10)
    base = RecordsView(r)
    assert_matches(base.select([7, 2, 2, 0]),
                   [r[7], r[2], r[2], r[0]])
    assert_matches(base.select(["r4", "r9"]), [r[4], r[9]])
    mask = np.zeros(10, bool)
    mask[[1, 5]] = True
    assert_matches(base.select(mask), [r[1], r[5]])
    assert_matches(base.select([-1, -10]), [r[9], r[0]])
    with pytest.raises(IndexError):
        base.select([10])
    with pytest.raises(IndexError):
        base.select(np.zeros(4, bool))
    with pytest.raises(KeyError):
        base.select(["nope"])


def test_concat_matches_eager():
    a, b, c = recs(5, "a"), recs(0, "b"), recs(7, "c")
    v = ConcatView(RecordsView(a), RecordsView(b), RecordsView(c))
    assert_matches(v, a + b + c)
    assert_matches(RecordsView(a) + RecordsView(c), a + c)
    assert_matches(RecordsView(a).concat(RecordsView(b), RecordsView(c)),
                   a + b + c)
    assert v.row(-1) == c[-1]
    # spans crossing child boundaries
    assert v.rows(3, 9) == (a + c)[3:9]


def test_interleave_round_robin_order():
    a, b = recs(4, "a"), recs(2, "b")
    v = InterleaveView(RecordsView(a), RecordsView(b))
    want = [a[0], b[0], a[1], b[1], a[2], a[3]]   # b drops out after 2
    assert_matches(v, want)


def test_nested_composition_matches_eager():
    r = recs(40)
    pred = lambda rec: int(rec["_id"][1:]) % 2 == 0        # noqa: E731
    fn = lambda rec: {**rec, "text": rec["text"][::-1]}    # noqa: E731
    other = recs(11, "z")
    v = (RecordsView(r).filter(pred).map(fn)
         + RecordsView(other)).select(list(range(0, 25, 2))[::-1])
    ref = [fn(x) for x in r if pred(x)] + other
    ref = [ref[i] for i in list(range(0, 25, 2))[::-1]]
    assert_matches(v, ref)
    deep = v.interleave(RecordsView(recs(3, "w"))).filter(
        lambda rec: not rec["_id"].startswith("w"))
    assert_matches(deep, ref)


# -- streaming contract -------------------------------------------------------


@pytest.mark.parametrize("lo,hi,chunk", [(0, 23, 5), (3, 17, 4),
                                         (0, 23, 64), (7, 7, 3)])
def test_open_slice_ordered_chunks(lo, hi, chunk):
    r = recs(23)
    v = RecordsView(r)
    got, offs = [], []
    for off, rows in v.open_slice(lo, hi, chunk):
        offs.append(off)
        assert len(rows) <= chunk
        got.extend(rows)
    assert got == r[lo:hi]
    assert offs == list(range(lo, hi, chunk))


def test_open_slice_clamps_hi_and_evicts():
    evicted = []

    class Spy(RecordsView):
        def evict(self, lo, hi):
            evicted.append((lo, hi))

    v = Spy(recs(10))
    rows = [r for _, chunk in v.open_slice(0, 999, 4) for r in chunk]
    assert len(rows) == 10
    assert evicted == [(0, 4), (4, 8), (8, 10)]


def test_combinators_propagate_evict():
    evicted = []

    class Spy(RecordsView):
        def evict(self, lo, hi):
            evicted.append((lo, hi))

    v = (Spy(recs(12)).filter(lambda r: True)
         + Spy(recs(4, "b"))).select(list(range(14)))
    list(v.open_slice(0, len(v), 6))
    assert evicted                                 # reached the leaves
    assert all(0 <= lo < hi <= 12 for lo, hi in evicted)


def test_viewtexts_lazy_sequence():
    r = recs(9)
    t = ViewTexts(RecordsView(r))
    want = [row_text(x) for x in r]
    assert len(t) == 9
    assert t[4] == want[4]
    assert t[2:7] == want[2:7]
    assert t[1:8:3] == want[1:8:3]
    assert list(t) == want
    assert t[-2:] == want[-2:]


def test_table_view_over_mmap(retrieval_data, tmp_path):
    from repro.core.config import MaterializedQRelConfig
    from repro.core.materialized_qrel import MaterializedQRel
    d = retrieval_data["dir"]
    m = MaterializedQRel(MaterializedQRelConfig(
        qrel_path=f"{d}/qrels/train.tsv", query_path=f"{d}/queries.jsonl",
        corpus_path=f"{d}/corpus.jsonl"), str(tmp_path))
    v = m.corpus_view()
    assert isinstance(v, TableView)
    assert len(v) == len(retrieval_data["corpus"])
    for did, text in list(retrieval_data["corpus"].items())[:5]:
        assert v.get(did)["text"] == text
        assert v.text(v.index_of(did)) == m.doc_text(stable_id_hash(did))
    # a full streaming scan (with page eviction) sees every row once
    seen = [r["_id"] for _, rows in v.open_slice(0, len(v), 7)
            for r in rows]
    assert seen == list(retrieval_data["corpus"])


def test_as_view_coercions():
    v = RecordsView(recs(3))
    assert as_view(v) is v
    assert isinstance(as_view({"a": "t"}), DatasetView)
    assert isinstance(as_view(recs(2)), RecordsView)
    assert len(as_view([])) == 0
    with pytest.raises(TypeError):
        as_view(42)


# -- end-to-end: rankings through views == rankings through dicts -------------


def _evaluator(tiny_retriever, tiny_params, score_impl, **kw):
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    return RetrievalEvaluator(
        EvaluationArguments(topk=10, score_impl=score_impl,
                            metrics=("ndcg@10", "recall@10")),
        tiny_retriever, coll, tiny_params, **kw)


@pytest.mark.parametrize("score_impl", ("numpy", "jax", "pallas_fused"))
def test_search_views_bitwise_equals_dicts(tiny_retriever, tiny_params,
                                           retrieval_data, score_impl):
    """Composed lazy corpus == eager dict corpus, identical rankings."""
    ev = _evaluator(tiny_retriever, tiny_params, score_impl)
    corpus = retrieval_data["corpus"]
    qh_ref, ids_ref, s_ref = ev.search(retrieval_data["queries"], corpus)

    items = list(corpus.items())
    half = len(items) // 2
    view = ConcatView(
        RecordsView([{"_id": k, "text": t} for k, t in items[:half]]),
        as_view(dict(items[half:])))
    q_view = as_view(retrieval_data["queries"])
    qh, ids, s = ev.search(q_view, view)
    np.testing.assert_array_equal(qh, qh_ref)
    np.testing.assert_array_equal(ids, ids_ref)
    np.testing.assert_array_equal(s, s_ref)


def test_search_filtered_view_equals_filtered_dict(tiny_retriever,
                                                   tiny_params,
                                                   retrieval_data):
    ev = _evaluator(tiny_retriever, tiny_params, "jax")
    corpus = retrieval_data["corpus"]
    keep = {k: t for k, t in corpus.items() if "topic1" not in t}
    assert 0 < len(keep) < len(corpus)
    _, ids_ref, s_ref = ev.search(retrieval_data["queries"], keep)
    view = as_view(corpus).filter(lambda r: "topic1" not in r["text"])
    _, ids, s = ev.search(retrieval_data["queries"], view)
    np.testing.assert_array_equal(ids, ids_ref)
    np.testing.assert_array_equal(s, s_ref)


@pytest.mark.distributed
@pytest.mark.parametrize("w", (2,))
def test_search_views_sharded_equals_single(tiny_retriever, tiny_params,
                                            retrieval_data, w):
    """W simulated workers over a ConcatView == single process."""
    from repro.launch.distributed import SimulatedCluster
    ev = _evaluator(tiny_retriever, tiny_params, "jax")
    corpus = retrieval_data["corpus"]
    items = list(corpus.items())
    half = len(items) // 2

    def make_view():
        return ConcatView(as_view(dict(items[:half])),
                          as_view(dict(items[half:])))

    _, ids_ref, s_ref = ev.search(retrieval_data["queries"], make_view())
    cluster = SimulatedCluster(w)
    evs = [_evaluator(tiny_retriever, tiny_params, "jax",
                      process_index=rank, process_count=w,
                      gather=cluster.gather, sharder=cluster.sharder)
           for rank in range(w)]
    outs = cluster.run(lambda rank: evs[rank].search(
        retrieval_data["queries"], make_view()))
    for _, ids, s in outs:
        np.testing.assert_array_equal(ids, ids_ref)
        np.testing.assert_array_equal(s, s_ref)


# -- property tests (skip individually when hypothesis is absent) -------------


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 60), st.integers(1, 17), st.integers(0, 7))
def test_property_open_slice_partitions(n, chunk, mod):
    r = recs(n)
    v = RecordsView(r).filter(lambda rec: len(rec["text"]) % 7 != mod)
    want = [x for x in r if len(x["text"]) % 7 != mod]
    got = [x for _, rows in v.open_slice(0, len(v), chunk) for x in rows]
    assert got == want


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 25), max_size=30), st.integers(1, 4))
def test_property_compositions_match_eager(positions, k):
    parts = [recs(9, f"p{j}") for j in range(k)]
    flat = [x for p in parts for x in p]
    v = ConcatView(*[RecordsView(p) for p in parts])
    sel = [p % len(flat) for p in positions]
    assert_matches(v.select(sel), [flat[i] for i in sel])
    inter = InterleaveView(*[RecordsView(p) for p in parts])
    ref = [p[i] for i in range(9) for p in parts]
    assert_matches(inter, ref)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 40), st.integers(0, 40), st.integers(1, 9))
def test_property_concat_rows_spans(a_n, b_n, chunk):
    a, b = recs(a_n, "a"), recs(b_n, "b")
    v = RecordsView(a) + RecordsView(b)
    ref = a + b
    for lo in range(0, len(ref) + 1, chunk):
        hi = min(lo + chunk * 2, len(ref))
        assert v.rows(lo, hi) == ref[lo:hi]
