import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.core.evaluator import RetrievalEvaluator
from repro.core.fair_sharding import FairSharder
from repro.data.tokenizer import HashTokenizer


@pytest.fixture()
def evaluator(tiny_retriever, tiny_params):
    coll = RetrievalCollator(DataArguments(vocab_size=257), HashTokenizer(257))
    return RetrievalEvaluator(
        EvaluationArguments(topk=10, metrics=("ndcg@10", "recall@10")),
        tiny_retriever, coll, tiny_params)


def test_search_returns_ranked(evaluator, retrieval_data):
    qh, ids, scores = evaluator.search(retrieval_data["queries"],
                                       retrieval_data["corpus"])
    assert ids.shape == (len(retrieval_data["queries"]), 10)
    assert (np.diff(scores, axis=1) <= 1e-6).all()      # descending


def test_identity_retrieval(evaluator, retrieval_data):
    """A doc used as its own query must rank itself first."""
    corpus = retrieval_data["corpus"]
    some = dict(list(corpus.items())[:5])
    qh, ids, _ = evaluator.search(some, corpus, topk=3)
    from repro.data.table import stable_id_hash
    for qi, did in enumerate(some):
        assert ids[qi, 0] == stable_id_hash(did)


def test_multi_shard_merge_equals_single(tiny_retriever, tiny_params,
                                         retrieval_data):
    """2 simulated nodes with merged heaps == 1 node (Table 2 invariant)."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    args = EvaluationArguments(topk=8, metrics=("ndcg@10",))
    single = RetrievalEvaluator(args, tiny_retriever, coll, tiny_params)
    qh1, ids1, s1 = single.search(retrieval_data["queries"],
                                  retrieval_data["corpus"])

    shards = {}

    def merge_via_bus(heap):
        # simulated transport: collect both processes' heaps, merge
        shards[merge_via_bus.rank] = heap
        if len(shards) < 2:
            return heap
        a, b = shards[0], shards[1]
        a.merge(b)
        return a

    evs = []
    for rank in range(2):
        ev = RetrievalEvaluator(args, tiny_retriever, coll, tiny_params,
                                process_index=rank, process_count=2,
                                shard_merge_fn=merge_via_bus)
        evs.append(ev)
    merge_via_bus.rank = 0
    evs[0].search(retrieval_data["queries"], retrieval_data["corpus"])
    merge_via_bus.rank = 1
    qh2, ids2, s2 = evs[1].search(retrieval_data["queries"],
                                  retrieval_data["corpus"])
    np.testing.assert_allclose(s1, s2, rtol=1e-5)
    np.testing.assert_array_equal(ids1, ids2)


def test_mining_forwards_cache(evaluator, retrieval_data, tmp_path):
    """Mining with a warm cache must not re-encode cached corpus ids
    (the paper's Table 3 "w/ Cached Embs" path)."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=32)
    evaluator.evaluate(retrieval_data["queries"], retrieval_data["corpus"],
                       retrieval_data["qrels"], cache=cache)
    assert len(cache) == len(retrieval_data["corpus"])

    corpus_encodes = []
    orig = evaluator._encode_texts

    def counting(texts, is_query, max_len=None, device=False):
        if not is_query:
            corpus_encodes.append(len(texts))
        return orig(texts, is_query, max_len, device=device)

    evaluator._encode_texts = counting
    try:
        negs = evaluator.mine_hard_negatives(
            retrieval_data["queries"], retrieval_data["corpus"],
            retrieval_data["qrels"], depth=8, cache=cache)
    finally:
        evaluator._encode_texts = orig
    assert negs
    assert corpus_encodes == []     # every corpus chunk came from the cache


def test_corpus_hash_cache_detects_mutation(evaluator, retrieval_data):
    """In-place corpus mutation (same object, same length) must not be
    served stale hashes from the per-corpus cache."""
    corpus = dict(retrieval_data["corpus"])
    h1 = evaluator._corpus_hashes(corpus)
    assert evaluator._corpus_hashes(corpus) is h1      # cache hit
    first = next(iter(corpus))
    del corpus[first]
    corpus["brand-new-doc"] = "text"                   # same len as before
    h2 = evaluator._corpus_hashes(corpus)
    from repro.data.table import stable_id_hash
    assert stable_id_hash("brand-new-doc") in h2
    assert stable_id_hash(first) not in h2


def test_mining_excludes_positives(evaluator, retrieval_data):
    negs = evaluator.mine_hard_negatives(
        retrieval_data["queries"], retrieval_data["corpus"],
        retrieval_data["qrels"], depth=8)
    for q, d, s in negs:
        assert d not in {k for k, v in retrieval_data["qrels"][q].items()
                         if v > 0}


def test_cache_roundtrip_consistency(evaluator, retrieval_data, tmp_path):
    cache = EmbeddingCache(str(tmp_path / "c"), dim=32)
    m1 = evaluator.evaluate(retrieval_data["queries"],
                            retrieval_data["corpus"],
                            retrieval_data["qrels"], cache=cache)
    assert len(cache) == len(retrieval_data["corpus"])
    m2 = evaluator.evaluate(retrieval_data["queries"],
                            retrieval_data["corpus"],
                            retrieval_data["qrels"], cache=cache)
    for k in m1:
        assert abs(m1[k] - m2[k]) < 1e-6


def test_heap_impls_agree_end_to_end(tiny_retriever, tiny_params,
                                     retrieval_data):
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    results = {}
    for impl in ("jax", "python", "pallas"):
        ev = RetrievalEvaluator(
            EvaluationArguments(topk=5, heap_impl=impl,
                                metrics=("ndcg@10",)),
            tiny_retriever, coll, tiny_params)
        _, ids, _ = ev.search(retrieval_data["queries"],
                              retrieval_data["corpus"])
        results[impl] = ids
    np.testing.assert_array_equal(results["jax"], results["python"])
    np.testing.assert_array_equal(results["jax"], results["pallas"])


@pytest.mark.parametrize("buckets", (0, 6))
def test_query_encoding_respects_query_max_len(tiny_retriever, tiny_params,
                                               buckets):
    """Queries must truncate at query_max_len, not silently inherit the
    passage budget (regression: _encode_texts never routed a max_len, so
    collator.encode_texts fell back to passage_max_len for queries)."""
    coll = RetrievalCollator(
        DataArguments(vocab_size=257, query_max_len=4, passage_max_len=64),
        HashTokenizer(257))
    ev = RetrievalEvaluator(
        EvaluationArguments(topk=2, encode_buckets=buckets,
                            metrics=("ndcg@10",)),
        tiny_retriever, coll, tiny_params)
    words = [f"w{i}" for i in range(40)]
    long_q = " ".join(words)
    head_q = " ".join(words[:4])
    q_long = ev._encode_texts([long_q], True)
    q_head = ev._encode_texts([head_q], True)
    # truncated at query_max_len=4: the 40-word query IS its 4-word head
    np.testing.assert_allclose(q_long, q_head, rtol=1e-5, atol=1e-6)
    # ...and not the passage-budget encoding of all 40 words
    p_long = ev._encode_texts([long_q], False)
    assert np.abs(q_long - p_long).max() > 1e-3


# -- cross-backend equivalence -----------------------------------------------------

SCORE_IMPLS = ("numpy", "jax", "pallas_fused")
HEAP_IMPLS = ("jax", "python", "pallas")


@pytest.fixture(scope="module")
def backend_env(tiny_retriever, tiny_params, retrieval_data,
                tmp_path_factory):
    """Shared warm cache + numpy/jax reference results for the
    score_impl x heap_impl equivalence matrix."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    cache = EmbeddingCache(str(tmp_path_factory.mktemp("beq") / "c"),
                           dim=32)

    def make(score_impl, heap_impl="jax", **kw):
        # encode_batch_size=20 leaves a ragged last chunk (96 % 20 != 0)
        return RetrievalEvaluator(
            EvaluationArguments(topk=10, encode_batch_size=20,
                                score_impl=score_impl, heap_impl=heap_impl,
                                metrics=("ndcg@10", "recall@10")),
            tiny_retriever, coll, tiny_params, **kw)

    ref = make("numpy", "jax")
    # warm the cache first: the first pass scores fresh float32 encodings,
    # later passes the float16-quantized cache — the reference must be
    # computed in the same (warm) regime every backend will see
    ref.search(retrieval_data["queries"], retrieval_data["corpus"],
               cache=cache)
    run = ref.search(retrieval_data["queries"], retrieval_data["corpus"],
                     cache=cache)
    metrics = ref.evaluate(retrieval_data["queries"],
                           retrieval_data["corpus"],
                           retrieval_data["qrels"], cache=cache)
    return {"make": make, "cache": cache, "run": run, "metrics": metrics}


@pytest.mark.parametrize("heap_impl", HEAP_IMPLS)
@pytest.mark.parametrize("score_impl", SCORE_IMPLS)
def test_backend_matrix_identical_rankings(backend_env, retrieval_data,
                                           score_impl, heap_impl):
    """Every score_impl x heap_impl combination returns the reference
    ranking bit-for-bit and the same evaluate() metrics."""
    ev = backend_env["make"](score_impl, heap_impl)
    qh, ids, vals = ev.search(retrieval_data["queries"],
                              retrieval_data["corpus"],
                              cache=backend_env["cache"])
    rqh, rids, rvals = backend_env["run"]
    np.testing.assert_array_equal(qh, rqh)
    np.testing.assert_array_equal(ids, rids)
    np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)
    metrics = ev.evaluate(retrieval_data["queries"],
                          retrieval_data["corpus"],
                          retrieval_data["qrels"],
                          cache=backend_env["cache"])
    for name, want in backend_env["metrics"].items():
        assert abs(metrics[name] - want) < 1e-9, name


@pytest.mark.parametrize("score_impl", SCORE_IMPLS)
def test_backend_shard_merge_equals_single(backend_env, retrieval_data,
                                           score_impl):
    """2 simulated nodes (shard_merge_fn transport) == 1 node, for every
    scoring backend."""
    shards = {}

    def merge_via_bus(heap):
        shards[merge_via_bus.rank] = heap
        if len(shards) < 2:
            return heap
        a, b = shards[0], shards[1]
        a.merge(b)
        return a

    evs = [backend_env["make"](score_impl, process_index=rank,
                               process_count=2,
                               shard_merge_fn=merge_via_bus)
           for rank in range(2)]
    merge_via_bus.rank = 0
    evs[0].search(retrieval_data["queries"], retrieval_data["corpus"],
                  cache=backend_env["cache"])
    merge_via_bus.rank = 1
    qh, ids, vals = evs[1].search(retrieval_data["queries"],
                                  retrieval_data["corpus"],
                                  cache=backend_env["cache"])
    rqh, rids, rvals = backend_env["run"]
    np.testing.assert_array_equal(ids, rids)
    np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)


# -- fair sharding -----------------------------------------------------------------

def test_fair_sharder_proportional():
    s = FairSharder(3, alpha=1.0)
    s.update(0, 100, 1.0)    # 100 it/s
    s.update(1, 300, 1.0)    # 300 it/s
    s.update(2, 100, 1.0)
    shares = s.shares(500)
    assert sum(shares) == 500
    assert shares[1] > shares[0] * 2      # 3x faster worker gets ~3x work


def test_fair_sharder_bounds_cover():
    s = FairSharder(4)
    bounds = s.bounds(103)
    assert bounds[0][0] == 0 and bounds[-1][1] == 103
    for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
        assert a1 == b0


# -- hard-negative selection (vectorized vs loop reference) ------------------------

def test_select_hard_negatives_equals_loop_reference():
    """The np.isin-vectorized selector must pin the old per-item loop
    (stable_id_hash + set membership over Q×k) exactly — same triplets,
    same order, same float scores."""
    from repro.core.evaluator import select_hard_negatives
    from repro.data.table import stable_id_hash

    rng = np.random.default_rng(42)
    docs = [f"doc-{i}" for i in range(50)]
    hashes = np.asarray([stable_id_hash(d) for d in docs], np.int64)
    hash_to_raw = dict(zip(hashes.tolist(), docs))
    q_ids = [f"q{i}" for i in range(7)]
    qrels = {q: {docs[j]: float(g) for j, g in
                 zip(rng.choice(50, size=4, replace=False),
                     rng.integers(0, 3, size=4))}
             for q in q_ids}                       # grades 0 — not all positive
    depth = 12
    run_ids = hashes[rng.integers(0, 50, size=(len(q_ids), depth))]
    run_ids[0, 3] = -1                             # empty slots survive
    run_ids[5, 0] = -1
    scores = rng.normal(size=(len(q_ids), depth)).astype(np.float32)

    def loop_reference(exclude_positives):
        out = []
        for qi, q in enumerate(q_ids):
            pos = {stable_id_hash(d) for d, g in qrels.get(q, {}).items()
                   if g > 0}
            for ri in range(run_ids.shape[1]):
                did = int(run_ids[qi, ri])
                if did < 0 or (exclude_positives and did in pos):
                    continue
                out.append((q, hash_to_raw[did], float(scores[qi, ri])))
        return out

    for exclude in (True, False):
        got = select_hard_negatives(q_ids, run_ids, scores, qrels,
                                    hash_to_raw, exclude)
        assert got == loop_reference(exclude)
