import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.core.evaluator import RetrievalEvaluator
from repro.core.fair_sharding import FairSharder
from repro.data.tokenizer import HashTokenizer


@pytest.fixture()
def evaluator(tiny_retriever, tiny_params):
    coll = RetrievalCollator(DataArguments(vocab_size=257), HashTokenizer(257))
    return RetrievalEvaluator(
        EvaluationArguments(topk=10, metrics=("ndcg@10", "recall@10")),
        tiny_retriever, coll, tiny_params)


def test_search_returns_ranked(evaluator, retrieval_data):
    qh, ids, scores = evaluator.search(retrieval_data["queries"],
                                       retrieval_data["corpus"])
    assert ids.shape == (len(retrieval_data["queries"]), 10)
    assert (np.diff(scores, axis=1) <= 1e-6).all()      # descending


def test_identity_retrieval(evaluator, retrieval_data):
    """A doc used as its own query must rank itself first."""
    corpus = retrieval_data["corpus"]
    some = dict(list(corpus.items())[:5])
    qh, ids, _ = evaluator.search(some, corpus, topk=3)
    from repro.data.table import stable_id_hash
    for qi, did in enumerate(some):
        assert ids[qi, 0] == stable_id_hash(did)


def test_multi_shard_merge_equals_single(tiny_retriever, tiny_params,
                                         retrieval_data):
    """2 simulated nodes with merged heaps == 1 node (Table 2 invariant)."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    args = EvaluationArguments(topk=8, metrics=("ndcg@10",))
    single = RetrievalEvaluator(args, tiny_retriever, coll, tiny_params)
    qh1, ids1, s1 = single.search(retrieval_data["queries"],
                                  retrieval_data["corpus"])

    shards = {}

    def merge_via_bus(heap):
        # simulated transport: collect both processes' heaps, merge
        shards[merge_via_bus.rank] = heap
        if len(shards) < 2:
            return heap
        a, b = shards[0], shards[1]
        a.merge(b)
        return a

    evs = []
    for rank in range(2):
        ev = RetrievalEvaluator(args, tiny_retriever, coll, tiny_params,
                                process_index=rank, process_count=2,
                                shard_merge_fn=merge_via_bus)
        evs.append(ev)
    merge_via_bus.rank = 0
    evs[0].search(retrieval_data["queries"], retrieval_data["corpus"])
    merge_via_bus.rank = 1
    qh2, ids2, s2 = evs[1].search(retrieval_data["queries"],
                                  retrieval_data["corpus"])
    np.testing.assert_allclose(s1, s2, rtol=1e-5)
    np.testing.assert_array_equal(ids1, ids2)


def test_mining_excludes_positives(evaluator, retrieval_data):
    negs = evaluator.mine_hard_negatives(
        retrieval_data["queries"], retrieval_data["corpus"],
        retrieval_data["qrels"], depth=8)
    for q, d, s in negs:
        assert d not in {k for k, v in retrieval_data["qrels"][q].items()
                         if v > 0}


def test_cache_roundtrip_consistency(evaluator, retrieval_data, tmp_path):
    cache = EmbeddingCache(str(tmp_path / "c"), dim=32)
    m1 = evaluator.evaluate(retrieval_data["queries"],
                            retrieval_data["corpus"],
                            retrieval_data["qrels"], cache=cache)
    assert len(cache) == len(retrieval_data["corpus"])
    m2 = evaluator.evaluate(retrieval_data["queries"],
                            retrieval_data["corpus"],
                            retrieval_data["qrels"], cache=cache)
    for k in m1:
        assert abs(m1[k] - m2[k]) < 1e-6


def test_heap_impls_agree_end_to_end(tiny_retriever, tiny_params,
                                     retrieval_data):
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    results = {}
    for impl in ("jax", "python", "pallas"):
        ev = RetrievalEvaluator(
            EvaluationArguments(topk=5, heap_impl=impl,
                                metrics=("ndcg@10",)),
            tiny_retriever, coll, tiny_params)
        _, ids, _ = ev.search(retrieval_data["queries"],
                              retrieval_data["corpus"])
        results[impl] = ids
    np.testing.assert_array_equal(results["jax"], results["python"])
    np.testing.assert_array_equal(results["jax"], results["pallas"])


# -- fair sharding -----------------------------------------------------------------

def test_fair_sharder_proportional():
    s = FairSharder(3, alpha=1.0)
    s.update(0, 100, 1.0)    # 100 it/s
    s.update(1, 300, 1.0)    # 300 it/s
    s.update(2, 100, 1.0)
    shares = s.shares(500)
    assert sum(shares) == 500
    assert shares[1] > shares[0] * 2      # 3x faster worker gets ~3x work


def test_fair_sharder_bounds_cover():
    s = FairSharder(4)
    bounds = s.bounds(103)
    assert bounds[0][0] == 0 and bounds[-1][1] == 103
    for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
        assert a1 == b0
