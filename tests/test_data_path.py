import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import st

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.data.graph import CSRGraph, NeighborSampler, make_random_graph
from repro.data.tokenizer import HashTokenizer


# -- tokenizer ----------------------------------------------------------------

def test_tokenizer_deterministic():
    t1, t2 = HashTokenizer(1000), HashTokenizer(1000)
    assert t1.encode("Hello World!") == t2.encode("hello world!")


def test_tokenizer_bounds():
    t = HashTokenizer(100)
    ids = t.encode("some words " * 50, max_len=16)
    assert len(ids) == 16
    assert all(3 <= i < 100 for i in ids)


def test_tokenizer_eos():
    t = HashTokenizer(100)
    assert t.encode("a b c", append_eos=True)[-1] == t.eos_id
    assert t.encode("a b c d e", max_len=3, append_eos=True)[-1] == t.eos_id


def test_tokenizer_truncate_to_empty():
    """Truncation may leave nothing: the eos re-pin must not IndexError
    on an empty id list (regression: max_len=0 / empty text)."""
    t = HashTokenizer(100)
    assert t.encode("a b c", max_len=0, append_eos=True) == []
    assert t.encode("a b c", max_len=0) == []
    assert t.encode("", max_len=5, append_eos=True) == [t.eos_id]
    assert t.encode("", max_len=0, append_eos=True) == []
    assert t.encode("a b c", max_len=1, append_eos=True) == [t.eos_id]
    assert t.batch_encode_ids(["a b", ""], max_len=0, append_eos=True) \
        == [[], []]


def test_batch_encode_ids_matches_scalar_encode():
    """The np.unique vectorized batch path must reproduce the scalar
    encode() exactly — same ids, same truncation/eos semantics."""
    t = HashTokenizer(512)
    texts = ["Hello, World!", "", "a a a a a", "punct...!?", "x" * 40,
             " ".join(f"tok{i}" for i in range(30)), "ümlaut çedilla",
             "123 456 123"]
    for max_len in (None, 0, 3, 16):
        for eos in (False, True):
            fresh = HashTokenizer(512)     # no warm id cache
            want = [t.encode(x, max_len, eos) for x in texts]
            assert t.batch_encode_ids(texts, max_len, eos) == want
            assert fresh.batch_encode_ids(texts, max_len, eos) == want


def test_batch_encode_matches_legacy_padding():
    t = HashTokenizer(256)
    texts = ["a b c", "a", "d e f g h i j"]
    toks, mask = t.batch_encode(texts, max_len=16, pad_to_multiple=4)
    assert toks.shape == (3, 8)            # longest=7 -> padded to 8
    assert mask.sum(1).tolist() == [3, 1, 7]
    assert (toks[mask == 0] == t.pad_id).all()


@settings(max_examples=25, deadline=None)
@given(st.text(min_size=0, max_size=80), st.integers(2, 16))
def test_collator_shapes_property(text, max_len):
    args = DataArguments(query_max_len=max_len, passage_max_len=max_len,
                         vocab_size=128, pad_to_multiple=4)
    coll = RetrievalCollator(args, HashTokenizer(128))
    batch = coll([{"query": text, "passages": [text, "x"]}])
    q = batch["query"]["tokens"]
    # padded to a multiple unless capped by max_len
    assert q.shape[0] == 1
    assert q.shape[1] % 4 == 0 or q.shape[1] == max_len
    assert q.shape[1] <= max_len
    assert batch["passage"]["tokens"].shape[0] == 2
    m = batch["query"]["mask"]
    # mask is a prefix of ones
    assert (np.cumsum(1 - m[0]) * m[0] == 0).all()


def test_collator_encode_texts_per_side_budget():
    args = DataArguments(query_max_len=4, passage_max_len=16,
                         vocab_size=128, pad_to_multiple=1)
    coll = RetrievalCollator(args, HashTokenizer(128))
    text = " ".join(f"w{i}" for i in range(10))
    assert coll.max_len_for(True) == 4 and coll.max_len_for(False) == 16
    assert coll.encode_texts([text], is_query=True)["mask"].sum() == 4
    assert coll.encode_texts([text])["mask"].sum() == 10
    assert coll.encode_texts([text], max_len=2)["mask"].sum() == 2


def test_collator_labels_passthrough():
    coll = RetrievalCollator(DataArguments(vocab_size=64), HashTokenizer(64))
    batch = coll([{"query": "q", "passages": ["a", "b"],
                   "labels": np.asarray([3.0, 1.0], np.float32)}])
    assert batch["labels"].shape == (1, 2)


# -- embedding cache -----------------------------------------------------------

def test_cache_append_and_lazy_read(tmp_path, rng):
    c = EmbeddingCache(str(tmp_path / "c"), dim=8)
    v1 = rng.normal(size=(5, 8)).astype(np.float16)
    c.cache_records([f"d{i}" for i in range(5)], v1)
    v2 = rng.normal(size=(3, 8)).astype(np.float16)
    c.cache_records([f"d{i}" for i in range(5, 8)], v2)
    assert len(c) == 8
    got = c.get(["d6", "d0"])
    np.testing.assert_allclose(got[0], v2[1], rtol=1e-3)
    np.testing.assert_allclose(got[1], v1[0], rtol=1e-3)
    assert c.has(["d0", "nope"]).tolist() == [True, False]


def test_cache_reopen(tmp_path, rng):
    c = EmbeddingCache(str(tmp_path / "c"), dim=4)
    v = rng.normal(size=(3, 4)).astype(np.float16)
    c.cache_records(["a", "b", "c"], v)
    c2 = EmbeddingCache(str(tmp_path / "c"), dim=4)   # reopen from disk
    np.testing.assert_allclose(c2.get(["b"])[0], v[1], rtol=1e-3)


def test_cache_missing_raises(tmp_path):
    c = EmbeddingCache(str(tmp_path / "c"), dim=4)
    with pytest.raises(KeyError):
        c.get(["missing"])


def test_cache_missing_keyerror_names_ids(tmp_path, rng):
    """The KeyError must be actionable: it names a sample of the missing
    raw ids, not just a count."""
    c = EmbeddingCache(str(tmp_path / "c"), dim=4)
    c.cache_records(["a", "b"], rng.normal(size=(2, 4)).astype(np.float16))
    with pytest.raises(KeyError) as ei:
        c.get(["a", "ghost-1", "b", "ghost-2"])
    msg = str(ei.value)
    assert "2 ids not cached" in msg
    assert "ghost-1" in msg and "ghost-2" in msg
    assert "a" not in msg.split("(e.g.")[1].split(")")[0].split(", ")
    # more than 5 missing: sampled, with an ellipsis marker
    with pytest.raises(KeyError) as ei:
        c.get([f"ghost-{i}" for i in range(9)])
    assert "..." in str(ei.value)


def test_cache_get_rows_rejects_out_of_range(tmp_path, rng):
    """get_rows must refuse rows outside [0, n): a stale plan carrying
    -1 missing-id sentinels used to wrap via fancy indexing and silently
    serve the LAST row's embedding (regression)."""
    c = EmbeddingCache(str(tmp_path / "c"), dim=4)
    v = rng.normal(size=(3, 4)).astype(np.float16)
    c.cache_records(["a", "b", "c"], v)
    with pytest.raises(IndexError, match="stale plan"):
        c.get_rows(np.array([0, -1, 2]))
    with pytest.raises(IndexError):
        c.get_rows(np.array([3]))
    # in-range rows (and the empty request) still serve
    np.testing.assert_allclose(c.get_rows(np.array([2, 0])),
                               v[[2, 0]], rtol=1e-3)
    assert c.get_rows(np.array([], np.int64)).shape == (0, 4)


def test_cache_append_is_append_only(tmp_path, rng):
    """cache_records must write O(delta) — the ids index file grows in
    place (same inode, +8 bytes/row) instead of being re-saved in full
    on every append (the old O(n²) layout)."""
    import os
    c = EmbeddingCache(str(tmp_path / "c"), dim=4)
    ids_path = os.path.join(str(tmp_path / "c"), "ids.bin")
    c.cache_records(["a", "b"], rng.normal(size=(2, 4)).astype(np.float16))
    st1 = os.stat(ids_path)
    c.cache_records(["c"], rng.normal(size=(1, 4)).astype(np.float16))
    st2 = os.stat(ids_path)
    assert st1.st_size == 2 * 8 and st2.st_size == 3 * 8
    assert st1.st_ino == st2.st_ino        # appended, not replaced


def test_cache_reopen_after_append(tmp_path, rng):
    """Append → reopen → append again → reopen: every committed row is
    served back, in insertion order, across sessions."""
    v1 = rng.normal(size=(3, 4)).astype(np.float16)
    v2 = rng.normal(size=(2, 4)).astype(np.float16)
    c = EmbeddingCache(str(tmp_path / "c"), dim=4)
    c.cache_records(["a", "b", "c"], v1)
    c2 = EmbeddingCache(str(tmp_path / "c"), dim=4)
    c2.cache_records(["d", "e"], v2)
    assert len(c2) == 5
    c3 = EmbeddingCache(str(tmp_path / "c"), dim=4)
    got = c3.get(["e", "a", "d"])
    np.testing.assert_allclose(got[0], v2[1], rtol=1e-3)
    np.testing.assert_allclose(got[1], v1[0], rtol=1e-3)
    np.testing.assert_allclose(got[2], v2[0], rtol=1e-3)
    np.testing.assert_allclose(c3.get_range(0, 5),
                               np.concatenate([v1, v2]), rtol=1e-3)


def test_cache_ignores_torn_trailing_bytes(tmp_path, rng):
    """A crash mid-append leaves trailing bytes past the committed meta
    count; reopen must truncate them so the next append can't misalign
    the ids/vectors row mapping."""
    import os
    c = EmbeddingCache(str(tmp_path / "c"), dim=4)
    v = rng.normal(size=(2, 4)).astype(np.float16)
    c.cache_records(["a", "b"], v)
    # simulate a crash: rows hit both files but meta.json was never replaced
    with open(os.path.join(str(tmp_path / "c"), "vectors.bin"), "ab") as f:
        f.write(b"\x01" * 5)
    with open(os.path.join(str(tmp_path / "c"), "ids.bin"), "ab") as f:
        f.write(b"\x02" * 11)
    c2 = EmbeddingCache(str(tmp_path / "c"), dim=4)
    assert len(c2) == 2
    w = rng.normal(size=(1, 4)).astype(np.float16)
    c2.cache_records(["z"], w)
    c3 = EmbeddingCache(str(tmp_path / "c"), dim=4)
    np.testing.assert_allclose(c3.get(["z"])[0], w[0], rtol=1e-3)
    np.testing.assert_allclose(c3.get(["b"])[0], v[1], rtol=1e-3)


def test_cache_migrates_legacy_ids_npy(tmp_path, rng):
    """Caches written by the old layout (full ids.npy re-save per append)
    open cleanly: ids.npy is converted once to the append-only ids.bin."""
    import json as _json
    import os
    from repro.data.table import stable_id_hash_array
    d = tmp_path / "legacy"
    os.makedirs(str(d))
    v = rng.normal(size=(3, 4)).astype(np.float16)
    with open(str(d / "vectors.bin"), "wb") as f:
        f.write(v.tobytes())
    np.save(str(d / "ids.npy"), stable_id_hash_array(["a", "b", "c"]))
    with open(str(d / "meta.json"), "w") as f:
        _json.dump({"dim": 4, "dtype": "float16", "n": 3}, f)
    c = EmbeddingCache(str(d), dim=4)
    assert len(c) == 3
    np.testing.assert_allclose(c.get(["b"])[0], v[1], rtol=1e-3)
    assert os.path.exists(str(d / "ids.bin"))
    c.cache_records(["d"], rng.normal(size=(1, 4)).astype(np.float16))
    c2 = EmbeddingCache(str(d), dim=4)
    assert len(c2) == 4 and c2.has(["d"]).tolist() == [True]


# -- neighbor sampler ------------------------------------------------------------

def test_csr_from_edges():
    src = np.asarray([0, 1, 2, 0], np.int32)
    dst = np.asarray([1, 2, 0, 2], np.int32)
    g = CSRGraph.from_edges(src, dst, 3)
    assert sorted(g.neighbors(2).tolist()) == [0, 1]
    assert g.degree(np.asarray([0, 1, 2])).tolist() == [1, 1, 2]


def test_sampler_shapes_and_membership():
    src, dst, comm = make_random_graph(200, 8, seed=1)
    g = CSRGraph.from_edges(src, dst, 200)
    s = NeighborSampler(g, (5, 3), seed=0)
    l0, l1, l2 = s.sample(np.arange(10))
    assert l0.shape == (10,) and l1.shape == (10, 5) and \
        l2.shape == (10, 5, 3)
    # sampled level-1 nodes are true neighbors (or self for isolated)
    for i in range(10):
        neigh = set(g.neighbors(i).tolist()) | {i}
        assert set(l1[i].tolist()) <= neigh


def test_sampler_isolated_self_loop():
    g = CSRGraph.from_edges(np.asarray([0], np.int32),
                            np.asarray([1], np.int32), 3)
    s = NeighborSampler(g, (4,))
    _, l1 = s.sample(np.asarray([2]))
    assert (l1 == 2).all()      # node 2 has no in-edges -> self loop


def test_sample_block_features(rng):
    src, dst, _ = make_random_graph(50, 4, seed=2)
    g = CSRGraph.from_edges(src, dst, 50)
    x = rng.normal(size=(50, 6)).astype(np.float32)
    s = NeighborSampler(g, (3, 2), seed=1)
    f0, f1, f2 = s.sample_block(x, np.arange(4))
    assert f0.shape == (4, 6) and f1.shape == (4, 3, 6) and \
        f2.shape == (4, 3, 2, 6)
