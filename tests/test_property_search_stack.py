"""Property-test harness for the search stack (FastResultHeapq +
FairSharder), pinned to brute-force oracles.

The check bodies are plain helpers shared by two entry points:

  * ``@given`` property tests — run when ``hypothesis`` is installed,
    skip individually otherwise (``tests/_hypothesis_shim.py``);
  * example-based grid tests — always run, covering ties, NaN, -inf,
    ``k > corpus size`` and permutation-invariance on a fixed grid.

Oracle semantics (see ``FastResultHeapq`` docstring): NaN and -inf
scores mean "never retrieve" — they sanitize to -inf and never surface
a doc id in any impl.  On finite score *ties* the impls may break
differently (heapq keeps the larger id, lax.top_k the earlier
candidate), so the oracle pins exact top-k *values* for every impl, plus
id validity (each returned id really has that score, no duplicates,
ids surface iff the slot value is above -inf); id-level equality is
additionally pinned whenever scores are unique.
"""

import itertools

import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.fair_sharding import FairSharder
from repro.core.result_heap import FastResultHeapq

# -- oracles ------------------------------------------------------------------


def _sanitize(scores: np.ndarray) -> np.ndarray:
    return np.where(np.isnan(scores), -np.inf, scores).astype(np.float32)


def _oracle_topk_vals(scores: np.ndarray, k: int) -> np.ndarray:
    """Brute-force: descending argsort of the sanitized full (Q, N)
    matrix, padded with -inf up to k (the heap's empty-slot value)."""
    s = _sanitize(scores)
    vals = -np.sort(-s, axis=1)[:, :k]
    if vals.shape[1] < k:
        pad = np.full((s.shape[0], k - vals.shape[1]), -np.inf, np.float32)
        vals = np.concatenate([vals, pad], axis=1)
    return vals


def _check_heap_vs_oracle(scores: np.ndarray, k: int, n_chunks: int,
                          impl: str, via_merge_arrays: bool = False):
    """Stream ``scores`` (Q, N) in ``n_chunks`` pieces through ``update``
    (or per-chunk ``merge_arrays`` of pre-reduced states) and compare
    against the brute-force oracle."""
    q, n = scores.shape
    heap = FastResultHeapq(q, k, impl=impl)
    edges = np.linspace(0, n, n_chunks + 1).astype(int)
    for lo, hi in zip(edges, edges[1:]):
        if lo == hi:
            continue
        ids = np.arange(lo, hi, dtype=np.int32)
        if via_merge_arrays:
            shard = FastResultHeapq(q, k, impl=impl)
            shard.update(scores[:, lo:hi], ids)
            heap.merge_arrays(*shard.finalize())
        else:
            heap.update(scores[:, lo:hi], ids)
    vals, ids = heap.finalize()
    np.testing.assert_array_equal(vals, _oracle_topk_vals(scores, k))
    s = _sanitize(scores)
    for qi in range(q):
        seen = set()
        for j in range(k):
            did = int(ids[qi, j])
            if did >= 0:
                assert did not in seen, "duplicate id surfaced"
                seen.add(did)
                assert s[qi, did] == vals[qi, j], \
                    "id does not point at its score"
                # "never retrieve": a surfaced id always has a score
                # above the -inf sentinel, in every impl
                assert not np.isneginf(vals[qi, j])
            else:
                # empty slot: value must be the -inf filler
                assert np.isneginf(vals[qi, j])
    # id-level oracle equality whenever scores are unique (no ties to
    # break): every impl must match stable descending argsort exactly
    if np.unique(s).size == s.size:
        order = np.argsort(-s, axis=1, kind="stable")[:, :k]
        kk = min(k, n)
        valid = ~np.isneginf(np.take_along_axis(s, order[:, :kk], 1))
        np.testing.assert_array_equal(
            np.where(valid, ids[:, :kk], order[:, :kk]), order[:, :kk])


def _check_merge_permutation_invariant(scores: np.ndarray, k: int,
                                       n_shards: int, impl: str,
                                       perm_seed: int):
    """Merging any permutation of per-shard (Q, k) states yields the
    same top-k values; identical ids too when scores are unique."""
    q, n = scores.shape
    edges = np.linspace(0, n, n_shards + 1).astype(int)
    states = []
    for lo, hi in zip(edges, edges[1:]):
        shard = FastResultHeapq(q, k, impl=impl)
        if hi > lo:
            shard.update(scores[:, lo:hi],
                         np.arange(lo, hi, dtype=np.int32))
        states.append(shard.finalize())
    rng = np.random.default_rng(perm_seed)
    results = []
    for _ in range(3):
        order = rng.permutation(len(states))
        merged = FastResultHeapq(q, k, impl=impl)
        for si in order:
            merged.merge_arrays(*states[si])
        results.append(merged.finalize())
    ref_vals, ref_ids = results[0]
    np.testing.assert_array_equal(ref_vals, _oracle_topk_vals(scores, k))
    unique = np.unique(_sanitize(scores)).size == scores.size
    for vals, ids in results[1:]:
        np.testing.assert_array_equal(vals, ref_vals)
        if unique:
            np.testing.assert_array_equal(ids, ref_ids)


def _make_scores(q: int, n: int, seed: int, mode: str) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if mode == "unique":
        # a shuffled arange: strictly distinct scores, exercises the
        # id-level stable-order oracle
        flat = rng.permutation(q * n).astype(np.float32)
        return flat.reshape(q, n)
    scores = rng.normal(size=(q, n)).astype(np.float32)
    if mode == "ties":
        scores = np.round(scores)            # heavy ties incl. +-0
    elif mode == "nan":
        scores[rng.random(size=scores.shape) < 0.15] = np.nan
    elif mode == "neginf":
        scores[rng.random(size=scores.shape) < 0.15] = -np.inf
    elif mode == "mixed":
        scores = np.round(scores * 2)
        scores[rng.random(size=scores.shape) < 0.1] = np.nan
        scores[rng.random(size=scores.shape) < 0.1] = -np.inf
    return scores


HEAP_MODES = ("unique", "ties", "nan", "neginf", "mixed")


# -- example-based grid (always runs) -----------------------------------------


@pytest.mark.parametrize("impl", ["python", "jax"])
@pytest.mark.parametrize("mode", HEAP_MODES)
def test_heap_grid_vs_oracle(impl, mode):
    for (q, n, k, chunks), via_merge in itertools.product(
            [(3, 40, 7, 4), (1, 5, 12, 2), (4, 17, 17, 3), (2, 8, 3, 1)],
            [False, True]):
        _check_heap_vs_oracle(_make_scores(q, n, seed=q * n + k, mode=mode),
                              k, chunks, impl, via_merge_arrays=via_merge)


@pytest.mark.parametrize("mode", ("unique", "mixed"))
def test_heap_grid_vs_oracle_pallas(mode):
    # pallas runs in interpret mode on CPU — keep the grid small
    _check_heap_vs_oracle(_make_scores(2, 20, seed=3, mode=mode), 5, 2,
                          "pallas")
    # k > streamed candidates: regression for the topk kernel re-picking
    # an already-selected position once the running max hits -inf and
    # re-emitting its real id (duplicate ids in the tail)
    _check_heap_vs_oracle(_make_scores(2, 12, seed=3, mode=mode), 15, 2,
                          "pallas")


@pytest.mark.parametrize("impl", ["python", "jax"])
@pytest.mark.parametrize("mode", ("unique", "ties"))
def test_merge_grid_permutation_invariant(impl, mode):
    for q, n, k, shards in [(3, 30, 6, 3), (2, 11, 4, 5), (1, 6, 9, 2)]:
        _check_merge_permutation_invariant(
            _make_scores(q, n, seed=n + k, mode=mode), k, shards, impl,
            perm_seed=17)


# -- hypothesis property tests (skip without hypothesis) ----------------------


@settings(max_examples=25, deadline=None)
@given(q=st.integers(1, 5), n=st.integers(1, 48), k=st.integers(1, 14),
       chunks=st.integers(1, 5), seed=st.integers(0, 10_000),
       mode=st.sampled_from(HEAP_MODES), impl=st.sampled_from(
           ["python", "jax"]),
       via_merge=st.booleans())
def test_property_heap_matches_oracle(q, n, k, chunks, seed, mode, impl,
                                      via_merge):
    """update/merge_arrays == brute-force argsort oracle for random
    matrices with ties, NaN, -inf, and k > corpus size."""
    _check_heap_vs_oracle(_make_scores(q, n, seed, mode), k, chunks, impl,
                          via_merge_arrays=via_merge)


@settings(max_examples=15, deadline=None)
@given(q=st.integers(1, 4), n=st.integers(1, 40), k=st.integers(1, 10),
       shards=st.integers(1, 6), seed=st.integers(0, 10_000),
       mode=st.sampled_from(("unique", "ties", "mixed")),
       impl=st.sampled_from(["python", "jax"]),
       perm_seed=st.integers(0, 10_000))
def test_property_merge_permutation_invariant(q, n, k, shards, seed, mode,
                                              impl, perm_seed):
    """Merging any permutation of shard states is order-invariant."""
    _check_merge_permutation_invariant(_make_scores(q, n, seed, mode), k,
                                       shards, impl, perm_seed)


# -- FairSharder --------------------------------------------------------------


def _check_sharder_invariants(n_workers: int, total: int,
                              throughput: np.ndarray,
                              min_share: float = 0.01):
    s = FairSharder(n_workers, min_share=min_share)
    s.throughput = np.asarray(throughput, np.float64)
    sizes = s.shares(total)
    assert sum(sizes) == total
    assert all(sz >= 0 for sz in sizes)
    bounds = s.bounds(total)
    assert bounds[0][0] == 0 and bounds[-1][1] == total
    for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
        assert a1 == b0, "bounds must be contiguous"
        assert a1 >= a0, "bounds must be non-negative ranges"
    # min_share holds after renormalization: each worker's fraction is at
    # least min_share / (1 + n*min_share) (minus 1 item of float slop)
    floor_items = int(np.floor(
        total * min_share / (1 + n_workers * min_share))) - 1
    assert all(sz >= max(0, floor_items) for sz in sizes)


def _check_straggler_monotone(n_workers: int, total: int, rounds: int,
                              slow_rate: float, fast_rate: float):
    """Under repeated full rounds where worker 0 observes ``slow_rate``
    items/s and the rest ``fast_rate``, worker 0's share never grows."""
    s = FairSharder(n_workers)
    prev = None
    for _ in range(rounds):
        shares = s.shares(total)
        if prev is not None:
            assert shares[0] <= prev, (shares, prev)
        prev = shares[0]
        for w in range(n_workers):
            items = max(shares[w], 1)
            rate = slow_rate if w == 0 else fast_rate
            s.update(w, items, items / rate)


def test_sharder_grid_invariants():
    rng = np.random.default_rng(0)
    for n, total in [(1, 0), (1, 17), (3, 100), (4, 103), (8, 3),
                     (5, 1), (6, 1_000_003), (2, 2)]:
        for tp in (np.ones(n), rng.uniform(0.01, 100.0, size=n),
                   np.full(n, 1e-12)):
            _check_sharder_invariants(n, total, tp)


def test_sharder_grid_straggler_monotone():
    for n, total, slow, fast in [(2, 1000, 0.2, 5.0), (4, 500, 0.5, 2.0),
                                 (3, 10_000, 0.01, 1.0)]:
        _check_straggler_monotone(n, total, rounds=8, slow_rate=slow,
                                  fast_rate=fast)


def test_sharder_total_smaller_than_workers_regression():
    """total_items < n_workers: shares are single items handed to the
    fastest workers, bounds stay contiguous, nothing goes negative."""
    s = FairSharder(8)
    s.update(3, 100, 1.0)                    # worker 3 looks fastest ...
    for w in range(8):
        if w != 3:
            s.update(w, 10, 1.0)             # ... once the round commits
    sizes = s.shares(3)
    assert sum(sizes) == 3 and all(sz >= 0 for sz in sizes)
    assert sizes[3] >= 1                     # fastest got one of the 3
    bounds = s.bounds(3)
    assert bounds[0][0] == 0 and bounds[-1][1] == 3
    for (_, a1), (b0, _) in zip(bounds, bounds[1:]):
        assert a1 == b0


def test_sharder_zero_items_reports_complete_round():
    """An empty-shard worker (items=0) must count toward round
    completion without polluting the EMA."""
    s = FairSharder(2)
    s.update(0, 100, 1.0)
    s.update(1, 0, 0.0)                      # empty shard
    assert s.throughput[0] != 1.0            # round committed
    assert s.throughput[1] == 1.0            # no signal, EMA untouched


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 16), total=st.integers(0, 1_000_000),
       seed=st.integers(0, 10_000))
def test_property_sharder_invariants(n, total, seed):
    """Shares sum to total, bounds are contiguous/non-negative, and
    min_share is respected, for arbitrary throughput states."""
    rng = np.random.default_rng(seed)
    _check_sharder_invariants(n, total, rng.uniform(1e-9, 1e6, size=n))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 8), total=st.integers(100, 100_000),
       slow=st.floats(0.01, 0.9), fast=st.floats(1.0, 50.0),
       rounds=st.integers(2, 10))
def test_property_straggler_share_monotone(n, total, slow, fast, rounds):
    """A straggler's share is monotonically non-increasing over repeated
    slow rounds."""
    _check_straggler_monotone(n, total, rounds, slow, fast)
