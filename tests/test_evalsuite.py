"""Multi-dataset eval suite: per-dataset + combined tables, with the
combined pass pinned bitwise to an eagerly merged union oracle."""

import json
import os

import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.evaluator import RetrievalEvaluator, format_metrics_table
from repro.data.synthetic import make_retrieval_dataset
from repro.data.tokenizer import HashTokenizer


@pytest.fixture(scope="module")
def suite_data(tmp_path_factory):
    """Two synthetic datasets with disjoint (prefixed) id spaces."""
    root = tmp_path_factory.mktemp("suite")
    out = {}
    for i in range(2):
        q, c, r = make_retrieval_dataset(
            str(root / f"d{i}"), n_queries=12, n_docs=48, n_topics=6,
            seed=20 + i, id_prefix=f"d{i}-")
        out[f"d{i}"] = {"queries": q, "corpus": c, "qrels": r}
    return out


@pytest.fixture()
def evaluator(tiny_retriever, tiny_params):
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    return RetrievalEvaluator(
        EvaluationArguments(topk=10, metrics=("ndcg@10", "mrr@10")),
        tiny_retriever, coll, tiny_params)


def test_suite_per_dataset_rows_match_individual_eval(evaluator,
                                                      suite_data):
    results = evaluator.evaluate_suite(suite_data)
    assert set(results) == {"d0", "d1", "combined"}
    for name, sc in suite_data.items():
        solo = evaluator.evaluate(sc["queries"], sc["corpus"], sc["qrels"])
        assert results[name] == solo


def test_suite_combined_equals_eager_union_oracle(evaluator, suite_data):
    """The ConcatView combined pass == evaluating eagerly merged dicts."""
    results = evaluator.evaluate_suite(suite_data)
    union = {k: {} for k in ("queries", "corpus", "qrels")}
    for sc in suite_data.values():
        for k in union:
            union[k].update(sc[k])
    oracle = evaluator.evaluate(union["queries"], union["corpus"],
                                union["qrels"])
    assert results["combined"] == oracle


def test_suite_combined_rankings_bitwise(evaluator, suite_data):
    """Stronger than metrics: the combined search itself is bitwise equal
    to searching the eagerly merged union corpus."""
    from repro.data.views import ConcatView, as_view
    q_union, c_union = {}, {}
    for sc in suite_data.values():
        q_union.update(sc["queries"])
        c_union.update(sc["corpus"])
    qh_ref, ids_ref, s_ref = evaluator.search(q_union, c_union)
    q_view = ConcatView(*[as_view(sc["queries"])
                          for sc in suite_data.values()])
    c_view = ConcatView(*[as_view(sc["corpus"])
                          for sc in suite_data.values()])
    qh, ids, s = evaluator.search(q_view, c_view)
    np.testing.assert_array_equal(qh, qh_ref)
    np.testing.assert_array_equal(ids, ids_ref)
    np.testing.assert_array_equal(s, s_ref)


def test_suite_rejects_duplicate_ids(evaluator, tmp_path):
    q, c, r = make_retrieval_dataset(str(tmp_path / "dup"), n_queries=6,
                                     n_docs=24, n_topics=4)
    scenarios = {"a": {"queries": q, "corpus": c, "qrels": r},
                 "b": {"queries": dict(q), "corpus": dict(c),
                       "qrels": dict(r)}}
    with pytest.raises(ValueError, match="duplicate"):
        evaluator.evaluate_suite(scenarios)
    # per-dataset still fine when the combined pass is off
    results = evaluator.evaluate_suite(scenarios, combined=False)
    assert set(results) == {"a", "b"}


def test_suite_writes_tables(evaluator, suite_data, tmp_path):
    out = str(tmp_path / "results")
    results = evaluator.evaluate_suite(suite_data, out_dir=out,
                                       suite_name="mysuite")
    payload = json.load(open(os.path.join(out, "mysuite.json")))
    assert payload["suite"] == "mysuite"
    assert payload["datasets"] == ["d0", "d1"]
    assert payload["results"] == results
    md = open(os.path.join(out, "mysuite.md")).read()
    assert md == format_metrics_table(results)
    for name in ("d0", "d1", "combined"):
        assert f"| {name}" in md
    for m, val in results["combined"].items():
        assert m in md
        assert f"{val:.4f}" in md


def test_suite_with_materialized_views(tiny_retriever, tiny_params,
                                       suite_data, tmp_path):
    """The evalsuite launcher path: MaterializedQRel-backed views and
    hash-keyed qrels give the same tables as plain dicts."""
    from repro.launch.evalsuite import build_scenarios
    root = tmp_path / "mq"
    dirs = []
    for i, (name, sc) in enumerate(suite_data.items()):
        d = root / name
        make_retrieval_dataset(str(d), n_queries=12, n_docs=48,
                               n_topics=6, seed=20 + i,
                               id_prefix=f"d{i}-")
        dirs.append(str(d))
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    ev = RetrievalEvaluator(
        EvaluationArguments(topk=10, metrics=("ndcg@10", "mrr@10")),
        tiny_retriever, coll, tiny_params)
    via_views = ev.evaluate_suite(
        build_scenarios(dirs, str(tmp_path / "cache")))
    via_dicts = ev.evaluate_suite(suite_data)
    assert via_views == via_dicts


@pytest.mark.distributed
def test_suite_sharded_equals_single(tiny_retriever, tiny_params,
                                     suite_data, tmp_path):
    """W=2 simulated workers produce identical tables, worker 0 writes."""
    from repro.launch.distributed import SimulatedCluster
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    args = EvaluationArguments(topk=10, metrics=("ndcg@10", "mrr@10"))
    single = RetrievalEvaluator(args, tiny_retriever, coll, tiny_params)
    ref = single.evaluate_suite(suite_data)

    out = str(tmp_path / "w2")
    cluster = SimulatedCluster(2)
    evs = [RetrievalEvaluator(args, tiny_retriever, coll, tiny_params,
                              process_index=rank, process_count=2,
                              gather=cluster.gather,
                              sharder=cluster.sharder)
           for rank in range(2)]
    outs = cluster.run(lambda rank: evs[rank].evaluate_suite(
        suite_data, out_dir=out, suite_name="w2"))
    for res in outs:
        assert res == ref
    assert json.load(open(os.path.join(out, "w2.json")))["results"] == ref


def test_evalsuite_cli_smoke(tmp_path):
    """The launcher end to end on a tiny synthetic suite."""
    from repro.launch import evalsuite
    results = evalsuite.main([
        "--smoke", "--data-root", str(tmp_path / "data"),
        "--out-dir", str(tmp_path / "results"),
        "--n-queries", "6", "--n-docs", "24", "--topk", "5"])
    assert set(results) == {"d0", "d1", "combined"}
    assert os.path.exists(str(tmp_path / "results" / "evalsuite.json"))
