import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelArguments
from repro.models.encoder import (ENCODER_REGISTRY, DefaultEncoder,
                                  EncoderWithInstruction, PretrainedEncoder,
                                  get_encoder)
from repro.models.retriever import (RETRIEVER_REGISTRY, BiEncoderRetriever,
                                    GradedBiEncoderRetriever)
from repro.models.transformer import LMConfig


def test_encoder_registry():
    for alias in ("lm", "encoder_with_inst", "encoder_mean_pool", "gnn"):
        assert alias in ENCODER_REGISTRY


def test_custom_encoder_autoregisters(tiny_lm_cfg):
    class MyEncoder(DefaultEncoder):
        _alias = "my_test_encoder"

        def format_query(self, text):
            return "Q: " + text

    enc = get_encoder("my_test_encoder", tiny_lm_cfg)
    assert enc.format_query("hi") == "Q: hi"
    # selectable via ModelArguments (paper: --encoder_class=...)
    retr = BiEncoderRetriever.from_model_args(
        ModelArguments(encoder_class="my_test_encoder"), tiny_lm_cfg)
    assert retr.format_query("x") == "Q: x"


def test_instruction_encoder_formats(tiny_lm_cfg):
    enc = EncoderWithInstruction(tiny_lm_cfg)
    assert enc.format_query("hello").startswith("Instruct:")
    assert enc.format_passage("doc", "title") == "title doc"


def test_user_provided_encoder_object(tiny_lm_cfg):
    """Paper: arbitrary objects with the encoder duck-type work."""

    class Bag(PretrainedEncoder):
        def __init__(self, cfg):
            self.cfg = cfg

        def init_params(self, rng):
            return {"emb": jax.random.normal(
                rng, (self.cfg.vocab_size, 16))}

        def abstract_params(self):
            return {"emb": jax.ShapeDtypeStruct(
                (self.cfg.vocab_size, 16), jnp.float32)}

        def param_logical_axes(self):
            return {"emb": (None, None)}

        def encode(self, params, batch, ctx=None):
            e = jnp.take(params["emb"], batch["tokens"], axis=0)
            m = batch["mask"][..., None].astype(jnp.float32)
            v = (e * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
            return v / jnp.clip(jnp.linalg.norm(v, axis=-1,
                                                keepdims=True), 1e-9)

    retr = BiEncoderRetriever.from_model_args(
        ModelArguments(), tiny_lm_cfg, encoder=Bag(tiny_lm_cfg))
    params = retr.init_params(jax.random.key(0))
    batch = {
        "query": {"tokens": jnp.ones((2, 4), jnp.int32),
                  "mask": jnp.ones((2, 4), jnp.int32)},
        "passage": {"tokens": jnp.ones((4, 4), jnp.int32),
                    "mask": jnp.ones((4, 4), jnp.int32)},
    }
    loss, metrics = retr.forward(params, batch)
    assert np.isfinite(float(loss))


def test_biencoder_learns_alignment(tiny_retriever, tiny_params):
    """Perfectly aligned embeddings give ~0 loss & accuracy 1."""
    # identical query/passage tokens -> identical embeddings -> diagonal wins
    toks = jax.random.randint(jax.random.key(0), (4, 6), 3, 257)
    batch = {"query": {"tokens": toks, "mask": jnp.ones_like(toks)},
             "passage": {"tokens": toks, "mask": jnp.ones_like(toks)}}
    loss, metrics = tiny_retriever.forward(tiny_params, batch)
    assert float(metrics["in_batch_accuracy"]) == 1.0


def test_graded_retriever_group_scores(tiny_lm_cfg):
    retr = GradedBiEncoderRetriever(DefaultEncoder(tiny_lm_cfg), "kl")
    params = retr.init_params(jax.random.key(0))
    b, g, s = 3, 4, 6
    q = jax.random.randint(jax.random.key(1), (b, s), 3, 257)
    p = jax.random.randint(jax.random.key(2), (b * g, s), 3, 257)
    labels = jnp.asarray(np.random.default_rng(0).integers(
        0, 4, (b, g)).astype(np.float32))
    batch = {"query": {"tokens": q, "mask": jnp.ones_like(q)},
             "passage": {"tokens": p, "mask": jnp.ones_like(p)},
             "labels": labels}
    loss, _ = retr.forward(params, batch)
    assert np.isfinite(float(loss))


def test_moe_encoder_aux_loss_flows():
    cfg = LMConfig(name="moe", n_layers=2, d_model=32, n_heads=4,
                   n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=101,
                   moe=True, n_experts=4, top_k=2, moe_d_ff=32,
                   dtype=jnp.float32, remat=False)
    retr = BiEncoderRetriever(DefaultEncoder(cfg), "infonce",
                              aux_loss_weight=0.05)
    params = retr.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (3, 6), 3, 101)
    batch = {"query": {"tokens": toks, "mask": jnp.ones_like(toks)},
             "passage": {"tokens": toks, "mask": jnp.ones_like(toks)}}
    loss, metrics = retr.forward(params, batch)
    assert "moe_aux_loss" in metrics
    assert float(loss) > float(metrics["contrastive_loss"])


def test_retriever_registry():
    assert "biencoder" in RETRIEVER_REGISTRY
    assert "graded_biencoder" in RETRIEVER_REGISTRY
