"""Live corpus mutation: the generation-versioned embedding cache
(tombstones, last-write-wins re-cache, snapshot pinning, crash-safe
compaction) and the snapshot-pinned search stack above it.

The centerpiece is the consistency oracle: a writer thread mutates the
cache (adds / updates / deletes / one online compaction) while searches
run across the ``score_impl`` × W ∈ {1, 2} × {flat, ivf} matrix — every
search result must equal a fresh evaluator run over a frozen copy of
the exact generation it pinned (ids bitwise; scores bitwise at W = 1
where the code path is identical, allclose across worker counts per the
repo's cross-impl convention).
"""

import threading
import time

import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.core.evaluator import (IVFPreparedCorpus, PreparedCorpus,
                                  RetrievalEvaluator)
from repro.core.fair_sharding import FairSharder, GenerationMismatch
from repro.core.serving import ClusterServeBackend, ServeFrontend
from repro.data.table import stable_id_hash
from repro.data.tokenizer import HashTokenizer
from repro.index.ivf import IVFIndex, cluster_order, corpus_digest
from repro.launch.distributed import SimulatedCluster


def _fill(cache, n, seed=0, prefix="d"):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, cache.dim)).astype(np.float32)
    ids = [f"{prefix}{i}" for i in range(n)]
    cache.cache_records(ids, vecs)
    return ids, vecs


# -- cache log semantics ------------------------------------------------------


def test_recache_is_last_write_wins(tmp_path):
    """Re-caching an id appends a new version that wins every later
    lookup — get, get_range/get_rows via row_plan, and snapshots (the
    old duplicate-id path served the stale first row)."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    ids, vecs = _fill(cache, 6)
    new = np.full((1, 8), 7.0, np.float32)
    cache.cache_records(["d2"], new)
    assert len(cache) == 7                     # log: physical append
    assert cache.n_live == 6                   # live: d2 superseded
    np.testing.assert_allclose(cache.get(["d2"]), new, atol=1e-2)
    # the resolved row plan serves the NEW row for d2, old rows for rest
    hashes = np.asarray([stable_id_hash(i) for i in ids])
    kind, rows = cache.row_plan(hashes)
    assert kind == "rows"
    got = cache.get_rows(rows)
    np.testing.assert_allclose(got[2], new[0], atol=1e-2)
    np.testing.assert_allclose(got[0], vecs[0], atol=1e-2)
    snap = cache.snapshot()
    np.testing.assert_allclose(snap.get(["d2"]), new, atol=1e-2)
    snap.close()


def test_delete_tombstone_then_readd_resurrects(tmp_path):
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    ids, vecs = _fill(cache, 5)
    g0 = cache.generation
    cache.delete_records(["d1", "d3"])
    assert cache.generation == g0 + 1
    assert cache.n_live == 3
    assert not cache.has(["d1"])[0]
    with pytest.raises(KeyError, match="d1"):
        cache.get(["d1"])
    assert sorted(cache.live_ids().tolist()) == sorted(
        stable_id_hash(i) for i in ("d0", "d2", "d4"))
    # re-add after delete resurrects with the new vector
    back = np.full((1, 8), 3.0, np.float32)
    cache.cache_records(["d1"], back)
    assert cache.has(["d1"])[0]
    np.testing.assert_allclose(cache.get(["d1"]), back, atol=1e-2)
    assert cache.n_live == 4
    # deleting a never-cached id is a committed no-op tombstone
    g = cache.generation
    cache.delete_records(["ghost"])
    assert cache.generation == g + 1
    assert cache.n_live == 4


def test_snapshot_pins_generation_across_mutations(tmp_path):
    """A pinned reader never sees rows from later generations or
    resurrected tombstones — the zero-downtime invariant."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    ids, vecs = _fill(cache, 6)
    snap = cache.snapshot()
    before_ids = snap.ids.copy()
    before = snap.get_range(0, snap.n_live).copy()
    # mutate underneath: delete, update, add
    cache.delete_records(["d0"])
    cache.cache_records(["d3"], np.full((1, 8), 9.0, np.float32))
    cache.cache_records(["new0"], np.full((1, 8), 4.0, np.float32))
    np.testing.assert_array_equal(snap.ids, before_ids)
    np.testing.assert_array_equal(snap.get_range(0, snap.n_live), before)
    assert snap.has(["d0"])[0]                 # deletion not visible
    assert not snap.has(["new0"])[0]           # later add not visible
    np.testing.assert_allclose(snap.get(["d3"]), vecs[3:4], atol=1e-2)
    # a fresh snapshot sees all three mutations
    live = cache.snapshot()
    assert not live.has(["d0"])[0]
    assert live.has(["new0"])[0]
    np.testing.assert_allclose(
        live.get(["d3"]), np.full((1, 8), 9.0), atol=1e-2)
    snap.close()
    live.close()


def test_snapshot_resolves_past_generations(tmp_path):
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    _fill(cache, 4)
    g1 = cache.generation
    cache.delete_records(["d2"])
    cache.cache_records(["d9"], np.ones((1, 8), np.float32))
    old = cache.snapshot(g1)
    assert old.generation == g1
    assert old.has(["d2"])[0] and not old.has(["d9"])[0]
    with pytest.raises(KeyError):
        cache.snapshot(g1 + 1000)
    old.close()


def test_compaction_preserves_views_and_retires_old_epoch(tmp_path):
    """compact() rewrites live rows into a new epoch: the logical
    content is unchanged, pinned readers keep streaming the retired
    epoch's files until the last pin drops, and a reopen from disk sees
    exactly the compacted state."""
    import os
    path = str(tmp_path / "c")
    cache = EmbeddingCache(path, dim=8)
    ids, vecs = _fill(cache, 10)
    cache.delete_records(["d4", "d7"])
    cache.cache_records(["d1"], np.full((1, 8), 5.0, np.float32))
    pinned = cache.snapshot()
    want_ids = pinned.ids.copy()
    want = pinned.get_range(0, pinned.n_live).copy()

    stats = cache.compact()
    assert cache.epoch == 1
    assert stats["rows_after"] == 8
    assert stats["dropped"] == 3               # 1 superseded + 2 deleted
    # generation unchanged: compaction moves bytes, not logical content
    assert cache.generation == pinned.generation
    live = cache.snapshot()
    order = np.argsort(want_ids)
    order2 = np.argsort(live.ids)
    np.testing.assert_array_equal(live.ids[order2], want_ids[order])
    np.testing.assert_array_equal(
        live.get_rows(order2), want[order])
    # the pinned epoch-0 reader still serves its exact view
    np.testing.assert_array_equal(pinned.get_range(0, pinned.n_live),
                                  want)
    assert os.path.exists(os.path.join(path, "vectors.bin"))
    pinned.close()                             # last pin: retire epoch 0
    assert not os.path.exists(os.path.join(path, "vectors.bin"))
    live.close()

    reopened = EmbeddingCache(path, dim=8)
    assert reopened.epoch == 1
    assert reopened.n_live == 8
    np.testing.assert_allclose(
        np.asarray(reopened.get(["d1"])), np.full((1, 8), 5.0),
        atol=1e-2)
    assert not reopened.has(["d4"])[0]


def test_compact_into_ivf_cluster_order(tmp_path):
    """compact(order=cluster_order(...)) lays live rows out
    cluster-contiguously: the compacted scan replays the permuted rows
    and every id still maps to its own vector."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    ids, vecs = _fill(cache, 32)
    cache.delete_records(["d3"])
    snap = cache.snapshot()
    order = cluster_order(
        lambda lo, hi: snap.get_range(lo, hi).astype(np.float32),
        snap.n_live, 4, seed=0, train_steps=8, train_batch=16)
    want_ids = snap.ids[order].copy()
    want = snap.get_rows(order).copy()
    snap.close()
    cache.compact(order=order)
    live = cache.snapshot()
    np.testing.assert_array_equal(live.ids, want_ids)
    np.testing.assert_array_equal(live.get_range(0, live.n_live), want)
    live.close()
    with pytest.raises(ValueError, match="permutation"):
        cache.compact(order=np.zeros(cache.n_live, np.int64))


def test_cache_records_validation_names_positions(tmp_path):
    cache = EmbeddingCache(str(tmp_path / "c"), dim=4)
    good = np.ones((3, 4), np.float32)
    with pytest.raises(ValueError, match="length mismatch"):
        cache.cache_records(["a", "b"], good)
    with pytest.raises(ValueError, match=r"\(n, 4\)"):
        cache.cache_records(["a"], np.ones((1, 5), np.float32))
    bad = good.copy()
    bad[1, 2] = np.nan
    with pytest.raises(ValueError, match=r"positions \[1\]"):
        cache.cache_records(["a", "b", "c"], bad)
    bad = good.copy()
    bad[0, 0] = np.inf
    bad[2, 3] = -np.inf
    with pytest.raises(ValueError, match=r"positions \[0, 2\]"):
        cache.cache_records(["a", "b", "c"], bad)
    # float16 cast overflow is caught too, naming the overflowing row
    big = good.copy()
    big[2] = 1e30
    with pytest.raises(ValueError, match=r"positions \[2\]"):
        cache.cache_records(["a", "b", "c"], big)
    assert len(cache) == 0                     # nothing committed


# -- IVF digest invalidation (satellite: generation in the digest key) --------


def test_corpus_digest_folds_in_generation():
    hashes = np.arange(5, dtype=np.int64)
    base = corpus_digest(hashes)
    g1 = corpus_digest(hashes, generation=(3, 0))
    g2 = corpus_digest(hashes, generation=(4, 0))
    e2 = corpus_digest(hashes, generation=(4, 1))
    assert len({base, g1, g2, e2}) == 4
    assert corpus_digest(hashes, generation=3) == g1


def test_post_mutation_ivf_load_returns_none_then_rebuilds(tmp_path):
    """A persisted index keyed to generation g must not load for g+1:
    the deleted doc would otherwise survive in the permutation."""
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(20, 8)).astype(np.float32)
    index = IVFIndex.build(lambda lo, hi: vecs[lo:hi], 20, 4,
                           train_steps=4, train_batch=8)
    hashes = np.arange(20, dtype=np.int64)
    d = str(tmp_path / "ivf")
    dig1 = corpus_digest(hashes, generation=(5, 0))
    index.save(d, digest=dig1)
    assert IVFIndex.load(d, expect_digest=dig1) is not None
    dig2 = corpus_digest(hashes, generation=(6, 0))
    assert IVFIndex.load(d, expect_digest=dig2) is None


# -- generation agreement in the fair sharder ---------------------------------


def test_generation_mismatch_does_not_consume_the_round():
    sharder = FairSharder(2)
    r0, _ = sharder.acquire(0, 100, generation=(5, 0))
    assert r0 == 0
    with pytest.raises(GenerationMismatch) as ei:
        sharder.acquire(1, 100, generation=(6, 0))
    assert ei.value.agreed == (5, 0)
    assert ei.value.mine == (6, 0)
    assert ei.value.round_no == 0
    # the round was rolled back: re-acquiring at the agreed key works
    r1, bounds = sharder.acquire(1, 100, generation=(5, 0))
    assert r1 == 0
    sharder.update(0, 50, 0.1, round_no=0)
    sharder.update(1, 50, 0.1, round_no=0)
    # round committed; the next round agrees on a fresh key
    r, _ = sharder.acquire(0, 100, generation=(6, 0))
    assert r == 1
    r, _ = sharder.acquire(1, 100, generation=(6, 0))
    assert r == 1


def test_generation_agreement_ignored_when_unpinned():
    sharder = FairSharder(2)
    sharder.acquire(0, 10)
    sharder.acquire(1, 10, generation=(1, 0))  # first *keyed* acquirer
    sharder.update(0, 5, 0.1, round_no=0)
    sharder.update(1, 5, 0.1, round_no=0)


# -- the consistency oracle ---------------------------------------------------


_ORACLE_DIM = 32


@pytest.fixture(scope="module")
def oracle_env(tiny_retriever, tiny_params, retrieval_data):
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))

    def make(score_impl, index_impl, rank=0, world=1, gather=None,
             sharder=None):
        return RetrievalEvaluator(
            EvaluationArguments(topk=5, encode_batch_size=16,
                                score_impl=score_impl,
                                index_impl=index_impl,
                                ivf_nclusters=4, ivf_nprobe=2,
                                ivf_train_steps=6, ivf_train_batch=16,
                                serve_max_batch=8, serve_max_wait_ms=2.0),
            tiny_retriever, coll, tiny_params,
            process_index=rank, process_count=world,
            gather=gather, sharder=sharder)

    corpus = dict(list(retrieval_data["corpus"].items())[:48])
    queries = list(retrieval_data["queries"].values())[:6]
    return {"make": make, "corpus": corpus, "queries": queries}


def _frozen_reference(ref_ev, index_impl, snap_ids, snap_vecs, texts,
                      topk):
    """A fresh search over a frozen copy of the pinned generation —
    same row order, same build knobs, so the index (and therefore the
    ranking) is reproduced exactly."""
    n = len(snap_ids)
    a = ref_ev.args
    if index_impl == "ivf" and n:
        idx = IVFIndex.build(
            lambda lo, hi: snap_vecs[lo:hi].astype(np.float32), n,
            int(min(a.ivf_nclusters, n)), seed=a.ivf_seed,
            train_steps=a.ivf_train_steps, train_batch=a.ivf_train_batch)
        prepared = IVFPreparedCorpus(
            snap_ids, n, lambda rows: snap_vecs[rows].astype(np.float32),
            idx, a.ivf_nprobe)
    else:
        prepared = PreparedCorpus(
            snap_ids, n,
            lambda lo, hi: snap_vecs[lo:hi].astype(np.float32))
    return ref_ev.search_texts(texts, prepared, topk, min_batch_dim=1)


class _Writer:
    """Background mutator: adds, updates, deletes, and one online
    compaction, with every committed generation's mutation recorded."""

    def __init__(self, cache, ev, corpus):
        self.cache = cache
        self.ev = ev
        self.texts = list(corpus.values())
        self.stop = threading.Event()
        self.error = None
        self.ops = 0
        self.thread = threading.Thread(target=self._run,
                                       name="mutation-writer")

    def _run(self):
        try:
            i = 0
            while not self.stop.is_set():
                emb = np.asarray(self.ev._encode_texts(
                    [f"breaking news item {i}"], False))
                self.cache.cache_records([f"live{i}"], emb)
                emb = np.asarray(self.ev._encode_texts(
                    [self.texts[i % len(self.texts)] + f" v{i}"], False))
                self.cache.cache_records([f"doc{i % len(self.texts)}"],
                                         emb)
                if i % 2 == 1:
                    self.cache.delete_records([f"live{i - 1}"])
                if i == 2:
                    self.cache.compact()
                self.ops += 1
                i += 1
                time.sleep(0.002)
        except BaseException as exc:      # noqa: BLE001 — re-raised below
            self.error = exc

    def __enter__(self):
        self.thread.start()
        return self

    def __exit__(self, *exc):
        self.stop.set()
        self.thread.join()
        if self.error is not None:
            raise self.error


@pytest.mark.parametrize("world", (1, 2))
@pytest.mark.parametrize("index_impl", ("flat", "ivf"))
@pytest.mark.parametrize("score_impl", ("numpy", "jax", "pallas_fused"))
def test_search_under_concurrent_mutation_matches_frozen_oracle(
        oracle_env, tmp_path, score_impl, index_impl, world):
    """While a writer thread mutates the cache, every search must equal
    a fresh run over a frozen copy of the generation it pinned — proof
    that no search ever reads a torn mix of generations."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=_ORACLE_DIM)
    ref_ev = oracle_env["make"](score_impl, index_impl)
    # seed the cache with the corpus (one committed generation)
    cv = ref_ev._corpus_view(oracle_env["corpus"])
    ref_ev.encode_corpus(np.asarray(cv.id_hashes), cv.texts(), cache)

    if world == 1:
        ev = oracle_env["make"](score_impl, index_impl)
        cluster = None

        def one_search(texts, topk):
            prepared = ev.prepare_cache_corpus(cache)
            try:
                out = ev.search_texts(texts, prepared, topk,
                                      min_batch_dim=1)
                snap = prepared.snapshot
                frozen = (snap.ids.copy(),
                          snap.get_range(0, snap.n_live).copy())
            finally:
                prepared.close()
            return out, frozen
    else:
        cluster = SimulatedCluster(world)
        evs = [oracle_env["make"](score_impl, index_impl, rank, world,
                                  cluster.gather, cluster.sharder)
               for rank in range(world)]
        backend = ClusterServeBackend(evs, cluster, {}, live_cache=cache)

        def one_search(texts, topk):
            out = backend.run(texts, topk)
            snap = backend.prepared[0].snapshot
            frozen = (snap.ids.copy(),
                      snap.get_range(0, snap.n_live).copy())
            return out, frozen

    texts = oracle_env["queries"]
    results = []
    with _Writer(cache, ref_ev, oracle_env["corpus"]) as writer:
        deadline = time.monotonic() + 30.0
        while len(results) < 4 and time.monotonic() < deadline:
            results.append(one_search(texts, 5))
            # make sure generations actually advance between searches
            while (writer.ops < 2 * len(results)
                   and time.monotonic() < deadline
                   and writer.error is None):
                time.sleep(0.002)
    assert len(results) >= 2
    if world > 1:
        backend.close()

    generations = set()
    for out, (snap_ids, snap_vecs) in results:
        ids, vals = out
        generations.add((len(snap_ids),
                         hash(snap_ids.tobytes())))
        ref_ids, ref_vals = _frozen_reference(
            ref_ev, index_impl, snap_ids, snap_vecs, texts, 5)
        np.testing.assert_array_equal(ids, ref_ids)
        if world == 1:
            # identical code path over identical bytes: bitwise
            np.testing.assert_array_equal(vals, ref_vals)
        else:
            np.testing.assert_allclose(vals, ref_vals, rtol=1e-5,
                                       atol=1e-6)
    # the oracle exercised more than one pinned generation
    assert len(generations) >= 2, generations


# -- live serve frontend ------------------------------------------------------


def test_live_frontend_swaps_generations_between_microbatches(
        oracle_env, tmp_path):
    """ServeFrontend(live=True): requests keep resolving while the cache
    mutates and compacts; new documents become searchable."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=_ORACLE_DIM)
    ev = oracle_env["make"]("numpy", "flat")
    fe = ServeFrontend.from_evaluator(ev, oracle_env["corpus"], cache,
                                      live=True, max_wait_ms=1.0)
    try:
        q = oracle_env["queries"][0]
        ids0, _ = fe.search(q, timeout=60)
        assert ids0.shape == (1, 5)
        # mutate: add a doc engineered to win for its own text
        emb = np.asarray(ev._encode_texts(["zzz unique marker text"],
                                          False))
        cache.cache_records(["fresh-doc"], emb)
        cache.compact()
        ids1, _ = fe.search("zzz unique marker text", timeout=60)
        assert stable_id_hash("fresh-doc") in ids1[0]
        # delete it; the next request must not surface it
        cache.delete_records(["fresh-doc"])
        ids2, _ = fe.search("zzz unique marker text", timeout=60)
        assert stable_id_hash("fresh-doc") not in ids2[0]
    finally:
        fe.close()


def test_live_requires_cache():
    with pytest.raises(ValueError, match="cache"):
        ServeFrontend.from_evaluator(object(), {}, None, live=True)
