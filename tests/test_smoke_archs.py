"""Per-architecture smoke tests (deliverable f): every assigned arch, every
shape, one REDUCED-config step on CPU — output shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch
from repro.training.optimizer import OptimizerConfig, make_optimizer

CELLS = []
for _name in ARCH_NAMES:
    _arch = get_arch(_name)
    for _shape in _arch.shape_names():
        CELLS.append((_name, _shape))


def _init_params(arch, shape):
    if arch.family == "lm":
        from repro.models import transformer as T
        return T.init_params(arch.cfg, jax.random.key(0))
    if arch.family == "gnn":
        from repro.models import gnn as G
        return G.init_params(arch.shape_cfg(shape), jax.random.key(0))
    from repro.models import recsys as R
    return R.init_params(arch.cfg, jax.random.key(0))


@pytest.mark.parametrize("name,shape", CELLS,
                         ids=[f"{n}-{s}" for n, s in CELLS])
def test_reduced_cell_step(name, shape):
    rng = np.random.default_rng(0)
    arch = get_arch(name).reduced()
    cell = arch.build_cell(shape, mesh=None)
    fn = jax.jit(cell.fn, **cell.jit_kwargs)
    params = _init_params(arch, shape)
    if cell.kind == "train":
        opt_name = "adamw" if arch.family != "lm" else arch.optimizer
        opt_init, _ = make_optimizer(OptimizerConfig(name=opt_name))
        state = {"step": jnp.zeros((), jnp.int32), "params": params,
                 "opt": opt_init(params)}
        # snapshot before the call: the cell donates its state buffers
        d0 = np.asarray(jax.tree.leaves(params)[0], np.float32).copy()
        batch = arch.smoke_inputs(shape, rng)
        new_state, metrics = fn(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(new_state["step"]) == 1
        # params actually moved
        d1 = np.asarray(jax.tree.leaves(new_state["params"])[0],
                        np.float32)
        assert np.abs(d1 - d0).max() > 0
    elif cell.kind == "serve" and arch.family == "lm":
        cache, toks = arch.smoke_inputs(shape, rng)
        len_before = int(cache["len"])      # cache is donated by the cell
        logits, new_cache = fn(params, cache, toks)
        assert logits.shape == (toks.shape[0], arch.cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        assert int(new_cache["len"]) == len_before + 1
    else:
        batch = arch.smoke_inputs(shape, rng)
        out = fn(params, batch)
        for leaf in jax.tree.leaves(out):
            if leaf.dtype.kind == "f":
                assert np.isfinite(np.asarray(leaf)).all()


def test_all_40_cells_enumerated():
    from repro.configs import all_cells
    assert len(all_cells()) == 40
