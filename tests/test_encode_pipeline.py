"""Bucketed encode pipeline: ladder geometry, order restoration, the
compile bound, and pipeline-vs-legacy ranking equivalence across the
score_impl x heap_impl x W matrix (ISSUE 5 acceptance)."""

import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.encode_pipeline import (EncodePipeline, PipelineChunkSource,
                                        bucket_ladder)
from repro.core.evaluator import RetrievalEvaluator
from repro.core.sharded_search import ShardedSearchDriver
from repro.data.tokenizer import HashTokenizer
from repro.launch.distributed import SimulatedCluster

SCORE_IMPLS = ("numpy", "jax", "pallas_fused")
HEAP_IMPLS = ("jax", "python", "pallas")


# -- ladder geometry ----------------------------------------------------------

def test_bucket_ladder_geometry():
    lad = bucket_ladder(128, n_buckets=6, multiple=8)
    assert lad[-1] == 128
    assert len(lad) <= 6
    assert all(b > a for a, b in zip(lad, lad[1:]))        # strictly up
    assert all(r % 8 == 0 for r in lad)
    assert lad[0] == 8


def test_bucket_ladder_degenerate():
    assert bucket_ladder(5, n_buckets=6, multiple=8) == (5,)
    assert bucket_ladder(64, n_buckets=1) == (64,)
    # non-multiple max_len: top rung stays exactly max_len
    assert bucket_ladder(100, n_buckets=4, multiple=8)[-1] == 100


# -- pipeline mechanics on a transparent encoder ------------------------------
#
# embedding = (sum of token ids, token count): exactly computable on the
# host, independent of padding, so order restoration and chunk/window
# alignment are checkable bit-for-bit.


def _sum_encoder():
    import jax.numpy as jnp

    def encode_fn(params, batch):
        t = batch["tokens"] * batch["mask"]
        return jnp.stack([t.sum(-1), batch["mask"].sum(-1)],
                         -1).astype(jnp.float32)

    return encode_fn


def _expected_rows(tok, texts, max_len):
    rows = []
    for t in texts:
        ids = tok.encode(t, max_len)
        rows.append([float(sum(ids)), float(len(ids))])
    return np.asarray(rows, np.float32)


@pytest.fixture()
def varied_texts():
    rng = np.random.default_rng(3)
    return [" ".join(f"w{rng.integers(1000)}"
                     for _ in range(int(rng.integers(1, 60))))
            for _ in range(137)]


def test_encode_restores_original_order(varied_texts):
    tok = HashTokenizer(4096)
    pipe = EncodePipeline(_sum_encoder(), tok, buckets=5, batch_size=16,
                          tokenizer_workers=2, depth=2)
    out = pipe.encode(None, varied_texts, 48)
    np.testing.assert_array_equal(out,
                                  _expected_rows(tok, varied_texts, 48))
    assert pipe.stats["compiles"] <= len(pipe.ladder(48))
    # bucketing must actually cut padding vs all-max_len padding
    assert pipe.stats["tokens_padded"] < 48 * len(varied_texts)


@pytest.mark.parametrize("device", (False, True))
@pytest.mark.parametrize("depth", (0, 2))
def test_stream_chunks_cover_slice_in_order(varied_texts, depth, device):
    tok = HashTokenizer(4096)
    pipe = EncodePipeline(_sum_encoder(), tok, buckets=4, batch_size=8,
                          tokenizer_workers=2, depth=depth)
    want = _expected_rows(tok, varied_texts, 32)
    lo, hi, chunk = 5, 131, 13
    offs, got = [], []
    for off, embs in pipe.stream(None, varied_texts, lo=lo, hi=hi,
                                 chunk_size=chunk, max_len=32,
                                 device=device):
        offs.append(off)
        got.append(np.asarray(embs))
    assert offs == list(range(lo, hi, chunk))
    assert [len(g) for g in got] == \
        [min(chunk, hi - o) for o in offs]
    np.testing.assert_array_equal(np.concatenate(got), want[lo:hi])


def test_chunk_source_through_driver(varied_texts):
    """The driver consumes a PipelineChunkSource via open_slice and must
    rank exactly like a plain array loader over the same embeddings."""
    tok = HashTokenizer(4096)
    pipe = EncodePipeline(_sum_encoder(), tok, buckets=4, batch_size=8,
                          tokenizer_workers=1, depth=1)
    embs = _expected_rows(tok, varied_texts, 32)
    q = embs[:7] + 0.5
    ref = ShardedSearchDriver(score_impl="numpy", chunk_size=16).search(
        q, len(varied_texts), lambda lo, hi: embs[lo:hi], 9)
    src = PipelineChunkSource(pipe, None, varied_texts, 32)
    drv = ShardedSearchDriver(score_impl="numpy", chunk_size=16)
    vals, pos = drv.search(q, len(varied_texts), src, 9)
    np.testing.assert_array_equal(pos, ref[1])
    np.testing.assert_array_equal(vals, ref[0])


def test_tokenize_workers_match_serial(varied_texts):
    tok = HashTokenizer(4096)
    serial = EncodePipeline(_sum_encoder(), tok, tokenizer_workers=1)
    fanned = EncodePipeline(_sum_encoder(), tok, tokenizer_workers=4)
    assert fanned.tokenize(varied_texts, 24) == \
        serial.tokenize(varied_texts, 24)


# -- evaluator-level equivalence: pipeline vs legacy per-batch path -----------


@pytest.fixture(scope="module")
def eq_env(tiny_retriever, tiny_params, retrieval_data):
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))

    def make(buckets, score_impl="jax", heap_impl="jax", rank=0, world=1,
             gather=None, sharder=None):
        # encode_batch_size=20: ragged chunks AND a ragged bucket tail
        return RetrievalEvaluator(
            EvaluationArguments(topk=10, encode_batch_size=20,
                                score_impl=score_impl, heap_impl=heap_impl,
                                encode_buckets=buckets,
                                metrics=("ndcg@10",)),
            tiny_retriever, coll, tiny_params, process_index=rank,
            process_count=world, gather=gather, sharder=sharder)

    legacy = make(0)
    assert legacy.encode_pipeline is None
    run = legacy.search(retrieval_data["queries"], retrieval_data["corpus"])
    return {"make": make, "run": run}


@pytest.mark.parametrize("heap_impl", HEAP_IMPLS)
@pytest.mark.parametrize("score_impl", SCORE_IMPLS)
def test_pipeline_matches_legacy_matrix(eq_env, retrieval_data, score_impl,
                                        heap_impl):
    """Online regime (no cache): the bucketed pipeline must return the
    legacy per-batch path's rankings bit-for-bit for every backend."""
    ev = eq_env["make"](6, score_impl, heap_impl)
    assert ev.encode_pipeline is not None
    qh, ids, vals = ev.search(retrieval_data["queries"],
                              retrieval_data["corpus"])
    rqh, rids, rvals = eq_env["run"]
    np.testing.assert_array_equal(qh, rqh)
    np.testing.assert_array_equal(ids, rids)
    np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("world", (2, 4))
def test_pipeline_matches_legacy_multiworker(eq_env, retrieval_data, world):
    """W simulated workers, each streaming its shard slice through its
    own pipeline, still reproduce the legacy W=1 rankings exactly."""
    cluster = SimulatedCluster(world)
    evs = [eq_env["make"](6, "jax", "jax", rank, world, cluster.gather,
                          cluster.sharder) for rank in range(world)]
    outs = cluster.run(
        lambda rank: evs[rank].search(retrieval_data["queries"],
                                      retrieval_data["corpus"]))
    rqh, rids, rvals = eq_env["run"]
    for qh, ids, vals in outs:
        np.testing.assert_array_equal(ids, rids)
        np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)


# -- compile-count regression -------------------------------------------------


def test_compile_count_bounded_by_ladder(tiny_retriever, tiny_params):
    """Encode a corpus of widely varying lengths: encoder compiles must
    stay <= ladder size + a small constant (query shapes), no matter how
    many distinct per-batch max lengths the corpus produces.  The legacy
    path compiles one executable per distinct padded shape — this pins
    shape churn out."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    ev = RetrievalEvaluator(
        EvaluationArguments(topk=5, encode_batch_size=16,
                            metrics=("ndcg@10",)),
        tiny_retriever, coll, tiny_params)
    rng = np.random.default_rng(11)
    corpus = {f"d{i}": " ".join(f"w{rng.integers(5000)}"
                                for _ in range(int(rng.integers(1, 128))))
              for i in range(160)}
    queries = {f"q{i}": f"w{i} w{i + 1} w{i + 2}" for i in range(6)}
    ev.search(queries, corpus)
    pipe = ev.encode_pipeline
    ladder = pipe.ladder(coll.args.passage_max_len)
    assert pipe.stats["compiles"] <= len(ladder) + 2
    # jax's own executable count (when exposed) must agree with the
    # trace-time counter — the stat is real compiles, not a proxy
    cache_size = pipe.jit_cache_size()
    if cache_size is not None:
        assert cache_size == pipe.stats["compiles"]
    # a second search over the same shapes must not recompile
    before = pipe.stats["compiles"]
    ev.search(queries, corpus)
    assert pipe.stats["compiles"] == before


# -- multi-node hard-negative mining write discipline -------------------------


def test_mine_hard_negatives_writes_only_on_worker0(
        tiny_retriever, tiny_params, retrieval_data, tmp_path):
    """All workers compute the identical merged triplets; only worker 0
    may write output_path (duplicate/racy writes on a shared FS)."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    cluster = SimulatedCluster(2)
    evs = [RetrievalEvaluator(
        EvaluationArguments(topk=8, metrics=("ndcg@10",)),
        tiny_retriever, coll, tiny_params, process_index=rank,
        process_count=2, gather=cluster.gather, sharder=cluster.sharder)
        for rank in range(2)]
    paths = [tmp_path / f"negs_rank{rank}.tsv" for rank in range(2)]
    outs = cluster.run(lambda rank: evs[rank].mine_hard_negatives(
        retrieval_data["queries"], retrieval_data["corpus"],
        retrieval_data["qrels"], depth=8, output_path=str(paths[rank])))
    assert outs[0] == outs[1]                  # allgather semantics
    assert paths[0].exists()
    assert not paths[1].exists()               # rank 1 must not write
    lines = paths[0].read_text().splitlines()
    assert len(lines) == len(outs[0])
    q, d, s = lines[0].split("\t")
    assert (q, d, float(s)) == (outs[0][0][0], outs[0][0][1],
                                pytest.approx(outs[0][0][2]))
