import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import collective_bytes
from repro.sharding import make_mesh
from repro.sharding.partitioning import (AxisRules, data_axes,
                                         data_parallelism)


@pytest.fixture(scope="module")
def mesh():
    # 1-device meshes still exercise the rule resolution logic
    return make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Shape-only mesh stand-in for rule resolution tests."""

    def __init__(self, **shape):
        self.shape = shape


def test_divisibility_guard():
    rules = AxisRules()
    mesh = FakeMesh(data=16, model=16)
    # divisible -> sharded
    assert rules.spec_for(("batch", None), (256, 4096), mesh) == \
        P("data", None)
    # not divisible -> replicated
    assert rules.spec_for(("heads", None), (14, 64), mesh) == P(None, None)
    # vocab not divisible by 16 (granite) -> replicated
    assert rules.spec_for(("vocab", "embed"), (49155, 1536), mesh) == \
        P(None, "model")


def test_axis_used_once():
    rules = AxisRules()
    mesh = FakeMesh(data=16, model=16)
    # experts takes "model"; expert_ffn then cannot reuse it
    spec = rules.spec_for(("experts", "fsdp", "expert_ffn"),
                          (128, 5120, 8192),
                          mesh)
    assert spec == P("model", None, None)
    # experts NOT divisible -> expert_ffn gets model instead (granite)
    spec = rules.spec_for(("experts", None, "expert_ffn"), (40, 1536, 512),
                          mesh)
    assert spec == P(None, None, "model")


def test_pod_prefix_fallback():
    rules = AxisRules().with_overrides(fsdp=("pod", "data"))
    mesh = FakeMesh(pod=2, data=16, model=16)
    # divisible by 2 but not 32 -> falls back to the "pod" prefix
    assert rules.spec_for(("fsdp",), (34,), mesh) == P("pod")
    assert rules.spec_for(("fsdp",), (64,), mesh) == P(("pod", "data"))


def test_missing_mesh_axes_dropped():
    rules = AxisRules()
    mesh = FakeMesh(data=4, model=2)     # no "pod"
    assert rules.spec_for(("batch",), (8,), mesh) == P("data")


def test_data_axes_helpers():
    assert data_axes(FakeMesh(pod=2, data=16, model=16)) == ("pod", "data")
    assert data_parallelism(FakeMesh(data=16, model=16)) == 16


# -- roofline HLO parsing ------------------------------------------------------

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = bf16[256,4096] parameter(0)
  %add.3 = bf16[256,4096] add(p0, p0)
  %ar = bf16[256,4096] all-reduce(add.3), replica_groups={}
  %ag = f32[16,128] all-gather(p0), dimensions={0}
  %tup = (bf16[8,8], bf16[8,8]) all-to-all(add.3, add.3)
}
"""


def test_collective_bytes_symbol_table():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 256 * 4096 * 2       # operand resolved
    assert out["all-to-all"] == 2 * 256 * 4096 * 2   # two operands
    assert out["all-gather"] == 256 * 4096 * 2       # p0 resolved
    assert out["count"] == 3
    assert out["total"] == out["all-reduce"] + out["all-gather"] + \
        out["all-to-all"]
