import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import st

from repro.core.result_heap import FastResultHeapq


def _stream(rng, q, chunks, c):
    for i in range(chunks):
        yield (rng.normal(size=(q, c)).astype(np.float32),
               np.arange(i * c, (i + 1) * c, dtype=np.int32))


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_impls_match_python_heapq(impl, rng):
    q, k, c = 7, 5, 33
    ref = FastResultHeapq(q, k, impl="python")
    fast = FastResultHeapq(q, k, impl=impl)
    for scores, ids in _stream(rng, q, 4, c):
        ref.update(scores, ids)
        fast.update(scores, ids)
    rv, ri = ref.finalize()
    fv, fi = fast.finalize()
    np.testing.assert_allclose(rv, fv, rtol=1e-6)
    np.testing.assert_array_equal(ri, fi)


def test_merge_equals_single_stream(rng):
    """Sharded (merge) result == unsharded result (multi-node invariant)."""
    q, k, c = 5, 8, 16
    whole = FastResultHeapq(q, k)
    parts = [FastResultHeapq(q, k) for _ in range(3)]
    for i, (scores, ids) in enumerate(_stream(rng, q, 6, c)):
        whole.update(scores, ids)
        parts[i % 3].update(scores, ids)
    merged = parts[0]
    merged.merge(parts[1])
    merged.merge(parts[2])
    wv, wi = whole.finalize()
    mv, mi = merged.finalize()
    np.testing.assert_allclose(wv, mv, rtol=1e-6)
    np.testing.assert_array_equal(wi, mi)


@pytest.mark.parametrize("impl", ["python", "jax", "pallas"])
def test_merge_arrays_equals_merge(impl, rng):
    """Array-level merge (fused-kernel output path) == object merge."""
    q, k, c = 5, 6, 21
    chunks = list(_stream(rng, q, 6, c))
    other = FastResultHeapq(q, k)
    for s, i in chunks[3:]:
        other.update(s, i)

    via_obj = FastResultHeapq(q, k, impl=impl)
    via_arr = FastResultHeapq(q, k, impl=impl)
    for s, i in chunks[:3]:
        via_obj.update(s, i)
        via_arr.update(s, i)
    via_obj.merge(other)
    via_arr.merge_arrays(*other.finalize())

    ov, oi = via_obj.finalize()
    av, ai = via_arr.finalize()
    np.testing.assert_allclose(ov, av, rtol=1e-6)
    np.testing.assert_array_equal(oi, ai)


@pytest.mark.parametrize("impl", ["python", "jax"])
def test_merge_arrays_ignores_empty_slots(impl):
    """-1 ids (unfilled fused-kernel slots) never surface as results."""
    h = FastResultHeapq(2, 3, impl=impl)
    vals = np.asarray([[1.0, -np.inf, -np.inf],
                       [2.0, 0.5, -np.inf]], np.float32)
    ids = np.asarray([[7, -1, -1], [9, 4, -1]], np.int32)
    h.merge_arrays(vals, ids)
    v, i = h.finalize()
    np.testing.assert_array_equal(i, [[7, -1, -1], [9, 4, -1]])
    assert np.isneginf(v[0, 1:]).all() and np.isneginf(v[1, 2])


def test_fewer_candidates_than_k(rng):
    h = FastResultHeapq(3, 10)
    h.update(rng.normal(size=(3, 4)).astype(np.float32),
             np.arange(4, dtype=np.int32))
    vals, ids = h.finalize()
    assert (ids[:, 4:] == -1).all()
    assert np.isneginf(vals[:, 4:]).all()


@settings(max_examples=20, deadline=None)
@given(q=st.integers(1, 6), k=st.integers(1, 12),
       n_chunks=st.integers(1, 4), c=st.integers(1, 40),
       seed=st.integers(0, 999))
def test_property_topk_of_concat(q, k, n_chunks, c, seed):
    """Streaming top-k == top-k of the concatenated score matrix."""
    rng = np.random.default_rng(seed)
    h = FastResultHeapq(q, k)
    all_scores = []
    for scores, ids in _stream(rng, q, n_chunks, c):
        h.update(scores, ids)
        all_scores.append(scores)
    full = np.concatenate(all_scores, axis=1)
    vals, ids = h.finalize()
    expect = -np.sort(-full, axis=1)[:, :k]
    got = vals[:, : min(k, full.shape[1])]
    np.testing.assert_allclose(got, expect[:, : got.shape[1]], rtol=1e-6)
    # ids actually point at those scores
    for qi in range(q):
        for j in range(min(k, full.shape[1])):
            assert full[qi, ids[qi, j]] == vals[qi, j]
