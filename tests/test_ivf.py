"""IVF cluster-pruned index: quantizer, layout, persistence, and the
flat-equivalence story.

The contract under test (ISSUE 8): ``nprobe == n_clusters`` reproduces
the flat exhaustive ranking through the same kernels across the whole
``score_impl × heap_impl × W`` matrix; pruned probes trade bounded
recall for sublinear work; the persisted cluster layout survives torn
writes exactly like the embedding cache.
"""

import json
import os

import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.core.evaluator import (IVFPreparedCorpus, IVFSearchSpace,
                                  RetrievalEvaluator)
from repro.core.fair_sharding import FairSharder
from repro.core.sharded_search import ShardedSearchDriver
from repro.data.tokenizer import HashTokenizer
from repro.index import IVFIndex, assign_rows, train_kmeans
from repro.launch.distributed import SimulatedCluster

SCORE_IMPLS = ("numpy", "jax", "pallas_fused")
HEAP_IMPLS = ("python", "jax", "pallas")
WORLD_SIZES = (1, 2)


def _clustered(n_docs, dim, n_topics, n_queries, seed=0, noise=0.12):
    """Unit-norm docs around unit-norm topic centers + nearby queries."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_topics, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    topic = rng.integers(0, n_topics, size=n_docs)
    docs = centers[topic] + noise * rng.normal(
        size=(n_docs, dim)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    q = docs[rng.choice(n_docs, n_queries, replace=False)] + \
        0.04 * rng.normal(size=(n_queries, dim)).astype(np.float32)
    return docs, (q / np.linalg.norm(q, axis=1, keepdims=True)
                  ).astype(np.float32)


# -- kmeans -------------------------------------------------------------------


def test_kmeans_deterministic():
    docs, _ = _clustered(300, 16, 5, 1)
    get = lambda lo, hi: docs[lo:hi]                      # noqa: E731
    c1 = train_kmeans(get, 300, 5, train_steps=10, batch_size=64, seed=3)
    c2 = train_kmeans(get, 300, 5, train_steps=10, batch_size=64, seed=3)
    np.testing.assert_array_equal(c1, c2)
    c3 = train_kmeans(get, 300, 5, train_steps=10, batch_size=64, seed=4)
    assert not np.array_equal(c1, c3)


def test_kmeans_recovers_separated_clusters():
    """On well-separated topics, nearly every doc should share its
    cluster with the other docs of its topic (assignment purity)."""
    docs, _ = _clustered(600, 24, 4, 1, noise=0.08)
    get = lambda lo, hi: docs[lo:hi]                      # noqa: E731
    cents = train_kmeans(get, 600, 4, train_steps=30, batch_size=128)
    assign = assign_rows(cents, get, 600)
    assert assign.shape == (600,)
    # every cluster is populated and every row sits in its own nearest
    # cluster (assignment consistent with the trained centroids)
    sizes = np.bincount(assign, minlength=4)
    assert (sizes > 0).all()
    d2 = ((docs[:, None] - cents[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(assign, np.argmin(d2, axis=1))


def test_kmeans_edge_cases():
    docs = np.eye(3, 8, dtype=np.float32)
    get = lambda lo, hi: docs[lo:hi]                      # noqa: E731
    # more clusters than rows: capped at n_rows
    cents = train_kmeans(get, 3, 10, train_steps=2, batch_size=2)
    assert cents.shape == (3, 8)
    with pytest.raises(ValueError, match="n_rows"):
        train_kmeans(get, 0, 2)
    with pytest.raises(ValueError, match="train_steps"):
        train_kmeans(get, 3, 2, train_steps=0)


# -- layout invariants --------------------------------------------------------


def test_build_layout_invariants():
    docs, _ = _clustered(500, 16, 6, 1)
    get = lambda lo, hi: docs[lo:hi]                      # noqa: E731
    idx = IVFIndex.build(get, 500, 6, train_steps=10)
    # perm is a permutation of [0, n)
    assert np.array_equal(np.sort(idx.perm), np.arange(500))
    # offsets partition [0, n) and match the assignment counts
    assign = assign_rows(idx.centroids, get, 500)
    np.testing.assert_array_equal(
        idx.cluster_sizes(), np.bincount(assign, minlength=6))
    # every cluster slice holds exactly that cluster's rows, in their
    # original (stable) relative order
    for c in range(idx.n_clusters):
        rows = idx.perm[idx.offsets[c]:idx.offsets[c + 1]]
        assert (assign[rows] == c).all()
        assert (np.diff(rows) > 0).all()


def test_select_and_gather_edges():
    docs, q = _clustered(400, 16, 8, 3)
    idx = IVFIndex.build(lambda lo, hi: docs[lo:hi], 400, 8,
                         train_steps=10)
    full = idx.select(q, idx.n_clusters)
    assert np.array_equal(np.sort(full), full)            # ascending
    assert len(idx.gather_rows(full)) == 400
    few = idx.select(q, 2)
    assert 1 <= len(few) <= min(2 * len(q), idx.n_clusters)
    # nprobe beyond n_clusters clamps; 1D query promotes to a batch
    assert np.array_equal(idx.select(q[0], 999), full)
    assert len(idx.gather_rows(np.empty(0, np.int64))) == 0
    b = idx.slice_boundaries(few)
    assert b[0] == 0 and b[-1] == len(idx.gather_rows(few))
    assert (np.diff(b) > 0).all()


# -- persistence --------------------------------------------------------------


def test_persist_roundtrip_and_staleness(tmp_path):
    docs, _ = _clustered(200, 8, 4, 1)
    idx = IVFIndex.build(lambda lo, hi: docs[lo:hi], 200, 4,
                         train_steps=5)
    d = str(tmp_path / "ivf")
    idx.save(d, digest="dig-1")
    back = IVFIndex.load(d, expect_n=200, expect_dim=8,
                         expect_clusters=4, expect_digest="dig-1")
    assert back is not None
    np.testing.assert_array_equal(back.perm, idx.perm)
    np.testing.assert_array_equal(back.offsets, idx.offsets)
    np.testing.assert_array_equal(back.centroids, idx.centroids)
    # any expectation mismatch means "rebuild", not "serve stale"
    assert IVFIndex.load(d, expect_digest="dig-2") is None
    assert IVFIndex.load(d, expect_n=201) is None
    assert IVFIndex.load(d, expect_dim=16) is None
    assert IVFIndex.load(d, expect_clusters=8) is None
    assert IVFIndex.load(str(tmp_path / "nowhere")) is None


def test_persist_torn_write_reopen(tmp_path):
    """Torn payload files (crash mid-save) must read as 'rebuild' —
    never as a wrong permutation (the cache's crash-safety contract)."""
    docs, _ = _clustered(150, 8, 3, 1)
    idx = IVFIndex.build(lambda lo, hi: docs[lo:hi], 150, 3,
                         train_steps=5)
    d = str(tmp_path / "ivf")

    def fresh():
        idx.save(d, digest="x")

    # short perm.bin
    fresh()
    with open(os.path.join(d, "perm.bin"), "r+b") as f:
        f.truncate(8 * 149)
    assert IVFIndex.load(d, expect_digest="x") is None
    # short offsets.bin
    fresh()
    with open(os.path.join(d, "offsets.bin"), "r+b") as f:
        f.truncate(8)
    assert IVFIndex.load(d, expect_digest="x") is None
    # short centroids.bin
    fresh()
    with open(os.path.join(d, "centroids.bin"), "r+b") as f:
        f.truncate(4)
    assert IVFIndex.load(d, expect_digest="x") is None
    # right length but not a permutation (e.g. recycled garbage bytes)
    fresh()
    perm = np.zeros(150, np.int64)
    with open(os.path.join(d, "perm.bin"), "wb") as f:
        f.write(perm.tobytes())
    assert IVFIndex.load(d, expect_digest="x") is None
    # torn meta.json
    fresh()
    with open(os.path.join(d, "meta.json"), "w") as f:
        f.write('{"n": 150, "dim"')
    assert IVFIndex.load(d) is None
    # trailing garbage past the committed sizes is ignored (cache rule)
    fresh()
    for fname in ("perm.bin", "offsets.bin", "centroids.bin"):
        with open(os.path.join(d, fname), "ab") as f:
            f.write(b"\x07" * 13)
    back = IVFIndex.load(d, expect_digest="x")
    assert back is not None
    np.testing.assert_array_equal(back.perm, idx.perm)


# -- driver-level equivalence and pruning -------------------------------------


def _flat_search(q, docs, topk, **kw):
    driver = ShardedSearchDriver(chunk_size=64, **kw)
    vals, pos = driver.search(q, len(docs), lambda lo, hi: docs[lo:hi],
                              topk)
    return vals, pos


def _ivf_search(q, docs, index, nprobe, topk, world=1, **kw):
    hashes = np.arange(len(docs), dtype=np.int64)
    prepared = IVFPreparedCorpus(hashes, len(docs),
                                 lambda rows: docs[rows], index, nprobe)
    sized, load_chunk, to_ids = prepared.round_for(q)
    if world == 1:
        driver = ShardedSearchDriver(chunk_size=64, **kw)
        vals, pos = driver.search(q, sized, load_chunk, topk)
        return [(to_ids(pos), vals)]
    cluster = SimulatedCluster(world)
    drivers = [ShardedSearchDriver(
        n_workers=world, worker_index=r, sharder=cluster.sharder,
        gather=cluster.gather, chunk_size=64, **kw)
        for r in range(world)]
    outs = cluster.run(
        lambda rank: drivers[rank].search(q, sized, load_chunk, topk))
    return [(to_ids(pos), vals) for vals, pos in outs]


@pytest.fixture(scope="module")
def ivf_synth():
    docs, q = _clustered(800, 16, 10, 12)
    index = IVFIndex.build(lambda lo, hi: docs[lo:hi], len(docs), 10,
                           train_steps=20)
    flat_vals, flat_pos = _flat_search(q, docs, 10, score_impl="numpy")
    flat_ids = np.where(flat_pos >= 0, flat_pos.astype(np.int64), -1)
    return {"docs": docs, "q": q, "index": index,
            "flat_ids": flat_ids, "flat_vals": flat_vals}


@pytest.mark.parametrize("world", WORLD_SIZES)
@pytest.mark.parametrize("heap_impl", HEAP_IMPLS)
@pytest.mark.parametrize("score_impl", SCORE_IMPLS)
def test_full_probe_matrix_matches_flat(ivf_synth, score_impl, heap_impl,
                                        world):
    """nprobe == n_clusters through every score/heap backend and world
    size reproduces the flat exhaustive ranking: bitwise ids, allclose
    scores, on every rank."""
    outs = _ivf_search(ivf_synth["q"], ivf_synth["docs"],
                       ivf_synth["index"], ivf_synth["index"].n_clusters,
                       10, world=world, score_impl=score_impl,
                       heap_impl=heap_impl)
    for ids, vals in outs:
        np.testing.assert_array_equal(ids, ivf_synth["flat_ids"])
        np.testing.assert_allclose(vals, ivf_synth["flat_vals"],
                                   rtol=1e-5, atol=1e-6)


def test_pruned_recall_floor(ivf_synth):
    """nprobe = n_clusters // 4 on a clustered corpus keeps
    recall@10 >= 0.9 against the flat oracle (queries probed in small
    batches — the serving regime pruning is for)."""
    docs, q, index = (ivf_synth["docs"], ivf_synth["q"],
                      ivf_synth["index"])
    nprobe = max(index.n_clusters // 4, 1)
    recalls = []
    for lo in range(0, len(q), 3):
        qb = q[lo: lo + 3]
        (ids, _), = _ivf_search(qb, docs, index, nprobe, 10,
                                score_impl="numpy")
        flat = ivf_synth["flat_ids"][lo: lo + 3]
        recalls += [len(set(f[f >= 0].tolist()) & set(r[r >= 0].tolist()))
                    / 10 for f, r in zip(flat, ids)]
    assert np.mean(recalls) >= 0.9, np.mean(recalls)


def test_pruned_scans_fewer_rows(ivf_synth):
    index, q = ivf_synth["index"], ivf_synth["q"]
    prepared = IVFPreparedCorpus(
        np.arange(len(ivf_synth["docs"]), dtype=np.int64),
        len(ivf_synth["docs"]), lambda rows: ivf_synth["docs"][rows],
        index, 1)
    sized, _, _ = prepared.round_for(q[:2])
    assert 0 < len(sized) < len(ivf_synth["docs"])
    assert isinstance(sized, IVFSearchSpace)
    assert sized.partition_boundaries[-1] == len(sized)


def test_topk_exceeds_selected_cluster_rows(ivf_synth):
    """k larger than the probed clusters' total rows: the tail is empty
    (-1), never recycled garbage — and larger than any single cluster
    is business as usual."""
    docs, q, index = (ivf_synth["docs"], ivf_synth["q"][:1],
                      ivf_synth["index"])
    sized, _, _ = IVFPreparedCorpus(
        np.arange(len(docs), dtype=np.int64), len(docs),
        lambda rows: docs[rows], index, 1).round_for(q)
    n_sel = len(sized)
    big_k = n_sel + 7
    (ids, vals), = _ivf_search(q, docs, index, 1, big_k,
                               score_impl="numpy")
    assert (ids[0, :n_sel] >= 0).all()
    assert (ids[0, n_sel:] == -1).all()
    assert (vals[0, n_sel:] == -np.inf).all()


def test_empty_selection_returns_empty():
    """A query whose probed clusters are all empty gets an all-empty
    result, not an exception (manually constructed degenerate layout —
    select() drops empty clusters, leaving nothing)."""
    rng = np.random.default_rng(0)
    docs = rng.normal(size=(20, 8)).astype(np.float32)
    # two centroids, every row in cluster 1; a query near centroid 0
    centroids = np.stack([np.full(8, 10.0, np.float32),
                          docs.mean(0)])
    index = IVFIndex(centroids, np.arange(20, dtype=np.int64),
                     np.array([0, 0, 20], np.int64))
    q = np.full((1, 8), 10.0, np.float32)
    assert len(index.select(q, 1)) == 0
    prepared = IVFPreparedCorpus(np.arange(20, dtype=np.int64), 20,
                                 lambda rows: docs[rows], index, 1)
    sized, load_chunk, to_ids = prepared.round_for(q)
    assert len(sized) == 0
    driver = ShardedSearchDriver(score_impl="numpy", chunk_size=8)
    vals, pos = driver.search(q, sized, load_chunk, 5)
    assert (to_ids(pos) == -1).all()


# -- fair sharding over cluster boundaries ------------------------------------


def test_sharder_snaps_to_boundaries():
    s = FairSharder(3)
    boundaries = np.array([0, 10, 35, 60, 80, 100], np.int64)
    bounds = s.bounds(100, boundaries)
    # exact partition of [0, 100) ...
    assert bounds[0][0] == 0 and bounds[-1][1] == 100
    for (a, b), (c, d) in zip(bounds, bounds[1:]):
        assert b == c
    # ... with every interior cut on a cluster edge
    for _, hi in bounds[:-1]:
        assert hi in boundaries.tolist()
    # plain bounds (no boundaries) unchanged
    plain = s.bounds(100)
    assert plain[0][0] == 0 and plain[-1][1] == 100


def test_sharder_boundaries_with_coarse_clusters():
    """Cluster granularity coarser than a worker's share: empty shards
    are legal, coverage stays exact."""
    s = FairSharder(4)
    boundaries = np.array([0, 90, 100], np.int64)
    bounds = s.bounds(100, boundaries)
    assert bounds[0][0] == 0 and bounds[-1][1] == 100
    for (a, b), (c, d) in zip(bounds, bounds[1:]):
        assert b == c
    for _, hi in bounds[:-1]:
        assert hi in boundaries.tolist()


def test_driver_partitions_ivf_space_on_cluster_edges(ivf_synth):
    """W=2 drivers over an IVFSearchSpace split on cluster boundaries
    (each worker's shard is a run of whole clusters), and the merged
    result still matches W=1."""
    docs, q, index = (ivf_synth["docs"], ivf_synth["q"],
                      ivf_synth["index"])
    prepared = IVFPreparedCorpus(np.arange(len(docs), dtype=np.int64),
                                 len(docs), lambda rows: docs[rows],
                                 index, 3)
    sized, load_chunk, to_ids = prepared.round_for(q)
    driver = ShardedSearchDriver(n_workers=2, worker_index=0,
                                 sharder=FairSharder(2),
                                 score_impl="numpy", chunk_size=64)
    bounds = driver.partition(sized)
    edges = set(np.asarray(sized.partition_boundaries).tolist())
    assert bounds[0][0] == 0 and bounds[-1][1] == len(sized)
    for _, hi in bounds[:-1]:
        assert hi in edges
    (ref_ids, ref_vals), = _ivf_search(q, docs, index, 3, 10,
                                       score_impl="numpy")
    outs = _ivf_search(q, docs, index, 3, 10, world=2,
                       score_impl="numpy")
    for ids, vals in outs:
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_allclose(vals, ref_vals, rtol=1e-5, atol=1e-6)


# -- evaluator integration (real encoder, persisted index) --------------------


@pytest.fixture(scope="module")
def ivf_env(tiny_retriever, tiny_params, retrieval_data,
            tmp_path_factory):
    """Warm shared cache + flat warm-regime reference rankings."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    cache = EmbeddingCache(str(tmp_path_factory.mktemp("ivfcache") / "c"),
                           dim=32)

    def make(rank=0, world=1, gather=None, sharder=None, **over):
        kw = dict(topk=10, encode_batch_size=20, score_impl="numpy",
                  metrics=("ndcg@10",))
        kw.update(over)
        return RetrievalEvaluator(
            EvaluationArguments(**kw), tiny_retriever, coll, tiny_params,
            process_index=rank, process_count=world,
            gather=gather, sharder=sharder)

    queries, corpus = retrieval_data["queries"], retrieval_data["corpus"]
    flat = make()
    flat.search(queries, corpus, cache=cache)           # warm the cache
    ref = flat.search(queries, corpus, cache=cache)     # warm reference
    return {"make": make, "cache": cache, "ref": ref,
            "queries": queries, "corpus": corpus}


def test_evaluator_ivf_full_probe_matches_flat(ivf_env):
    """index_impl=ivf with nprobe == nclusters == flat rankings through
    the evaluator (warm cache, persisted index round-trips)."""
    ev = ivf_env["make"](index_impl="ivf", ivf_nclusters=6, ivf_nprobe=6,
                         ivf_train_steps=8)
    qh, ids, vals = ev.search(ivf_env["queries"], ivf_env["corpus"],
                              cache=ivf_env["cache"])
    rqh, rids, rvals = ivf_env["ref"]
    np.testing.assert_array_equal(qh, rqh)
    np.testing.assert_array_equal(ids, rids)
    np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)
    # the index persisted under the cache dir and is reused verbatim
    d = os.path.join(ivf_env["cache"].path, "ivf_k6")
    assert os.path.exists(os.path.join(d, "meta.json"))
    meta = json.load(open(os.path.join(d, "meta.json")))
    st = os.stat(os.path.join(d, "perm.bin"))
    qh2, ids2, vals2 = ev.search(ivf_env["queries"], ivf_env["corpus"],
                                 cache=ivf_env["cache"])
    assert os.stat(os.path.join(d, "perm.bin")).st_mtime_ns == st.st_mtime_ns
    np.testing.assert_array_equal(ids2, ids)
    assert meta["n"] == len(ivf_env["corpus"])


@pytest.mark.distributed
@pytest.mark.parametrize("world", WORLD_SIZES)
@pytest.mark.parametrize("heap_impl", HEAP_IMPLS)
@pytest.mark.parametrize("score_impl", SCORE_IMPLS)
def test_evaluator_ivf_matrix(ivf_env, score_impl, heap_impl, world):
    """The ISSUE equivalence matrix: index_impl=ivf at full probe ==
    the seed flat rankings across score_impl × heap_impl × W, every
    rank identical."""
    over = dict(index_impl="ivf", ivf_nclusters=6, ivf_nprobe=6,
                ivf_train_steps=8, score_impl=score_impl,
                heap_impl=heap_impl)
    queries, corpus = ivf_env["queries"], ivf_env["corpus"]
    if world == 1:
        outs = [ivf_env["make"](**over).search(queries, corpus,
                                               cache=ivf_env["cache"])]
    else:
        cluster = SimulatedCluster(world)
        evs = [ivf_env["make"](rank, world, cluster.gather,
                               cluster.sharder, **over)
               for rank in range(world)]
        outs = cluster.run(
            lambda rank: evs[rank].search(queries, corpus,
                                          cache=ivf_env["cache"]))
    rqh, rids, rvals = ivf_env["ref"]
    for qh, ids, vals in outs:
        np.testing.assert_array_equal(qh, rqh)
        np.testing.assert_array_equal(ids, rids)
        np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)


def test_evaluator_ivf_stale_digest_rebuilds(ivf_env, tmp_path):
    """Changing the build knobs (digest input) rebuilds instead of
    serving the stale persisted layout."""
    cache = ivf_env["cache"]
    ev1 = ivf_env["make"](index_impl="ivf", ivf_nclusters=6,
                          ivf_nprobe=6, ivf_train_steps=8)
    ev1.search(ivf_env["queries"], ivf_env["corpus"], cache=cache)
    d = os.path.join(cache.path, "ivf_k6")
    st = os.stat(os.path.join(d, "meta.json"))
    ev2 = ivf_env["make"](index_impl="ivf", ivf_nclusters=6,
                          ivf_nprobe=6, ivf_train_steps=9)
    qh, ids, vals = ev2.search(ivf_env["queries"], ivf_env["corpus"],
                               cache=cache)
    assert os.stat(os.path.join(d, "meta.json")).st_mtime_ns \
        != st.st_mtime_ns                       # rebuilt + re-persisted
    np.testing.assert_array_equal(ids, ivf_env["ref"][1])


def test_config_validates_ivf_knobs():
    with pytest.raises(ValueError, match="index_impl"):
        EvaluationArguments(index_impl="annoy")
    with pytest.raises(ValueError, match="ivf_nclusters"):
        EvaluationArguments(ivf_nclusters=0)
    with pytest.raises(ValueError, match="ivf_nprobe"):
        EvaluationArguments(ivf_nprobe=0)
    with pytest.raises(ValueError, match="ivf_train_steps"):
        EvaluationArguments(ivf_train_steps=0)
    args = EvaluationArguments(index_impl="ivf", ivf_nclusters=4,
                               ivf_nprobe=4)
    assert args.index_impl == "ivf"


@pytest.mark.serving
def test_serve_frontend_over_ivf(ivf_env):
    """ServeFrontend over an IVF-prepared corpus: full probe serves the
    flat frontend's exact results per request."""
    from repro.core.serving import ServeFrontend

    queries = list(ivf_env["queries"].values())[:6]
    flat_fe = ServeFrontend.from_evaluator(
        ivf_env["make"](score_impl="jax"), ivf_env["corpus"],
        ivf_env["cache"], max_wait_ms=0.5)
    try:
        flat_out = [flat_fe.search(t) for t in queries]
    finally:
        flat_fe.close()
    ivf_fe = ServeFrontend.from_evaluator(
        ivf_env["make"](score_impl="jax", index_impl="ivf",
                        ivf_nclusters=6, ivf_nprobe=6,
                        ivf_train_steps=8),
        ivf_env["corpus"], ivf_env["cache"], max_wait_ms=0.5)
    try:
        for t, (rids, rvals) in zip(queries, flat_out):
            ids, vals = ivf_fe.search(t)
            np.testing.assert_array_equal(ids, rids)
            np.testing.assert_allclose(vals, rvals, rtol=1e-5,
                                       atol=1e-6)
    finally:
        ivf_fe.close()
