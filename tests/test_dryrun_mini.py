"""Dry-run machinery test: real lowering through mesh/cell/roofline
plumbing on a small placeholder-device mesh (subprocess — device count
must be set before jax initializes)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import get_arch
from repro.launch.roofline import collective_bytes, normalize_cost, \
    roofline_terms
from repro.launch.memmodel import memory_model
from repro.sharding import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}
for name, shape in [("qwen2-0.5b", "train_4k"),
                    ("granite-moe-3b-a800m", "decode_32k"),
                    ("deepfm", "retrieval_cand"),
                    ("graphsage-reddit", "minibatch_lg")]:
    arch = get_arch(name).reduced()
    cell = arch.build_cell(shape, mesh=mesh)
    lowered = jax.jit(cell.fn, **cell.jit_kwargs).lower(*cell.abstract_args)
    compiled = lowered.compile()
    cost = normalize_cost(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    terms = roofline_terms(cost, coll["total"])
    mm = memory_model(arch, shape, mesh, cell)
    out[f"{name}:{shape}"] = {
        "flops": cost.get("flops", 0), "collective_count": coll["count"],
        "collective_bytes": coll["total"],
        "dominant": terms["dominant"], "mem_total": mm["total_bytes"],
    }
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_cells_on_mini_mesh():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert len(out) == 4
    for key, rec in out.items():
        assert rec["flops"] > 0, key
        assert rec["mem_total"] > 0, key
        # sharded programs must exchange SOMETHING across the 8 devices
    assert any(r["collective_count"] > 0 for r in out.values())
