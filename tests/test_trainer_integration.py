"""Trainer integration: grad accumulation equivalence, compression modes,
dev-metric hook — kept tiny for CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import RetrievalTrainingArguments
from repro.core.metrics import IRMetrics
from repro.training.trainer import RetrievalTrainer


class ToyRetriever:
    """Quadratic toy model exposing the retriever duck-type."""

    def init_params(self, rng):
        return {"w": jnp.asarray([2.0, -1.0, 0.5])}

    def abstract_params(self):
        return {"w": jax.ShapeDtypeStruct((3,), jnp.float32)}

    def param_logical_axes(self):
        return {"w": (None,)}

    def forward(self, params, batch, ctx=None):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"mse": loss}


def _args(tmp_path, **kw):
    base = dict(output_dir=str(tmp_path), max_steps=20, learning_rate=0.05,
                warmup_steps=0, per_device_batch_size=8, log_every=5,
                checkpoint_every=100, weight_decay=0.0)
    base.update(kw)
    return RetrievalTrainingArguments(**base)


class _Data:
    def __init__(self, n=64, seed=0):
        rng = np.random.default_rng(seed)
        self.x = rng.normal(size=(n, 3)).astype(np.float32)
        self.w_true = np.asarray([1.0, 2.0, -0.5], np.float32)
        self.y = self.x @ self.w_true

    def __len__(self):
        return len(self.x)

    def __getitem__(self, i):
        return i


class _Collator:
    def __init__(self, data):
        self.data = data

    def __call__(self, idx):
        idx = np.asarray(idx)
        return {"x": self.data.x[idx], "y": self.data.y[idx]}


def _make_trainer(tmp_path, **kw):
    data = _Data()
    retr = ToyRetriever()
    tr = RetrievalTrainer(retr, _args(tmp_path, **kw), _Collator(data),
                          data)
    return tr


def test_toy_convergence(tmp_path):
    tr = _make_trainer(tmp_path, max_steps=60, learning_rate=0.1)
    state = tr.train()
    w = np.asarray(state["params"]["w"])
    np.testing.assert_allclose(w, [1.0, 2.0, -0.5], atol=0.15)


def test_grad_accum_steps_equivalent_loss_path(tmp_path):
    """accum=2 with half micro-batch trains to a similar optimum."""
    t1 = _make_trainer(tmp_path / "a", max_steps=40, learning_rate=0.1)
    s1 = t1.train()
    t2 = _make_trainer(tmp_path / "b", max_steps=40, learning_rate=0.1,
                       grad_accum_steps=2)
    s2 = t2.train()
    np.testing.assert_allclose(np.asarray(s1["params"]["w"]),
                               np.asarray(s2["params"]["w"]), atol=0.2)


@pytest.mark.parametrize("comp", ["bf16", "int8"])
def test_compressed_training_converges(tmp_path, comp):
    tr = _make_trainer(tmp_path, max_steps=60, learning_rate=0.1,
                       grad_compression=comp)
    tr.dp_mode = "shard_map"
    state = tr.train()
    w = np.asarray(state["params"]["w"])
    np.testing.assert_allclose(w, [1.0, 2.0, -0.5], atol=0.25)


def test_adafactor_path(tmp_path):
    tr = _make_trainer(tmp_path, max_steps=60, optimizer="adafactor",
                       learning_rate=0.5)
    state = tr.train()
    assert tr.logs[-1]["loss"] < tr.logs[0]["loss"]
