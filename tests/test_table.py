import json
import os

import numpy as np
import pytest

from repro.data.table import (MMapTable, atomic_write_dir, file_fingerprint,
                              stable_id_hash, stable_id_hash_array)


def test_hash_array_matches_scalar():
    """Vectorized hashing == per-element hashing for every id flavor,
    including Python ints beyond int64 (scalar masks at arbitrary
    precision; the array path must not OverflowError)."""
    cases = [
        ["doc-a", "doc-b", ""],                       # strings
        [0, 7, -5, 2**62],                            # int64-range ints
        [2**63, 2**64 + 3, -2**63],                   # beyond-int64 ints
        np.asarray([1, 2, 3], np.uint64),             # unsigned ndarray
    ]
    for ids in cases:
        got = stable_id_hash_array(ids)
        want = [stable_id_hash(int(i) if isinstance(i, np.integer) else i)
                for i in ids]
        assert got.dtype == np.int64
        assert got.tolist() == want, ids


def _records(n):
    return [{"_id": f"doc{i}", "text": f"text {i}"} for i in range(n)]


def test_build_and_lookup(tmp_path):
    t = MMapTable.build(_records(100), str(tmp_path / "t"))
    assert len(t) == 100
    assert t.get("doc42")["text"] == "text 42"
    assert t.get(stable_id_hash("doc7"))["_id"] == "doc7"
    assert "doc99" in t and "doc100" not in t
    with pytest.raises(KeyError):
        t.get("missing")


def test_vectorized_indices(tmp_path):
    t = MMapTable.build(_records(50), str(tmp_path / "t"))
    hashes = np.asarray([stable_id_hash(f"doc{i}") for i in (3, 30, 7)])
    idx = t.indices_of(hashes)
    assert [t.row(i)["_id"] for i in idx] == ["doc3", "doc30", "doc7"]


def test_duplicate_ids_rejected(tmp_path):
    with pytest.raises(ValueError, match="collision|duplicate"):
        MMapTable.build(_records(5) + [{"_id": "doc3", "text": "dup"}],
                        str(tmp_path / "t"))


def test_build_cached_reuses(tmp_path):
    calls = []

    def records():
        calls.append(1)
        return _records(10)

    t1 = MMapTable.build_cached(records, str(tmp_path), "fp123")
    t2 = MMapTable.build_cached(records, str(tmp_path), "fp123")
    assert len(calls) == 1              # second call hit the cache
    assert len(t1) == len(t2) == 10


def test_atomic_write_failure_leaves_nothing(tmp_path):
    target = str(tmp_path / "out")
    with pytest.raises(RuntimeError):
        with atomic_write_dir(target) as tmp:
            with open(os.path.join(tmp, "partial"), "w") as f:
                f.write("x")
            raise RuntimeError("boom")
    assert not os.path.exists(target)


def test_fingerprint_changes_with_content(tmp_path):
    p = tmp_path / "f.txt"
    p.write_text("a")
    fp1 = file_fingerprint(str(p))
    os.utime(p, ns=(1, 2))
    fp2 = file_fingerprint(str(p))
    assert fp1 != fp2
    assert file_fingerprint(str(p), "cfgA") != file_fingerprint(str(p), "cfgB")


def test_memory_mapped_payload(tmp_path):
    # a large-ish table's payload should not be resident after open
    t = MMapTable.build(_records(5000), str(tmp_path / "t"))
    assert isinstance(t._payload, np.memmap)
    # row decode only touches its slice
    assert t.row(4999)["_id"] == "doc4999"
