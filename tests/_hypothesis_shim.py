"""Optional-``hypothesis`` shim (the spirit of ``pytest.importorskip``,
scoped to the property tests only).

``pytest.importorskip("hypothesis")`` at module top would skip *every*
test in the module; importing from here instead keeps the example-based
tests running everywhere, runs the property tests when hypothesis is
installed, and turns each ``@given`` test into an individual skip when
it is not.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: strategies built here
        are only ever passed to the stub ``given`` below, so any callable
        returning None suffices."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        if args and callable(args[0]):               # bare @settings
            return args[0]
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # Zero-arg replacement: pytest must not mistake the
            # hypothesis-bound parameters for fixtures.
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
