"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
sweeping shapes and dtypes per the spec."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import st

from repro.kernels import ops, ref


def _sorted_pairs(vals, ids):
    order = np.argsort(-np.asarray(vals), axis=1, kind="stable")
    return (np.take_along_axis(np.asarray(vals), order, 1),
            np.take_along_axis(np.asarray(ids), order, 1))


@pytest.mark.parametrize("q,k,c", [(1, 1, 1), (3, 5, 17), (16, 10, 128),
                                   (9, 33, 257), (128, 128, 512)])
def test_topk_update_shapes(q, k, c, rng):
    vals = jnp.asarray(rng.normal(size=(q, k)).astype(np.float32))
    ids = jnp.arange(q * k, dtype=jnp.int32).reshape(q, k)
    scores = jnp.asarray(rng.normal(size=(q, c)).astype(np.float32))
    cids = jnp.arange(10_000, 10_000 + c, dtype=jnp.int32)
    kv, ki = ops.topk_update(vals, ids, scores, cids)
    rv, ri = ref.topk_update_ref(vals, ids, scores, cids)
    kvs, kis = _sorted_pairs(kv, ki)
    rvs, ris = _sorted_pairs(rv, ri)
    np.testing.assert_allclose(kvs, rvs, rtol=1e-6)
    np.testing.assert_array_equal(kis, ris)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("q,d,n,k", [(4, 16, 64, 7), (8, 128, 300, 16)])
def test_fused_score_topk(q, d, n, k, dtype, rng):
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    qs = jnp.asarray(rng.normal(size=(q, d))).astype(dtype)
    ds = jnp.asarray(rng.normal(size=(n, d))).astype(dtype)
    fv, fi = ops.fused_score_topk(qs, ds, k, id_offset=3)
    rv, ri = ref.fused_score_topk_ref(qs, ds, k, id_offset=3)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(rv), rtol=tol,
                               atol=tol)
    # id agreement can differ on near-ties under bf16: check score parity
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(fi), np.asarray(ri))


def test_fused_id_offset_traced_no_recompile(rng):
    """The streaming search passes a different id_offset per corpus chunk;
    offsets must shift ids without triggering a recompile per chunk."""
    qs = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    ds = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    v0, i0 = ops.fused_score_topk(qs, ds, 5, id_offset=0)
    before = (ops._fused_jit._cache_size()
              if hasattr(ops._fused_jit, "_cache_size") else None)
    v1, i1 = ops.fused_score_topk(qs, ds, 5, id_offset=1000)
    if before is not None:
        assert ops._fused_jit._cache_size() == before
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0) + 1000)


def test_fused_block_sizes(rng):
    qs = jnp.asarray(rng.normal(size=(10, 32)).astype(np.float32))
    ds = jnp.asarray(rng.normal(size=(500, 32)).astype(np.float32))
    base_v, base_i = ref.fused_score_topk_ref(qs, ds, 9)
    for bq, bn in [(4, 64), (8, 128), (16, 512)]:
        fv, fi = ops.fused_score_topk(qs, ds, 9, bq=bq, bn=bn)
        np.testing.assert_allclose(np.asarray(fv), np.asarray(base_v),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(fi), np.asarray(base_i))


@pytest.mark.parametrize("v,d,b,L", [(20, 8, 5, 3), (100, 32, 16, 10)])
def test_embedding_bag(v, d, b, L, rng):
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-1, v, size=(b, L)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(b, L)).astype(np.float32))
    got = ops.embedding_bag(table, idx, w)
    want = ref.embedding_bag_ref(table, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(q=st.integers(1, 8), d=st.sampled_from([8, 32]),
       n=st.integers(4, 120), k=st.integers(1, 12),
       seed=st.integers(0, 99))
def test_fused_property(q, d, n, k, seed):
    rng = np.random.default_rng(seed)
    k = min(k, n)
    qs = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    ds = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    fv, fi = ops.fused_score_topk(qs, ds, k)
    scores = np.asarray(qs) @ np.asarray(ds).T
    expect = -np.sort(-scores, axis=1)[:, :k]
    np.testing.assert_allclose(np.asarray(fv), expect, rtol=1e-4,
                               atol=1e-5)
    # returned ids index the right scores
    for qi in range(q):
        np.testing.assert_allclose(scores[qi, np.asarray(fi)[qi]],
                                   np.asarray(fv)[qi], rtol=1e-4,
                                   atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 10), L=st.integers(1, 12),
       v=st.sampled_from([16, 64]), seed=st.integers(0, 99))
def test_embedding_bag_property(b, L, v, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(v, 8)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-1, v, size=(b, L)).astype(np.int32))
    got = ops.embedding_bag(table, idx)
    want = ref.embedding_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
