"""ShardedSearchDriver + SimulatedCluster: the multi-node equivalence
matrix (paper §3.5 "same script, any number of nodes").

Every ``score_impl`` × W ∈ {1, 2, 4} simulated workers must reproduce
the seed single-process numpy path: bitwise-identical rankings and
metrics, warm or cold EmbeddingCache, and every worker of a cluster must
return the identical merged result.
"""

import numpy as np
import pytest

from repro.core.collator import RetrievalCollator
from repro.core.config import DataArguments, EvaluationArguments
from repro.core.embedding_cache import EmbeddingCache
from repro.core.evaluator import RetrievalEvaluator
from repro.core.metrics import compute_metrics
from repro.core.sharded_search import ShardedSearchDriver
from repro.data.table import stable_id_hash
from repro.data.tokenizer import HashTokenizer
from repro.launch.distributed import SimulatedCluster

pytestmark = pytest.mark.distributed

SCORE_IMPLS = ("numpy", "jax", "pallas_fused")
WORLD_SIZES = (1, 2, 4)


# -- driver-level tests (synthetic embeddings, no encoder) --------------------


def _load_from(corpus_embs):
    return lambda lo, hi: corpus_embs[lo:hi]


@pytest.fixture()
def synth():
    rng = np.random.default_rng(7)
    q = rng.normal(size=(9, 16)).astype(np.float32)
    docs = rng.normal(size=(230, 16)).astype(np.float32)
    return q, docs


def test_driver_w1_matches_argsort_oracle(synth):
    """A single-worker driver is exactly brute-force top-k."""
    q, docs = synth
    driver = ShardedSearchDriver(score_impl="numpy", chunk_size=37)
    vals, pos = driver.search(q, docs.shape[0], _load_from(docs), 10)
    full = q @ docs.T
    oracle_pos = np.argsort(-full, axis=1, kind="stable")[:, :10]
    np.testing.assert_array_equal(pos, oracle_pos)
    np.testing.assert_allclose(
        vals, np.take_along_axis(full, oracle_pos, 1), rtol=1e-6)


@pytest.mark.parametrize("w", (2, 4))
def test_simulated_cluster_matches_w1(synth, w):
    """W real drivers + in-memory all-gather == the W=1 driver, and all
    ranks return the identical merged result."""
    q, docs = synth
    single = ShardedSearchDriver(score_impl="numpy", chunk_size=37)
    ref_vals, ref_pos = single.search(q, docs.shape[0], _load_from(docs),
                                      10)
    cluster = SimulatedCluster(w)
    drivers = [ShardedSearchDriver(
        n_workers=w, worker_index=rank, sharder=cluster.sharder,
        score_impl="numpy", chunk_size=37, gather=cluster.gather)
        for rank in range(w)]
    outs = cluster.run(
        lambda rank: drivers[rank].search(q, docs.shape[0],
                                          _load_from(docs), 10))
    for vals, pos in outs:
        np.testing.assert_array_equal(pos, ref_pos)
        np.testing.assert_allclose(vals, ref_vals, rtol=1e-6)


def test_prefetch_does_not_change_results(synth):
    q, docs = synth
    outs = {}
    for prefetch in (False, True):
        driver = ShardedSearchDriver(score_impl="numpy", chunk_size=23,
                                     prefetch=prefetch)
        outs[prefetch] = driver.search(q, docs.shape[0], _load_from(docs),
                                       7)
    np.testing.assert_array_equal(outs[True][1], outs[False][1])
    np.testing.assert_array_equal(outs[True][0], outs[False][0])


def test_prefetch_loads_every_chunk_once_in_order(synth):
    q, docs = synth
    calls = []

    def loader(lo, hi):
        calls.append((lo, hi))
        return docs[lo:hi]

    driver = ShardedSearchDriver(score_impl="numpy", chunk_size=50)
    driver.search(q, docs.shape[0], loader, 5)
    assert calls == [(0, 50), (50, 100), (100, 150), (150, 200),
                     (200, 230)]
    assert driver.stats["chunks"] == 5
    assert driver.stats["items"] == 230


def test_cluster_with_fewer_docs_than_workers(synth):
    """total_items < n_workers: empty shards are legal and the merged
    result still matches W=1 (FairSharder regression)."""
    q, docs = synth
    docs = docs[:3]
    single = ShardedSearchDriver(score_impl="numpy", chunk_size=8)
    ref_vals, ref_pos = single.search(q, 3, _load_from(docs), 5)
    cluster = SimulatedCluster(4)
    drivers = [ShardedSearchDriver(
        n_workers=4, worker_index=rank, sharder=cluster.sharder,
        score_impl="numpy", chunk_size=8, gather=cluster.gather)
        for rank in range(4)]
    outs = cluster.run(
        lambda rank: drivers[rank].search(q, 3, _load_from(docs), 5))
    for vals, pos in outs:
        np.testing.assert_array_equal(pos, ref_pos)
        # rtol 1e-5: BLAS low-bit drift between a 3-doc GEMM (W=1) and
        # the single-row dots the 1-doc shards take
        np.testing.assert_allclose(vals, ref_vals, rtol=1e-5)
    # k=5 > 3 docs: the tail must be empty, not garbage
    assert (ref_pos[:, 3:] == -1).all()


def test_cluster_propagates_worker_errors():
    cluster = SimulatedCluster(3)

    def worker(rank):
        if rank == 1:
            raise ValueError("boom on rank 1")
        # healthy ranks block in the gather and must not deadlock when
        # rank 1 aborts the barrier
        from repro.core.result_heap import FastResultHeapq
        return cluster.gather.merge(FastResultHeapq(2, 3), rank)

    with pytest.raises(ValueError, match="boom on rank 1"):
        cluster.run(worker)


def test_round_stable_bounds_under_staggered_updates():
    """A worker reporting its round must not move the shard bounds other
    workers of the same round still have to read (the EMA commits only
    once the whole round has reported)."""
    from repro.core.fair_sharding import FairSharder
    s = FairSharder(2)
    before = s.bounds(1000)
    s.update(0, 500, 0.1)                     # rank 0 finishes first
    assert s.bounds(1000) == before           # rank 1 must see the same
    s.update(1, 500, 10.0)                    # round complete -> commit
    after = s.bounds(1000)
    assert after != before                    # now the EMA has moved
    assert after[0][1] - after[0][0] > after[1][1] - after[1][0]


# -- evaluator-level equivalence matrix (real encoder) ------------------------


@pytest.fixture(scope="module")
def cluster_env(tiny_retriever, tiny_params, retrieval_data,
                tmp_path_factory):
    """Seed single-process numpy reference + a shared warm cache."""
    coll = RetrievalCollator(DataArguments(vocab_size=257),
                             HashTokenizer(257))
    cache = EmbeddingCache(str(tmp_path_factory.mktemp("mncache") / "c"),
                           dim=32)

    def make(score_impl, rank=0, world=1, gather=None, sharder=None):
        # encode_batch_size=20: ragged last chunk for every shard split
        return RetrievalEvaluator(
            EvaluationArguments(topk=10, encode_batch_size=20,
                                score_impl=score_impl,
                                metrics=("ndcg@10", "recall@10")),
            tiny_retriever, coll, tiny_params,
            process_index=rank, process_count=world,
            gather=gather, sharder=sharder)

    ref = make("numpy")
    queries, corpus = retrieval_data["queries"], retrieval_data["corpus"]
    ref.search(queries, corpus, cache=cache)        # warm the cache
    run = ref.search(queries, corpus, cache=cache)  # warm-regime reference
    qrels_h = {
        stable_id_hash(q): {stable_id_hash(d): float(g)
                            for d, g in docs.items()}
        for q, docs in retrieval_data["qrels"].items()}

    def metrics_of(q_hashes, run_ids):
        return compute_metrics(("ndcg@10", "recall@10"), run_ids,
                               q_hashes, qrels_h)

    return {"make": make, "cache": cache, "run": run,
            "metrics": metrics_of(run[0], run[1]),
            "metrics_of": metrics_of}


def _cluster_search(env, score_impl, world, queries, corpus, caches):
    """All ranks' (q_hashes, ids, scores) from a W-worker simulated
    cluster search."""
    if world == 1:
        ev = env["make"](score_impl)
        return [ev.search(queries, corpus, cache=caches[0])]
    cluster = SimulatedCluster(world)
    evs = [env["make"](score_impl, rank, world, cluster.gather,
                       cluster.sharder) for rank in range(world)]
    return cluster.run(
        lambda rank: evs[rank].search(queries, corpus,
                                      cache=caches[rank]))


@pytest.mark.parametrize("world", WORLD_SIZES)
@pytest.mark.parametrize("score_impl", SCORE_IMPLS)
def test_matrix_matches_seed_numpy_path(cluster_env, retrieval_data,
                                        score_impl, world):
    """score_impl × W simulated workers == the seed single-process numpy
    rankings (bitwise ids, allclose scores) and identical metrics, with
    the shared warm cache."""
    queries, corpus = retrieval_data["queries"], retrieval_data["corpus"]
    outs = _cluster_search(cluster_env, score_impl, world, queries, corpus,
                           [cluster_env["cache"]] * world)
    rqh, rids, rvals = cluster_env["run"]
    for qh, ids, vals in outs:          # every rank: identical result
        np.testing.assert_array_equal(qh, rqh)
        np.testing.assert_array_equal(ids, rids)
        np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)
        metrics = cluster_env["metrics_of"](qh, ids)
        for name, want in cluster_env["metrics"].items():
            assert abs(metrics[name] - want) < 1e-9, name


@pytest.mark.parametrize("score_impl", ("numpy", "jax"))
def test_matrix_cold_cache(cluster_env, retrieval_data, tmp_path,
                           score_impl):
    """Cold per-worker caches (each node encodes its own shard, as on a
    real cluster): rankings still match W=1 with a cold cache, and the
    worker caches jointly cover the corpus exactly once."""
    queries, corpus = retrieval_data["queries"], retrieval_data["corpus"]
    ref_cache = EmbeddingCache(str(tmp_path / "w1"), dim=32)
    (ref,) = _cluster_search(cluster_env, score_impl, 1, queries, corpus,
                             [ref_cache])
    caches = [EmbeddingCache(str(tmp_path / f"w2_{r}"), dim=32)
              for r in range(2)]
    outs = _cluster_search(cluster_env, score_impl, 2, queries, corpus,
                           caches)
    for qh, ids, vals in outs:
        np.testing.assert_array_equal(ids, ref[1])
        np.testing.assert_allclose(vals, ref[2], rtol=1e-5, atol=1e-6)
    assert sum(len(c) for c in caches) == len(corpus)


def test_shared_cold_cache_is_thread_safe(cluster_env, retrieval_data,
                                          tmp_path):
    """Workers of one simulated node may share one cache directory: the
    locked append path keeps the id index consistent with the vector
    file (every corpus id lands exactly once and is readable), and warm
    passes over the shared cache are deterministic."""
    queries, corpus = retrieval_data["queries"], retrieval_data["corpus"]
    cache = EmbeddingCache(str(tmp_path / "shared"), dim=32)
    _cluster_search(cluster_env, "jax", 2, queries, corpus, [cache] * 2)
    assert len(cache) == len(corpus)           # disjoint shards, no dupes
    assert cache.get(list(corpus)).shape == (len(corpus), 32)
    warm1 = _cluster_search(cluster_env, "jax", 2, queries, corpus,
                            [cache] * 2)
    warm2 = _cluster_search(cluster_env, "jax", 2, queries, corpus,
                            [cache] * 2)
    np.testing.assert_array_equal(warm1[0][1], warm2[0][1])
    np.testing.assert_array_equal(warm1[0][2], warm2[0][2])
