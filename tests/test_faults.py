"""Fault injection + fault-tolerant shard recovery (core.faults).

The chaos contract (ISSUE 9): every scheduled failure — worker crash,
stalled chunk loads, gather-transport drop, torn cache write — is
reproducible in-process through :class:`FaultInjector`; a resilient
cluster recovers orphaned shards **bitwise-equal** to the no-fault run
(same rows, same kernels, same merge order) across fault × W × index
space; when the retry budget or a request deadline is exhausted the
round degrades to partial coverage instead of raising; and no accepted
serve request is ever dropped or left unresolved.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.embedding_cache import EmbeddingCache
from repro.core.evaluator import IVFSearchSpace
from repro.core.fair_sharding import FairSharder, ShardAborted
from repro.core.faults import (Fault, FaultInjector, InjectedCrash,
                               InjectedTransportDrop, SearchOutcome,
                               WorkerHealth, full_coverage)
from repro.core.serving import ServeFrontend, ServeTimeoutError
from repro.core.sharded_search import ShardedSearchDriver
from repro.launch.distributed import SimulatedCluster
from repro.training.fault_tolerance import resilient_loop

pytestmark = pytest.mark.faults


# -- fixtures -----------------------------------------------------------------


N_DOCS, DIM, N_Q, K = 200, 16, 6, 5
# cluster edges for the IVF-shaped search space: shard cuts snap here
IVF_EDGES = np.array([0, 40, 80, 120, 160, 200], np.int64)


@pytest.fixture()
def synth():
    rng = np.random.default_rng(11)
    q = rng.normal(size=(N_Q, DIM)).astype(np.float32)
    docs = rng.normal(size=(N_DOCS, DIM)).astype(np.float32)
    return q, docs


def _load_from(docs):
    return lambda lo, hi: docs[lo:hi]


def _space(index_impl):
    """The driver's sized ``n_docs`` argument: a plain int for a flat
    scan, an :class:`IVFSearchSpace` (cluster-edge boundaries) for the
    IVF path — dead-worker repartitions must re-snap to these edges."""
    if index_impl == "flat":
        return N_DOCS
    return IVFSearchSpace(N_DOCS, IVF_EDGES)


def _oracle(q, docs, space):
    driver = ShardedSearchDriver(score_impl="numpy", chunk_size=16)
    return driver.search(q, space, _load_from(docs), K)


def _run_cluster(q, docs, space, w, injector, *, deadline_s=None,
                 round_deadline_s=0.15, max_retries=2, backoff_s=0.01,
                 searches=1):
    """W resilient drivers, one shared injector; returns the per-rank
    outs of the last search plus the cluster (for health inspection)."""
    cluster = SimulatedCluster(w, resilient=True)
    drivers = [ShardedSearchDriver(
        n_workers=w, worker_index=rank, sharder=cluster.sharder,
        gather=cluster.gather, score_impl="numpy", chunk_size=16,
        fault_injector=injector, round_deadline_s=round_deadline_s,
        max_shard_retries=max_retries, retry_backoff_s=backoff_s)
        for rank in range(w)]
    outs = None
    for _ in range(searches):
        outs = cluster.run(lambda rank: drivers[rank].search(
            q, space, _load_from(docs), K, deadline_s=deadline_s))
    return outs, cluster


# -- FaultInjector ------------------------------------------------------------


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault(kind="meteor")
    with pytest.raises(ValueError):
        Fault(kind="crash", phase="orbit")
    with pytest.raises(ValueError):
        Fault(kind="torn_write", point="nowhere")


def test_injector_fires_once_and_logs():
    inj = FaultInjector([Fault(kind="crash", worker=1, round=0)])
    inj.on_chunk(0, 0, 0)                   # wrong worker: no fire
    inj.on_chunk(1, 1, 0)                   # wrong round: no fire
    with pytest.raises(InjectedCrash):
        inj.on_chunk(1, 0, 0)
    inj.on_chunk(1, 0, 0)                   # one-shot: spent
    assert inj.fired == [("crash", 1, 0, "load")]


def test_injector_repeat_fires_every_match():
    inj = FaultInjector([Fault(kind="crash", repeat=True)])
    for _ in range(3):
        with pytest.raises(InjectedCrash):
            inj.on_chunk(0, 0, 0)
    assert len(inj.fired) == 3


def test_injector_stall_sleeps_instead_of_raising():
    inj = FaultInjector([Fault(kind="stall", stall_s=0.1)])
    t0 = time.monotonic()
    inj.on_chunk(0, 0, 0)
    assert time.monotonic() - t0 >= 0.09


def test_injector_gather_drop():
    inj = FaultInjector([Fault(kind="drop", worker=2, phase="gather")])
    inj.on_gather(0, 0)
    with pytest.raises(InjectedTransportDrop):
        inj.on_gather(2, 0)


def test_from_seed_is_deterministic():
    a = FaultInjector.from_seed(7, n_workers=4, n_faults=3)
    b = FaultInjector.from_seed(7, n_workers=4, n_faults=3)
    assert a.faults == b.faults
    assert all(f.kind in ("crash", "stall", "drop") for f in a.faults)
    assert all(f.worker in range(4) for f in a.faults)
    c = FaultInjector.from_seed(8, n_workers=4, n_faults=3)
    assert a.faults != c.faults


def test_search_outcome_unpacks_like_a_tuple():
    v, i = np.zeros((2, 3)), np.ones((2, 3), np.int64)
    out = SearchOutcome((v, i), coverage=full_coverage(2))
    a, b = out
    assert a is v and b is i
    assert not out.degraded
    np.testing.assert_array_equal(out.coverage, [1.0, 1.0])


# -- the chaos matrix: fault × W × index space --------------------------------


def _fault_for(kind):
    if kind == "drop":
        return Fault(kind="drop", worker=1, round=0, phase="gather")
    return Fault(kind=kind, worker=1, round=0, phase="load", stall_s=1.0)


@pytest.mark.parametrize("index_impl", ("flat", "ivf"))
@pytest.mark.parametrize("w", (2, 4))
@pytest.mark.parametrize("kind", ("crash", "stall", "drop"))
def test_recovery_is_bitwise_equal_to_no_fault_run(synth, kind, w,
                                                   index_impl):
    """One worker crashes / stalls past the round deadline / loses its
    gather send: survivors rescore the orphaned shard and the merged
    positions are bitwise-equal to the no-fault W=1 oracle, with full
    coverage on every rank."""
    q, docs = synth
    space = _space(index_impl)
    ref_vals, ref_pos = _oracle(q, docs, space)
    inj = FaultInjector([_fault_for(kind)])
    outs, _ = _run_cluster(q, docs, space, w, inj)
    assert inj.fired, f"{kind} fault never fired"
    for out in outs:
        vals, pos = out
        np.testing.assert_array_equal(pos, ref_pos)
        np.testing.assert_allclose(vals, ref_vals, rtol=1e-5)
        np.testing.assert_array_equal(out.coverage, full_coverage(N_Q))
        assert not out.degraded


@pytest.mark.parametrize("index_impl", ("flat", "ivf"))
def test_round_after_crash_repartitions_over_survivors(synth, index_impl):
    """The round *after* a crash: the dead rank gets an exact-zero share
    (bounds re-snapped to cluster edges on the IVF space) and survivors
    still reproduce the oracle."""
    q, docs = synth
    space = _space(index_impl)
    ref_vals, ref_pos = _oracle(q, docs, space)
    inj = FaultInjector([Fault(kind="crash", worker=1, round=0)])
    outs, cluster = _run_cluster(q, docs, space, 4, inj, searches=2)
    assert cluster.health.is_dead(1)
    bounds = cluster.sharder.bounds(
        N_DOCS, IVF_EDGES if index_impl == "ivf" else None)
    lo, hi = bounds[1]
    assert lo == hi, f"dead worker kept a non-empty shard {bounds[1]}"
    if index_impl == "ivf":
        for b in {b for lo_hi in bounds for b in lo_hi}:
            assert b in IVF_EDGES, f"cut {b} not on a cluster edge"
    for out in outs:
        vals, pos = out
        np.testing.assert_array_equal(pos, ref_pos)
        np.testing.assert_array_equal(out.coverage, full_coverage(N_Q))


def test_retry_budget_exhaustion_degrades_with_partial_coverage(synth):
    """Every rescue attempt crashes too: past max_shard_retries the
    round resolves partial — identical on every rank, coverage < 1,
    degraded set — instead of raising."""
    q, docs = synth
    inj = FaultInjector([
        Fault(kind="crash", worker=1, round=0, phase="load"),
        Fault(kind="crash", round=0, phase="retry", repeat=True)])
    outs, _ = _run_cluster(q, docs, N_DOCS, 2, inj, max_retries=1)
    ref = outs[0]
    for out in outs:
        assert out.degraded
        assert (np.asarray(out.coverage) < 1.0).all()
        np.testing.assert_allclose(out.coverage, 0.5)
        np.testing.assert_array_equal(out[1], ref[1])
    # the half that survived is still exact: every returned position
    # comes from worker 0's shard and matches the flat oracle's ranking
    # restricted to that shard
    lo, hi = 0, N_DOCS // 2
    full = q @ docs[lo:hi].T
    oracle_pos = np.argsort(-full, axis=1, kind="stable")[:, :K]
    np.testing.assert_array_equal(ref[1], oracle_pos + lo)


def test_request_deadline_degrades_instead_of_blocking(synth):
    """A crash whose rescuer is itself stalled: waiters hit the request
    deadline and resolve partial NOW (coverage = the shards that did
    arrive) instead of waiting out the stalled recovery."""
    q, docs = synth
    inj = FaultInjector([
        Fault(kind="crash", worker=1, round=0, phase="load"),
        Fault(kind="stall", round=0, phase="retry", stall_s=2.0,
              repeat=True)])
    t0 = time.monotonic()
    outs, _ = _run_cluster(q, docs, N_DOCS, 4, inj, deadline_s=0.4,
                           round_deadline_s=0.05)
    for out in outs:
        assert out.degraded
        assert (np.asarray(out.coverage) < 1.0).all()
        np.testing.assert_array_equal(out[1], outs[0][1])
    # the partial merge resolved near the deadline, not after the stall
    # (cluster.run still joins the stalled rescuer thread afterwards)
    assert time.monotonic() - t0 < 10.0


def test_no_survivor_left_degrades_to_reporting_ranks(synth):
    """Both of a W=2 cluster's recovery paths dead-end (the only
    survivor's rescue crashes repeatedly): partial result, no hang."""
    q, docs = synth
    inj = FaultInjector([
        Fault(kind="crash", worker=0, round=0, phase="load"),
        Fault(kind="crash", round=0, phase="retry", repeat=True)])
    outs, _ = _run_cluster(q, docs, N_DOCS, 2, inj, max_retries=0)
    assert outs[0].degraded
    np.testing.assert_allclose(outs[0].coverage, 0.5)


# -- FairSharder: diagnostics + dead-worker bookkeeping -----------------------


def test_acquire_timeout_raises_with_diagnostics():
    s = FairSharder(2)
    s.ACQUIRE_TIMEOUT_S = 0.1               # instance override
    r0, _ = s.acquire(0, 100)
    assert r0 == 0
    s.update(0, 50, 1.0, round_no=0)
    with pytest.raises(ShardAborted) as ei:
        s.acquire(0, 100)                   # round 1 blocks on worker 1
    msg = str(ei.value)
    assert "round 0" in msg and "workers [1]" in msg
    assert "no round committed yet" in msg


def test_abort_releases_waiters_with_diagnostics():
    s = FairSharder(2)
    s.acquire(0, 100)
    errs = []

    def blocked():
        try:
            s.acquire(0, 100)
        except ShardAborted as e:
            errs.append(e)

    t = threading.Thread(target=blocked)
    t.start()
    time.sleep(0.05)
    boom = RuntimeError("worker 1 exploded")
    s.abort(boom)
    t.join(timeout=5)
    assert not t.is_alive()
    (err,) = errs
    assert "aborted while worker 0 waited for round 1" in str(err)
    assert "pending" in str(err)
    assert err.__cause__ is boom


def test_mark_dead_zeroes_share_and_unblocks_round():
    s = FairSharder(4)
    for w in range(4):
        s.acquire(w, 100)
    for w in (0, 2, 3):
        s.update(w, 25, 1.0, round_no=0)
    s.mark_dead(1)                          # round 0 commits without it
    r, bounds = s.acquire(0, 100)
    assert r == 1
    lo, hi = bounds[1]
    assert lo == hi
    assert sum(b - a for a, b in bounds) == 100


def test_absolve_is_noop_for_committed_rounds():
    s = FairSharder(2)
    s.acquire(0, 10), s.acquire(1, 10)
    s.update(0, 5, 1.0, round_no=0)
    s.update(1, 5, 1.0, round_no=0)
    before = s.throughput.copy()
    s.absolve(0, 0)                         # round 0 already committed
    s.absolve(1, 5)                         # future round: buffered only
    np.testing.assert_array_equal(s.throughput, before)


def test_all_dead_shares_raise():
    s = FairSharder(2)
    s.mark_dead(0)
    s.mark_dead(1)
    with pytest.raises(ShardAborted, match="all 2 workers are dead"):
        s.shares(100)


# -- serve frontend: abandoned / expired / never-dropped ----------------------


def _echo_backend(delay=0.0):
    def run(texts, topk):
        if delay:
            time.sleep(delay)
        qnum = np.asarray([int(t[1:]) for t in texts])
        ids = qnum[:, None] * 100 + np.arange(topk)[None, :]
        return ids, ids.astype(np.float32)

    return run


def test_search_timeout_abandons_request():
    """A timed-out blocking search resolves its Future with
    ServeTimeoutError (never left unresolved) and coalescing skips the
    abandoned request instead of scoring it."""
    release = threading.Event()

    def gated(texts, topk):
        release.wait(5.0)
        return _echo_backend()(texts, topk)

    with ServeFrontend(gated, topk=2, max_batch=8, max_wait_ms=1) as fe:
        blocker = fe.submit("q1")           # occupies the dispatcher
        time.sleep(0.05)
        with pytest.raises(ServeTimeoutError):
            fe.search("q2", timeout=0.05)
        assert fe.stats["abandoned"] == 1
        release.set()
        blocker.result(timeout=10)
        # the abandoned request's Future is resolved, not dangling
        after = fe.submit("q3").result(timeout=10)
        np.testing.assert_array_equal(after[0][:, 0], [300])
    assert fe.stats["completed"] == 2       # q1 + q3, never q2


def test_deadline_ms_expires_queued_request_degraded_empty():
    release = threading.Event()

    def gated(texts, topk):
        release.wait(5.0)
        return _echo_backend()(texts, topk)

    with ServeFrontend(gated, topk=3, max_batch=8, max_wait_ms=1) as fe:
        fe.submit("q1")                     # occupies the dispatcher
        time.sleep(0.05)
        doomed = fe.submit(["q2", "q4"], deadline_ms=10.0)
        time.sleep(0.1)                     # deadline lapses in queue
        release.set()
        out = doomed.result(timeout=10)
        ids, scores = out
        assert out.degraded
        np.testing.assert_array_equal(out.coverage, [0.0, 0.0])
        np.testing.assert_array_equal(ids, -np.ones((2, 3)))
        assert np.all(np.isneginf(scores))
    assert fe.stats["expired"] == 1


def test_no_accepted_request_left_unresolved_under_mixed_deadlines():
    """The no-lost-request property: every accepted Future resolves —
    a real result, a degraded-empty expiry, or ServeTimeoutError —
    none dangle."""
    with ServeFrontend(_echo_backend(delay=0.02), topk=2, max_batch=4,
                       max_wait_ms=1) as fe:
        futs = []
        for i in range(12):
            ddl = 1.0 if i % 3 == 0 else None   # some effectively-instant
            futs.append(fe.submit(f"q{i}", deadline_ms=ddl))
        resolved = 0
        for f in futs:
            try:
                f.result(timeout=10)
                resolved += 1
            except ServeTimeoutError:
                resolved += 1
        assert resolved == len(futs)
    st = fe.stats
    assert st["completed"] + st["expired"] == st["accepted"]


def test_deadline_ms_validation():
    with ServeFrontend(_echo_backend(), topk=2, max_batch=4,
                       max_wait_ms=1) as fe:
        with pytest.raises(ValueError):
            fe.submit("q1", deadline_ms=0)
        with pytest.raises(ValueError):
            fe.submit("q1", deadline_ms=-5)


# -- WorkerHealth + the shared Heartbeat --------------------------------------


def test_heartbeat_requires_path_or_sink():
    from repro.training.fault_tolerance import Heartbeat
    with pytest.raises(ValueError):
        Heartbeat()


def test_heartbeat_feeds_worker_health_staleness():
    """One Heartbeat implementation serves training (file sink) and
    serving (WorkerHealth sink): a beating worker never goes stale, a
    silent one does."""
    health = WorkerHealth(2, stale_after_s=0.2)
    with health.heartbeat(0, interval=0.05):
        time.sleep(0.35)
        assert not health.failed(0)         # beats keep it fresh
        assert health.failed(1)             # silent since construction
    assert health.live() == [0, 1]          # stale != dead
    health.mark_dead(1)
    assert health.is_dead(1)
    assert health.dead == {1}
    assert health.live() == [0]
    assert health.failed(1)


def test_heartbeat_file_sink_still_writes(tmp_path):
    from repro.training.fault_tolerance import Heartbeat
    import json
    path = str(tmp_path / "hb.json")
    with Heartbeat(path, interval=10.0) as hb:
        hb.update(42)
    payload = json.load(open(path))
    assert payload["step"] == 42 and "time" in payload


# -- resilient_loop (training retry loop, previously uncovered) ---------------


def test_resilient_loop_completes_without_failures():
    seen = []
    end = resilient_loop(seen.append, 0, 5, on_failure=lambda e: 0)
    assert end == 5 and seen == [0, 1, 2, 3, 4]


def test_resilient_loop_restores_and_resumes():
    calls, failed = [], []

    def step(i):
        calls.append(i)
        if i == 2 and not failed:
            raise RuntimeError("transient")

    def on_failure(e):
        failed.append(e)
        return 1                            # "restore" to step 1

    end = resilient_loop(step, 0, 4, on_failure)
    assert end == 4
    assert calls == [0, 1, 2, 1, 2, 3]      # resumed from the restore
    assert len(failed) == 1


def test_resilient_loop_gives_up_after_max_consecutive_failures():
    def step(i):
        raise RuntimeError("persistent")

    with pytest.raises(RuntimeError, match="persistent"):
        resilient_loop(step, 0, 3, on_failure=lambda e: 0,
                       max_failures=2)


def test_resilient_loop_does_not_swallow_interrupts():
    def step(i):
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        resilient_loop(step, 0, 3, on_failure=lambda e: 0)


# -- EmbeddingCache torn writes through the injector --------------------------


def _fill(cache, n, seed=0, prefix="d"):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, cache.dim)).astype(np.float32)
    ids = [f"{prefix}{i}" for i in range(n)]
    cache.cache_records(ids, vecs)
    return ids, vecs


def test_torn_write_mid_append_recovers_to_committed_state(tmp_path):
    """Crash between the vector payload and the id-index append: the
    reopened cache trusts meta['n'], truncates the torn payload bytes,
    and the next append lands with correct row alignment."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    ids, vecs = _fill(cache, 10)
    cache.fault_injector = FaultInjector(
        [Fault(kind="torn_write", phase="cache", point="payload")])
    with pytest.raises(InjectedCrash):
        _fill(cache, 4, seed=1, prefix="x")
    assert cache.fault_injector.fired == [
        ("torn_write", None, None, "cache:payload")]
    # torn on disk: payload grew, id index did not
    import os
    vec_bytes = os.path.getsize(tmp_path / "c" / "vectors.bin")
    ids_bytes = os.path.getsize(tmp_path / "c" / "ids.bin")
    assert vec_bytes == 14 * 8 * cache.dtype.itemsize
    assert ids_bytes == 10 * 8                    # id append never ran

    reopened = EmbeddingCache(str(tmp_path / "c"), dim=8)
    assert len(reopened) == 10
    np.testing.assert_allclose(reopened.get(ids), vecs, atol=1e-2)
    ids2, vecs2 = _fill(reopened, 4, seed=2, prefix="y")
    assert len(reopened) == 14
    np.testing.assert_allclose(reopened.get(ids2), vecs2, atol=1e-2)
    np.testing.assert_allclose(reopened.get(ids), vecs, atol=1e-2)


def test_torn_write_before_meta_commit_recovers(tmp_path):
    """Crash after both payload appends but before the atomic meta.json
    replace: the rows exist on disk but were never committed — the
    reopened cache ignores and truncates them."""
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    ids, vecs = _fill(cache, 6)
    cache.fault_injector = FaultInjector(
        [Fault(kind="torn_write", phase="cache", point="meta")])
    with pytest.raises(InjectedCrash):
        _fill(cache, 3, seed=1, prefix="x")

    reopened = EmbeddingCache(str(tmp_path / "c"), dim=8)
    assert len(reopened) == 6
    assert not reopened.has([f"x{i}" for i in range(3)]).any()
    ids2, vecs2 = _fill(reopened, 3, seed=2, prefix="y")
    assert len(reopened) == 9
    np.testing.assert_allclose(reopened.get(ids2), vecs2, atol=1e-2)


# -- compaction chaos: crash/stall at every compaction injection point --------


def _mutated_cache(tmp_path, layout):
    """A cache with superseded rows and tombstones — real work for the
    compactor — plus (for ``layout="ivf"``) the cluster-sorted
    permutation compaction should lay the live rows out in."""
    from repro.index.ivf import cluster_order
    cache = EmbeddingCache(str(tmp_path / "c"), dim=8)
    _fill(cache, 24)
    cache.delete_records(["d3", "d10"])
    cache.cache_records(["d5"], np.full((1, 8), 2.0, np.float32))
    order = None
    if layout == "ivf":
        snap = cache.snapshot()
        order = cluster_order(
            lambda lo, hi: snap.get_range(lo, hi).astype(np.float32),
            snap.n_live, 4, train_steps=4, train_batch=8)
        snap.close()
    return cache, order


def _live_view(cache):
    """(ids, vectors) of the live set, sorted by id — layout-independent
    content equality across compaction/reopen."""
    snap = cache.snapshot()
    order = np.argsort(snap.ids)
    ids = snap.ids[order].copy()
    vecs = snap.get_rows(order).copy()
    snap.close()
    return ids, vecs


@pytest.mark.parametrize("w", (1, 2))
@pytest.mark.parametrize("layout", ("flat", "ivf"))
@pytest.mark.parametrize("point", ("compact_payload", "compact_meta",
                                   "compact_swap"))
def test_compaction_crash_reopens_to_one_generation(tmp_path, point,
                                                    layout, w):
    """Crash at every compaction injection point: reopen lands on
    exactly the pre- or post-compaction generation (one epoch's payload
    files on disk, never a torn hybrid), zero committed records are
    lost, and a W-worker search over the reopened cache matches the
    flat-scan oracle."""
    import os
    cache, order = _mutated_cache(tmp_path, layout)
    gen0 = cache.generation
    want_ids, want_vecs = _live_view(cache)
    cache.fault_injector = FaultInjector(
        [Fault(kind="torn_write", phase="cache", point=point)])
    with pytest.raises(InjectedCrash):
        cache.compact(order=order)
    assert cache.fault_injector.fired == [
        ("torn_write", None, None, f"cache:{point}")]

    reopened = EmbeddingCache(str(tmp_path / "c"), dim=8)
    # a single consistent generation: pre-compaction for the payload /
    # meta crashes, post-compaction once the meta swap landed
    want_epoch = 1 if point == "compact_swap" else 0
    assert reopened.epoch == want_epoch
    assert reopened.generation == gen0
    # exactly one epoch's payload files remain (strays swept on open)
    names = sorted(os.listdir(tmp_path / "c"))
    vec_files = [f for f in names if f.startswith("vectors")]
    want_vec = "vectors.bin" if want_epoch == 0 else "vectors.e1.bin"
    assert vec_files == [want_vec], names
    # zero lost committed records
    got_ids, got_vecs = _live_view(reopened)
    np.testing.assert_array_equal(got_ids, want_ids)
    np.testing.assert_array_equal(got_vecs, want_vecs)

    # the reopened cache serves a W-worker search bitwise-matching the
    # single-worker oracle over the same snapshot
    snap = reopened.snapshot()
    docs = snap.get_range(0, snap.n_live).astype(np.float32)
    snap.close()
    rng = np.random.default_rng(3)
    q = rng.normal(size=(4, 8)).astype(np.float32)
    ref_vals, ref_pos = ShardedSearchDriver(
        score_impl="numpy", chunk_size=16).search(
            q, len(docs), _load_from(docs), K)
    if w == 1:
        outs = [ShardedSearchDriver(score_impl="numpy", chunk_size=8)
                .search(q, len(docs), _load_from(docs), K)]
    else:
        cluster = SimulatedCluster(w)
        drivers = [ShardedSearchDriver(
            n_workers=w, worker_index=rank, sharder=cluster.sharder,
            gather=cluster.gather, score_impl="numpy", chunk_size=8)
            for rank in range(w)]
        outs = cluster.run(lambda rank: drivers[rank].search(
            q, len(docs), _load_from(docs), K))
    for vals, pos in outs:
        np.testing.assert_array_equal(pos, ref_pos)
        np.testing.assert_array_equal(vals, ref_vals)


@pytest.mark.parametrize("point", ("compact_payload", "compact_meta",
                                   "compact_swap"))
def test_compaction_stall_keeps_pinned_readers_serving(tmp_path, point):
    """A stalled disk mid-compaction must not block pinned readers:
    snapshot reads resolve through the frozen (rows, mmap) pair without
    taking the writer lock, so they stream bit-identical rows all the
    way through the stall."""
    cache, _ = _mutated_cache(tmp_path, "flat")
    cache.fault_injector = FaultInjector(
        [Fault(kind="stall", phase="cache", point=point, stall_s=0.3)])
    snap = cache.snapshot()
    first = snap.get_range(0, snap.n_live).copy()
    reads = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            reads.append(snap.get_range(0, snap.n_live).copy())
            time.sleep(0.01)

    t = threading.Thread(target=reader)
    t.start()
    try:
        t0 = time.monotonic()
        stats = cache.compact()
        dt = time.monotonic() - t0
    finally:
        stop.set()
        t.join()
    assert dt >= 0.29, dt                 # the stall really fired
    assert stats["epoch"] == 1
    assert len(reads) >= 10               # readers ran during the stall
    for r in reads:
        np.testing.assert_array_equal(r, first)
    # the pin still serves the retired epoch after compaction completes
    np.testing.assert_array_equal(snap.get_range(0, snap.n_live), first)
    snap.close()
