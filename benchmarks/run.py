"""Benchmark harness — one entry per paper table (+ kernel benches).

Prints ``name,us_per_call,derived`` CSV rows (see DESIGN.md §7 index):
  Table 1  memory: naive vs Trove data management, plus the ConcatView
           combined-corpus streaming variant (+ results/*.json)
  Table 2  multi-node inference scaling (simulated nodes)
  Table 3  Python heapq vs FastResultHeapq (online / cached)
  Table 4  time-to-first-sample, first vs warm run
  kernels  fused score+top-k HBM-traffic reduction
  search   score_impl backends: host-numpy baseline vs device paths
  multinode  ShardedSearchDriver scaling W=1,2,4 (+ results/*.json)
  dispatch  per-chunk streaming vs superchunk scan (+ results/*.json)
  encode   legacy per-batch padding vs bucketed pipeline (+ results/*.json)
  serve    sequential per-request loop vs continuous-batching frontend
           QPS/p50/p99 curve over submitter concurrency (+ results/*.json)
  ivf      flat exhaustive scan vs IVF cluster-pruned search: recall@10
           vs speedup over the nprobe sweep (+ results/*.json)
  mutation serve QPS/p99 under sustained live corpus mutation vs a
           frozen corpus, compaction pause, post-compaction scan
           speedup (+ results/*.json)

``run.py --check [--tol T]`` re-runs the JSON-emitting benches into a
scratch dir and compares their key metrics against the committed
baselines in ``results/`` — exits nonzero on regression.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (bench_dispatch, bench_encode, bench_faults,
                            bench_ivf, bench_kernels, bench_memory,
                            bench_multinode, bench_mutation,
                            bench_result_heap, bench_scaling,
                            bench_search_backends, bench_serve,
                            bench_ttfs)
    bench_result_heap.run()
    bench_scaling.run()
    bench_ttfs.run()
    bench_memory.run()
    bench_kernels.run()
    bench_search_backends.run()
    bench_multinode.run()
    bench_dispatch.run()
    bench_encode.run()
    bench_serve.run()
    bench_ivf.run()
    bench_faults.run()
    bench_mutation.run()


if __name__ == "__main__":
    from benchmarks.check import main as check_main
    if "--check" in sys.argv[1:]:
        sys.exit(check_main(sys.argv[1:]))
    main()
