"""Paper Table 3: Python heapq vs FastResultHeapq.

Two regimes, as in the paper:
  * online — small doc chunks (256) arriving during encoding
  * cached — large chunks (4096+) streamed from the embedding cache
Reports us/update-call and the speedup factor.
"""

import numpy as np

from benchmarks.common import emit, time_call
from repro.core.result_heap import FastResultHeapq


def _bench(impl: str, q: int, k: int, chunk: int, n_chunks: int,
           iters: int = 3) -> float:
    rng = np.random.default_rng(0)
    chunks = [(rng.normal(size=(q, chunk)).astype(np.float32),
               np.arange(i * chunk, (i + 1) * chunk, dtype=np.int32))
              for i in range(n_chunks)]

    def run():
        h = FastResultHeapq(q, k, impl=impl)
        for s, i in chunks:
            h.update(s, i)
        h.finalize()

    us_total = time_call(run, warmup=1, iters=iters)
    return us_total / n_chunks          # per update call


def run():
    results = {}
    for regime, (q, chunk, n_chunks) in {
            "online": (64, 256, 12), "cached": (256, 4096, 6)}.items():
        k = 100
        py = _bench("python", q, k, chunk, n_chunks, iters=1)
        jx = _bench("jax", q, k, chunk, n_chunks)
        emit(f"table3_heap_python_{regime}", py, f"q={q} chunk={chunk}")
        emit(f"table3_heap_trove_{regime}", jx,
             f"speedup={py / jx:.0f}x")
        results[regime] = py / jx
    return results


if __name__ == "__main__":
    run()
