"""Paper Table 4: time-to-first-sample (TTFS), first vs subsequent runs.

Trove builds fingerprinted mmap tables + grouped qrels on the first run;
afterwards the data is available nearly instantly.
"""

import os
import shutil
import tempfile
import time

from benchmarks.common import emit
from repro.core.config import DataArguments, MaterializedQRelConfig
from repro.core.datasets import BinaryDataset
from repro.data.synthetic import make_retrieval_dataset


def _ttfs(data_dir, cache_root) -> float:
    cfg = MaterializedQRelConfig(
        qrel_path=f"{data_dir}/qrels/train.tsv",
        query_path=f"{data_dir}/queries.jsonl",
        corpus_path=f"{data_dir}/corpus.jsonl", min_score=1)
    t0 = time.monotonic()
    ds = BinaryDataset(DataArguments(group_size=2), lambda t: t,
                       lambda t, title="": t, cfg, cfg,
                       cache_root=cache_root)
    _ = ds[0]
    return time.monotonic() - t0


def run(n_docs: int = 40_000, n_queries: int = 3_000):
    d = os.path.join(tempfile.gettempdir(), "trove_bench_ttfs")
    if not os.path.exists(os.path.join(d, "queries.jsonl")):
        os.makedirs(d, exist_ok=True)
        make_retrieval_dataset(d, n_queries=n_queries, n_docs=n_docs,
                               n_topics=256, doc_len=60)
    cache = os.path.join(d, "cache")
    shutil.rmtree(cache, ignore_errors=True)
    first = _ttfs(d, cache)
    warm = _ttfs(d, cache)
    emit("table4_ttfs_first_run", first * 1e6, f"{first:.2f}s")
    emit("table4_ttfs_warm_run", warm * 1e6,
         f"{warm:.3f}s ({first / max(warm, 1e-9):.0f}x faster)")
    return {"first_s": first, "warm_s": warm}


if __name__ == "__main__":
    run()
