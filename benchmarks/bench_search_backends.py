"""Search scoring backends: host-numpy baseline vs the device paths.

Measures the scoring phase of ``RetrievalEvaluator.search`` (encoder
factored out): streaming synthetic corpus-embedding chunks into a
FastResultHeapq through each ``EvaluationArguments.score_impl`` backend.
numpy and jax are timed *interleaved* (alternating iterations) so system
drift on small shared machines hits both backends equally.

Two regimes, matching where chunks come from in the real pipeline:
  * cached — chunks arrive as host numpy arrays (the mmap'd
    EmbeddingCache path); device backends pay the h2d embedding copy
  * online — chunks arrive device-resident (encoder output); the numpy
    baseline pays d2h(embs) + host GEMM + h2d(scores) per chunk

``pallas_fused`` executes in interpret mode on CPU (semantics
validation; its perf target is the TPU Mosaic path, where the (Q,N)
score matrix never reaches HBM), so it is timed once on a reduced
corpus and the headline device-vs-host ratio is reported for the
``jax`` backend.
"""

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.sharded_search import SCORE_BACKENDS
from repro.core.result_heap import FastResultHeapq


def _search(backend, q_emb, chunks, chunk: int, q: int, k: int):
    heap = FastResultHeapq(q, k, impl="jax")
    for i, embs in enumerate(chunks):
        backend(q_emb, embs, i * chunk, heap, k)
    return heap.finalize()


def run(q: int = 512, d: int = 128, n: int = 32_768, k: int = 100,
        chunk: int = 4_096, iters: int = 6, include_fused: bool = True):
    rng = np.random.default_rng(0)
    q_np = rng.normal(size=(q, d)).astype(np.float32)
    c_np = rng.normal(size=(n, d)).astype(np.float32)
    q_dev = jnp.asarray(q_np)
    chunks_np = [c_np[o: o + chunk] for o in range(0, n, chunk)]
    chunks_dev = [jnp.asarray(c) for c in chunks_np]

    # one-time sanity: the backends being compared return the same ranking
    _, ids_np = _search(SCORE_BACKENDS["numpy"], q_np, chunks_np, chunk,
                        q, k)
    _, ids_jx = _search(SCORE_BACKENDS["jax"], q_dev, chunks_np, chunk,
                        q, k)
    np.testing.assert_array_equal(ids_np, ids_jx)

    results = {}
    shape = f"q={q} n={n} d={d} k={k} chunk={chunk}"
    for regime, chunks in {"cached": chunks_np, "online": chunks_dev}.items():
        _search(SCORE_BACKENDS["numpy"], q_np, chunks, chunk, q, k)
        _search(SCORE_BACKENDS["jax"], q_dev, chunks, chunk, q, k)
        t_np = t_jx = 0.0
        for _ in range(iters):
            t0 = time.monotonic()
            _search(SCORE_BACKENDS["numpy"], q_np, chunks, chunk, q, k)
            t_np += time.monotonic() - t0
            t0 = time.monotonic()
            _search(SCORE_BACKENDS["jax"], q_dev, chunks, chunk, q, k)
            t_jx += time.monotonic() - t0
        us_np = t_np / iters * 1e6
        us_jx = t_jx / iters * 1e6
        emit(f"search_backend_{regime}_numpy", us_np, shape)
        emit(f"search_backend_{regime}_jax", us_jx, shape)
        emit(f"search_backend_{regime}_jax_speedup", us_jx,
             f"{us_np / us_jx:.2f}x vs host numpy")
        results[regime] = us_np / us_jx

    if include_fused:
        # reduced corpus: interpret mode emulates the TPU kernel on CPU
        small = chunks_dev[:2]
        us = time_call(
            lambda: _search(SCORE_BACKENDS["pallas_fused"], q_dev, small,
                            chunk, q, k), warmup=1, iters=1)
        emit("search_backend_pallas_fused_interpret", us,
             f"q={q} n={2 * chunk} d={d} interpret-mode semantics check")
    return results


if __name__ == "__main__":
    run()
