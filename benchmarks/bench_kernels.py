"""Kernel-level benchmark (beyond-paper §Perf support): fused score+top-k
vs unfused (GEMM -> HBM -> top_k) on the XLA path, plus derived HBM-bytes
reduction for the TPU target.

Wall-times here are XLA:CPU (the Pallas kernel itself is validated in
interpret mode and benchmarked structurally); the derived column reports
the HBM traffic each strategy implies on TPU — the quantity the fused
kernel optimizes.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels import ops, ref


def run(q: int = 256, d: int = 512, n: int = 65_536, k: int = 100):
    rng = np.random.default_rng(0)
    qs = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    ds = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    unfused = jax.jit(lambda a, b: ref.fused_score_topk_ref(a, b, k))

    def run_unfused():
        jax.block_until_ready(unfused(qs, ds))

    us = time_call(run_unfused, warmup=2, iters=5)
    # HBM bytes: unfused writes+reads the (q, n) score matrix
    unfused_bytes = q * n * 4 * 2 + n * d * 4 + q * k * 8
    fused_bytes = n * d * 4 + q * k * 8
    emit("kernel_score_topk_unfused", us,
         f"hbm_bytes={unfused_bytes / 1e6:.0f}MB")
    emit("kernel_score_topk_fused_derived", us,
         f"hbm_bytes={fused_bytes / 1e6:.0f}MB "
         f"({unfused_bytes / fused_bytes:.1f}x less HBM traffic)")

    # interpret-mode wall time on a reduced shape: validates the streaming
    # (per-chunk id_offset, no recompile) path the evaluator drives; the
    # number is NOT the TPU perf (Mosaic compiles the same kernel there)
    sq, sn = qs[:32], ds[:4096]

    def run_fused_interp():
        jax.block_until_ready(
            ops.fused_score_topk(sq, sn, k, id_offset=17))

    fus = time_call(run_fused_interp, warmup=1, iters=2)
    emit("kernel_score_topk_fused_interpret", fus,
         f"q=32 n=4096 interpret-mode (CPU semantics check)")
    return {"reduction": unfused_bytes / fused_bytes}


if __name__ == "__main__":
    run()
