"""IVF cluster-pruned search: recall@k vs speedup curve (nprobe sweep).

The flat exhaustive scan is the recall oracle; the IVF path scans only
each query batch's top-``nprobe`` clusters through the *same*
``ShardedSearchDriver`` superchunk executor.  This bench builds a
synthetic clustered corpus (unit-norm Gaussian topic centers, docs =
normalized center + noise — the regime ANN pruning is for), trains the
coarse quantizer once, then sweeps nprobe from 1 to n_clusters
measuring per-round wall time and recall@k against the flat ranking.

Reported to ``results/bench_ivf.json`` for ``run.py --check``:

  * ``speedup_at_recall95`` — best flat/ivf throughput ratio among
    sweep points with recall@10 >= 0.95 (the ISSUE gate: >= 2x).
  * ``recall_quarter_probe`` — recall@10 at nprobe = n_clusters / 4.
  * ``ivf_full_probe_bitwise`` — 1.0 iff nprobe == n_clusters returns
    exactly the flat ids and scores (structural, no tolerance).
  * ``ivf_n_clusters`` — sweep structure (structural).

Both paths pay the same driver/kernel dispatch machinery, so the curve
isolates what pruning buys, not executor differences.
"""

import json
import os
import time

import numpy as np

from benchmarks.common import emit

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_ivf.json")


def _make_corpus(n_docs: int, dim: int, n_topics: int, n_queries: int,
                 seed: int = 0):
    """Clustered unit-norm corpus + queries near random docs."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_topics, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    topic = rng.integers(0, n_topics, size=n_docs)
    docs = centers[topic] + 0.15 * rng.normal(
        size=(n_docs, dim)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    anchors = rng.choice(n_docs, size=n_queries, replace=False)
    queries = docs[anchors] + 0.05 * rng.normal(
        size=(n_queries, dim)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    return docs, queries.astype(np.float32)


def _time_rounds(search_round, rounds: int) -> float:
    """Best-of-``rounds`` seconds per search round (first call outside —
    compile/warm happens before timing)."""
    best = float("inf")
    for _ in range(rounds):
        t0 = time.monotonic()
        search_round()
        best = min(best, time.monotonic() - t0)
    return best


def run(n_docs: int = 32768, dim: int = 64, n_clusters: int = 64,
        n_topics: int = 64, n_queries: int = 64, query_batch: int = 4,
        topk: int = 10, chunk_size: int = 512, rounds: int = 3,
        out_json: str = DEFAULT_JSON):
    from repro.core.evaluator import IVFPreparedCorpus
    from repro.core.sharded_search import ShardedSearchDriver
    from repro.index import IVFIndex

    docs, queries = _make_corpus(n_docs, dim, n_topics, n_queries)
    # id hash = corpus position: recall bookkeeping stays trivial and
    # the driver/kernel path is identical to real hashed corpora
    hashes = np.arange(n_docs, dtype=np.int64)
    # the serving regime: cluster pruning is per query batch (the union
    # of the batch's probed clusters), so it pays off for the small
    # coalesced micro-batches a frontend dispatches — measure those
    batches = [queries[lo: lo + query_batch]
               for lo in range(0, n_queries, query_batch)]

    def make_driver():
        return ShardedSearchDriver(score_impl="jax", heap_impl="jax",
                                   chunk_size=chunk_size)

    # -- flat oracle ---------------------------------------------------------
    driver = make_driver()

    def flat_pass():
        out = []
        for q in batches:
            vals, pos = driver.search(q, n_docs,
                                      lambda lo, hi: docs[lo:hi], topk)
            out.append((vals, pos))
        return out

    flat_out = flat_pass()                              # warm + oracle
    flat_vals = np.concatenate([v for v, _ in flat_out])
    flat_pos = np.concatenate([p for _, p in flat_out])
    flat_ids = np.where(flat_pos >= 0, hashes[np.clip(flat_pos, 0, None)],
                        -1)
    flat_s = _time_rounds(flat_pass, rounds)
    flat_qps = n_queries / flat_s
    emit("ivf_flat_scan", flat_s * 1e6 / n_queries,
         f"qps={flat_qps:.0f} docs={n_docs} batch={query_batch}")

    # -- IVF sweep -----------------------------------------------------------
    t0 = time.monotonic()
    index = IVFIndex.build(lambda lo, hi: docs[lo:hi], n_docs, n_clusters,
                           seed=0, train_steps=40, train_batch=1024)
    build_s = time.monotonic() - t0
    emit("ivf_build", build_s * 1e6,
         f"k={index.n_clusters} sizes [{index.cluster_sizes().min()}, "
         f"{index.cluster_sizes().max()}]")

    nprobe = 1
    sweep_points = []
    while nprobe <= n_clusters:
        sweep_points.append(nprobe)
        nprobe *= 2
    if sweep_points[-1] != n_clusters:
        sweep_points.append(n_clusters)

    sweep = []
    for nprobe in sweep_points:
        prepared = IVFPreparedCorpus(hashes, n_docs,
                                     lambda rows: docs[rows], index,
                                     nprobe)
        driver = make_driver()

        def ivf_pass():
            out_i, out_v = [], []
            for q in batches:
                sized, load_chunk, to_ids = prepared.round_for(q)
                vals, pos = driver.search(q, sized, load_chunk, topk)
                out_i.append(to_ids(pos))
                out_v.append(vals)
            return np.concatenate(out_i), np.concatenate(out_v)

        ids, vals = ivf_pass()                          # warm
        ivf_s = _time_rounds(ivf_pass, rounds)
        recall = float(np.mean([
            len(set(f[f >= 0].tolist()) & set(r[r >= 0].tolist())) / topk
            for f, r in zip(flat_ids, ids)]))
        scanned = float(np.mean(
            [len(prepared.round_for(q)[0]) for q in batches])) / n_docs
        speedup = flat_s / ivf_s
        bitwise = bool(np.array_equal(ids, flat_ids)
                       and np.array_equal(vals, flat_vals))
        emit(f"ivf_nprobe_{nprobe}", ivf_s * 1e6 / n_queries,
             f"recall@{topk}={recall:.3f} speedup={speedup:.2f}x "
             f"scanned={scanned:.2f}")
        sweep.append({"nprobe": nprobe, "recall": recall,
                      "speedup": speedup, "qps": n_queries / ivf_s,
                      "scanned_fraction": scanned,
                      "bitwise_vs_flat": bitwise})

    good = [p for p in sweep if p["recall"] >= 0.95]
    full = sweep[-1]
    assert full["nprobe"] == n_clusters
    payload = {
        "name": "bench_ivf",
        "shape": f"docs={n_docs} dim={dim} k={n_clusters} "
                 f"topics={n_topics} queries={n_queries} "
                 f"batch={query_batch} topk={topk} chunk={chunk_size}",
        "flat": {"seconds_per_round": flat_s, "qps": flat_qps},
        "build_seconds": build_s,
        "n_clusters": n_clusters,
        "sweep": sweep,
        "headline": {
            "speedup_at_recall95": max((p["speedup"] for p in good),
                                       default=0.0),
            "recall_quarter_probe": next(
                (p["recall"] for p in sweep
                 if p["nprobe"] == max(n_clusters // 4, 1)), 0.0),
            "ivf_full_probe_bitwise": float(full["bitwise_vs_flat"]),
            "ivf_n_clusters": float(n_clusters),
        },
    }
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    run()
