"""Serve throughput and latency under sustained live corpus mutation.

A generation-versioned ``EmbeddingCache`` serves search rounds through
pinned snapshots while a writer thread continuously adds, re-caches,
and tombstones documents.  The bench records four things:

  * steady-state round QPS / p99 with a *frozen* corpus (the baseline);
  * QPS / p99 of the same round loop under *sustained mutation*, where
    every round pins the newest generation — and every round's results
    are replayed bitwise against a no-mutation oracle over a frozen
    copy of that round's snapshot (snapshot isolation is the structural
    guarantee, so ``oracle_bitwise`` is exact in the check gate);
  * the compaction "pause": a pinned reader fires tiny snapshot reads
    while a background ``compact()`` rewrites the fragmented log, and
    the median during-compaction read is compared against the idle
    median — pinned readers never block on the rewrite, so the ratio
    stays ~1 (a blocking rewrite would stall every probe);
  * full-scan throughput before vs after compaction: the mutated log
    is fragmented (live rows resolve through a row map), compaction
    restores the contiguous fast path, so the post/pre speedup is >= 1.

Emits CSV rows and ``results/bench_mutation.json`` (gated by
``benchmarks/run.py --check``: the bitwise / resolved fractions are
exact, timing ratios get the usual noise tolerance).
"""

import json
import os
import shutil
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import emit, time_call
from repro.core.embedding_cache import EmbeddingCache
from repro.core.sharded_search import ShardedSearchDriver

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_mutation.json")

N_DOCS, DIM, N_Q, K = 4096, 64, 16, 10
CHUNK = 256
FROZEN_ROUNDS = 12
LIVE_ROUNDS = 12


def _fill(cache, rng):
    ids = [f"doc-{i}" for i in range(N_DOCS)]
    vecs = rng.normal(size=(N_DOCS, DIM)).astype(np.float32)
    cache.cache_records(ids, vecs)


def _round(driver, snap, q):
    """One search round over a pinned snapshot; returns (s, ids, pos)."""
    load = lambda lo, hi: snap.get_range(lo, hi)          # noqa: E731
    t0 = time.monotonic()
    vals, pos = driver.search(q, snap.n_live, load, K)
    return time.monotonic() - t0, vals, pos


class _Writer:
    """Background mutator: add / re-cache / tombstone in a tight loop."""

    def __init__(self, cache, rng):
        self.cache, self.ops = cache, 0
        self._vec = rng.normal(size=(1, DIM)).astype(np.float32)
        self._stop = threading.Event()
        self._err = None
        self._t = threading.Thread(target=self._loop, name="bench-mutate",
                                   daemon=True)

    def _loop(self):
        i = 0
        try:
            while not self._stop.is_set():
                self.cache.cache_records([f"live-{i}"], self._vec)
                self.cache.cache_records([f"doc-{i % N_DOCS}"], self._vec)
                if i % 2 == 1:
                    self.cache.delete_records([f"live-{i - 1}"])
                self.ops += 3
                i += 1
                self._stop.wait(0.005)
        except Exception as exc:            # surfaced on join
            self._err = exc

    def __enter__(self):
        self._t.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._t.join(timeout=30.0)
        if self._err is not None:
            raise self._err


def _scan_us(snap):
    """Full-corpus chunked scan (the bulk-encode / index-build read
    pattern), best-of-7 microseconds per sweep (min, not mean: the
    scan is ~100us so scheduler noise dominates a mean)."""
    def sweep():
        for lo in range(0, snap.n_live, CHUNK):
            snap.get_range(lo, min(lo + CHUNK, snap.n_live))
    sweep()                                               # fault pages in
    return min(time_call(sweep, warmup=0, iters=1) for _ in range(7))


def run(out_json: str = DEFAULT_JSON) -> dict:
    rng = np.random.default_rng(0)
    q = rng.normal(size=(N_Q, DIM)).astype(np.float32)
    tmp = tempfile.mkdtemp(prefix="bench_mutation_")
    try:
        cache = EmbeddingCache(os.path.join(tmp, "cache"), DIM,
                               dtype=np.float32)
        _fill(cache, rng)
        driver = ShardedSearchDriver(score_impl="numpy", chunk_size=CHUNK)
        oracle = ShardedSearchDriver(score_impl="numpy", chunk_size=CHUNK)

        # -- phase A: frozen baseline -----------------------------------------
        frozen_s = []
        with cache.snapshot() as snap:
            for _ in range(FROZEN_ROUNDS + 1):        # +1 warmup round
                frozen_s.append(_round(driver, snap, q)[0])
        frozen_s = frozen_s[1:]

        # -- phase B: sustained mutation, oracle-checked ----------------------
        live_s, bitwise, resolved, gens = [], 0, 0, set()
        with _Writer(cache, rng) as writer:
            for _ in range(LIVE_ROUNDS):
                snap = cache.snapshot()               # pin newest generation
                gens.add(snap.key)
                frozen = snap.get_range(0, snap.n_live).copy()
                dt, vals, pos = _round(driver, snap, q)
                live_s.append(dt)
                ref_vals, ref_pos = oracle.search(
                    q, len(frozen), lambda lo, hi: frozen[lo:hi], K)
                bitwise += int(np.array_equal(pos, ref_pos)
                               and np.array_equal(vals, ref_vals))
                resolved += 1
                snap.close()
                while writer.ops == 0:                # writer really ran
                    time.sleep(0.001)
        ops = writer.ops

        # -- phase C: scan pre, compact mid-serve, scan post ------------------
        with cache.snapshot() as snap:
            pre_scan_us = _scan_us(snap)
            frag_rows = len(cache) - snap.n_live

        # pinned-reader probe: tiny snapshot reads before and during
        # the background compaction.  A blocking rewrite would stall
        # every during-probe for the full rewrite; lock-free pinned
        # readers only see GIL-sharing noise.  Hundreds of samples
        # make the medians stable on a noisy box.
        rows = np.arange(0, 64, dtype=np.int64)
        stats = {}
        with cache.snapshot() as snap:
            def probe():
                t0 = time.monotonic()
                snap.get_rows(rows)
                return time.monotonic() - t0
            probe()                                       # fault pages in
            idle_probe = [probe() for _ in range(300)]
            compact_t = threading.Thread(
                target=lambda: stats.update(cache.compact()),
                name="bench-compact", daemon=True)
            compact_t.start()
            during_probe = []
            while compact_t.is_alive():
                during_probe.append(probe())
            compact_t.join(timeout=60.0)
            during_probe += [probe() for _ in range(20)]  # tail coverage
        assert stats.get("epoch", 0) >= 1, "compaction never committed"

        with cache.snapshot() as snap:
            post_scan_us = _scan_us(snap)
            assert snap.epoch >= 1
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    frozen_qps = N_Q / float(np.mean(frozen_s))
    live_qps = N_Q / float(np.mean(live_s))
    frozen_p99 = float(np.percentile(frozen_s, 99))
    live_p99 = float(np.percentile(live_s, 99))

    payload = {
        "config": {"n_docs": N_DOCS, "dim": DIM, "n_queries": N_Q,
                   "topk": K, "chunk_size": CHUNK,
                   "frozen_rounds": FROZEN_ROUNDS,
                   "live_rounds": LIVE_ROUNDS},
        "frozen_s": frozen_s,
        "live_s": live_s,
        "n_during_probes": len(during_probe),
        "writer_ops": ops,
        "generations_seen": len(gens),
        "fragmented_rows": frag_rows,
        "compact_stats": {k: int(v) for k, v in stats.items()},
        "headline": {
            # structural (exact in the check gate)
            "oracle_bitwise": bitwise / LIVE_ROUNDS,
            "resolved_fraction": resolved / LIVE_ROUNDS,
            # timing (tolerance-gated)
            "live_qps_ratio": live_qps / frozen_qps,
            "live_p99_headroom": frozen_p99 / live_p99,
            "compaction_pause_ratio": float(np.median(idle_probe))
            / float(np.median(during_probe)),
            "compaction_worst_pause_ms": float(max(during_probe)) * 1e3,
            "compact_scan_speedup": pre_scan_us / post_scan_us,
            "frozen_qps": frozen_qps,
            "live_qps": live_qps,
            "frozen_p99_ms": frozen_p99 * 1e3,
            "live_p99_ms": live_p99 * 1e3,
            "pre_compact_scan_us": pre_scan_us,
            "post_compact_scan_us": post_scan_us,
        },
    }
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)

    h = payload["headline"]
    emit("mutation_frozen_round", float(np.mean(frozen_s)) * 1e6,
         f"frozen corpus {frozen_qps:.0f} q/s")
    emit("mutation_live_round", float(np.mean(live_s)) * 1e6,
         f"{ops} writer ops, {len(gens)} generations, "
         f"bitwise={h['oracle_bitwise']:.0f} "
         f"({h['live_qps_ratio']:.2f}x of frozen)")
    emit("mutation_compact_probe",
         float(np.median(during_probe)) * 1e6,
         f"{len(during_probe)} pinned reads during compaction, pause "
         f"ratio {h['compaction_pause_ratio']:.2f} (~1 means no pause)")
    emit("mutation_post_compact_scan", post_scan_us,
         f"{h['compact_scan_speedup']:.2f}x of fragmented pre-compact "
         f"scan ({frag_rows} dead rows dropped)")
    return payload


if __name__ == "__main__":
    run()
