"""Benchmark regression gate: fresh runs vs committed baselines.

``python benchmarks/run.py --check [--tol T]`` re-runs every bench that
records a ``results/*.json`` baseline, writing the fresh JSON into a
scratch dir, then compares *relative* key metrics (speedups, scaling
efficiencies, dispatch reductions — never absolute wall times, which
track the machine not the code) against the committed file.  A
higher-is-better metric may dip up to ``tol`` (default 0.35 — the
tier-1 container is a noisy 2-core box) below baseline before the gate
fails; structural metrics like dispatch counts (``EXACT_METRICS``) are
deterministic and fail on any drop.  Exit status: 0 = all within
tolerance, 1 = regression, 0 with a SKIP note when a baseline file was
never committed.
"""

import argparse
import json
import os
import tempfile

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results")


def _dispatch_metrics(payload):
    return {
        "superchunk_speedup": payload["headline"]["speedup"],
        "dispatch_reduction": payload["headline"]["dispatch_reduction"],
    }


def _multinode_metrics(payload):
    eff = {r["workers"]: r["scaling_efficiency"]
           for r in payload["scaling"]}
    return {
        "w4_scaling_efficiency": eff[4],
        "w4_pipelined_efficiency": {
            r["workers"]: r["pipelined_scaling_efficiency"]
            for r in payload["scaling"]}[4],
        "chunk_pipeline_overlap": payload["chunk_pipeline"]["overlap"],
        "round_pipeline_overlap": payload["round_pipeline"]["overlap"],
    }


def _encode_metrics(payload):
    return {
        "encode_speedup": payload["headline"]["encode_speedup"],
        "warm_encode_speedup": payload["headline"]["warm_speedup"],
        "compile_reduction": payload["headline"]["compile_reduction"],
    }


def _run_dispatch(out_json):
    from benchmarks import bench_dispatch
    return bench_dispatch.run(out_json=out_json)


def _run_multinode(out_json):
    from benchmarks import bench_multinode
    return bench_multinode.run(out_json=out_json)


def _run_encode(out_json):
    from benchmarks import bench_encode
    return bench_encode.run(out_json=out_json)


def _memory_metrics(payload):
    return {
        "table1_memory_ratio": payload["table1"]["ratio"],
        "concat_saving": payload["concat_view"]["saving"],
        "concat_flatness": payload["concat_view"]["flatness"],
        "concat_vs_max_parts": payload["concat_view"]["vs_max_parts"],
    }


def _run_memory(out_json):
    from benchmarks import bench_memory
    return bench_memory.run(out_json=out_json)


def _ivf_metrics(payload):
    return {
        # best throughput among sweep points keeping recall@10 >= 0.95
        "ivf_speedup_at_recall95":
            payload["headline"]["speedup_at_recall95"],
        "ivf_recall_quarter_probe":
            payload["headline"]["recall_quarter_probe"],
        # structural: full probe must replay the flat ranking exactly,
        # and the sweep must keep its cluster structure
        "ivf_full_probe_bitwise":
            payload["headline"]["ivf_full_probe_bitwise"],
        "ivf_n_clusters": payload["headline"]["ivf_n_clusters"],
    }


def _run_ivf(out_json):
    from benchmarks import bench_ivf
    return bench_ivf.run(out_json=out_json)


def _serve_metrics(payload):
    return {
        "serve_qps_speedup_c4": payload["headline"]["qps_speedup_c4"],
        "serve_qps_speedup_c8": payload["headline"]["qps_speedup_c8"],
        "serve_p99_headroom_c4": payload["headline"]["p99_headroom_c4"],
        "serve_completed_fraction":
            payload["headline"]["completed_fraction"],
    }


def _run_serve(out_json):
    from benchmarks import bench_serve
    return bench_serve.run(out_json=out_json)


def _faults_metrics(payload):
    return {
        # structural recovery guarantees: exact
        "fault_recovery_bitwise": payload["headline"]["recovery_bitwise"],
        "fault_recovery_coverage":
            payload["headline"]["recovery_coverage"],
        "fault_all_rounds_bitwise":
            payload["headline"]["all_rounds_bitwise"],
        # timing: tolerance-gated
        "fault_recovery_latency_ratio":
            payload["headline"]["recovery_latency_ratio"],
        "fault_post_kill_throughput_ratio":
            payload["headline"]["post_fault_throughput_ratio"],
    }


def _run_faults(out_json):
    from benchmarks import bench_faults
    return bench_faults.run(out_json=out_json)


def _mutation_metrics(payload):
    return {
        # structural snapshot-isolation guarantees: exact
        "mutation_oracle_bitwise": payload["headline"]["oracle_bitwise"],
        "mutation_resolved_fraction":
            payload["headline"]["resolved_fraction"],
        # timing: tolerance-gated
        "mutation_live_qps_ratio": payload["headline"]["live_qps_ratio"],
        "mutation_live_p99_headroom":
            payload["headline"]["live_p99_headroom"],
        "mutation_compaction_pause_ratio":
            payload["headline"]["compaction_pause_ratio"],
        "mutation_compact_scan_speedup":
            payload["headline"]["compact_scan_speedup"],
    }


def _run_mutation(out_json):
    from benchmarks import bench_mutation
    return bench_mutation.run(out_json=out_json)


# baseline file -> (fresh-run fn, metric extractor).  Metrics are all
# higher-is-better ratios.
CHECKS = {
    "bench_dispatch.json": (_run_dispatch, _dispatch_metrics),
    "bench_multinode.json": (_run_multinode, _multinode_metrics),
    "bench_encode.json": (_run_encode, _encode_metrics),
    "bench_memory.json": (_run_memory, _memory_metrics),
    "bench_serve.json": (_run_serve, _serve_metrics),
    "bench_ivf.json": (_run_ivf, _ivf_metrics),
    "bench_faults.json": (_run_faults, _faults_metrics),
    "bench_mutation.json": (_run_mutation, _mutation_metrics),
}

# Structural metrics are deterministic functions of the code (dispatch /
# compile counts, completed-request fractions — not wall times): no
# noise allowance — any drop is a regression.
EXACT_METRICS = {"dispatch_reduction", "compile_reduction",
                 "serve_completed_fraction", "ivf_full_probe_bitwise",
                 "ivf_n_clusters", "fault_recovery_bitwise",
                 "fault_recovery_coverage", "fault_all_rounds_bitwise",
                 "mutation_oracle_bitwise", "mutation_resolved_fraction"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="run.py --check")
    ap.add_argument("--check", action="store_true")  # consumed by run.py
    ap.add_argument("--tol", type=float, default=0.35,
                    help="allowed relative dip below baseline (0.35 = "
                         "fresh metric may be 35%% worse)")
    args = ap.parse_args(argv)

    failures = 0
    with tempfile.TemporaryDirectory() as scratch:
        for fname, (run_fn, metrics_fn) in CHECKS.items():
            base_path = os.path.join(RESULTS_DIR, fname)
            if not os.path.exists(base_path):
                print(f"SKIP {fname}: no committed baseline")
                continue
            with open(base_path) as f:
                base = metrics_fn(json.load(f))
            fresh = metrics_fn(run_fn(os.path.join(scratch, fname)))
            for key, want in base.items():
                got = fresh[key]
                floor = (want if key in EXACT_METRICS
                         else want * (1.0 - args.tol))
                ok = got >= floor
                failures += not ok
                print(f"{'PASS' if ok else 'FAIL'} {fname}:{key} "
                      f"fresh={got:.3f} baseline={want:.3f} "
                      f"floor={floor:.3f}")
    if failures:
        print(f"bench check: {failures} metric(s) regressed")
        return 1
    print("bench check: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
