"""Multi-node sharded search scaling (paper Table 2, driver edition).

Runs the *real* ``ShardedSearchDriver`` — fair sharding, double-buffered
chunk prefetch, pluggable score backend, O(Q·k·W) ``merge_arrays``
reduction — for W ∈ {1, 2, 4} simulated workers on CPU.  One physical
machine, so workers execute sequentially and "cluster time" =
max(per-worker wall time) + merge time, exactly like ``bench_scaling``;
linear scaling shows up as cluster time ~ 1/W.

Also measures the async chunk pipeline directly: with an artificial
chunk-load latency L and scoring cost S, the synchronous loop costs
~n·(L+S) while the double-buffered loop costs ~n·max(L, S).

Emits CSV rows and records the scaling-efficiency table to the bench
JSON (``results/bench_multinode.json``).
"""

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.fair_sharding import FairSharder
from repro.core.result_heap import FastResultHeapq
from repro.core.sharded_search import ShardedSearchDriver

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_multinode.json")


def _cluster_round(corpus: np.ndarray, q: np.ndarray, w: int, k: int,
                   chunk: int, score_impl: str):
    """One W-worker round, workers timed sequentially; returns
    (cluster_seconds, max_worker_seconds, merge_seconds,
    merged (vals, ids)).  ``cluster_seconds`` is the *serialized* model
    (merge waits for scoring — the old per-round regime); the pipelined
    steady state is modeled from the two components by the caller."""
    sharder = FairSharder(w)
    worker_seconds, states = [], []
    for rank in range(w):
        driver = ShardedSearchDriver(
            n_workers=w, worker_index=rank, sharder=sharder,
            score_impl=score_impl, chunk_size=chunk, gather=None)
        vals, ids = driver.search(
            q, corpus.shape[0],
            lambda lo, hi: corpus[lo:hi], k)
        worker_seconds.append(driver.stats["seconds"])
        states.append((vals, ids))
    t0 = time.monotonic()
    merged = FastResultHeapq(q.shape[0], k)
    for vals, ids in states:                 # O(Q*k*W), rank order
        merged.merge_arrays(vals, ids)
    out = merged.finalize()
    merge_s = time.monotonic() - t0
    worker_s = max(worker_seconds)
    return worker_s + merge_s, worker_s, merge_s, out


def _round_pipeline(corpus: np.ndarray, q: np.ndarray, w: int, k: int,
                    chunk: int, score_impl: str, rounds: int = 5):
    """Real wall-clock of R back-to-back query rounds on a W-worker
    simulated cluster: ``search`` (each round's gather merge serializes
    after its scoring) vs ``search_async`` (round r's merge runs on the
    reduce thread while round r+1 already scores).  Returns
    (sync_seconds, pipelined_seconds)."""
    from repro.launch.distributed import SimulatedCluster
    load = lambda lo, hi: corpus[lo:hi]
    n = corpus.shape[0]

    def run_mode(pipelined: bool):
        cluster = SimulatedCluster(w)
        drivers = [ShardedSearchDriver(
            n_workers=w, worker_index=rank, sharder=cluster.sharder,
            score_impl=score_impl, chunk_size=chunk,
            gather=cluster.gather) for rank in range(w)]

        def worker(rank):
            d = drivers[rank]
            if pipelined:
                futs = [d.search_async(q, n, load, k)
                        for _ in range(rounds)]
                return [f.result() for f in futs]
            return [d.search(q, n, load, k) for _ in range(rounds)]

        cluster.run(worker)                  # warmup (jit, EMA settle)
        t0 = time.monotonic()
        outs = cluster.run(worker)
        dt = time.monotonic() - t0
        for d in drivers:
            d.close()
        return dt, outs

    sync_s, sync_outs = run_mode(False)
    pipe_s, pipe_outs = run_mode(True)
    for (_, ids_s), (_, ids_p) in zip(sync_outs[0], pipe_outs[0]):
        np.testing.assert_array_equal(ids_p, ids_s)  # bitwise identical
    return sync_s, pipe_s


def _pipeline_overlap(n_chunks: int = 8, load_ms: float = 10.0,
                      score_ms: float = 10.0):
    """Measure the double-buffered prefetch against the synchronous loop
    with controlled per-chunk load/score latencies."""
    q = np.zeros((1, 4), np.float32)

    def loader(lo, hi):
        time.sleep(load_ms / 1e3)
        return np.zeros((hi - lo, 4), np.float32)

    def slow_score(q_emb, embs, off, heap, k):
        time.sleep(score_ms / 1e3)

    from repro.core import sharded_search
    times = {}
    orig = sharded_search.SCORE_BACKENDS["numpy"]
    sharded_search.SCORE_BACKENDS["numpy"] = slow_score
    try:
        for prefetch in (False, True):
            drv = ShardedSearchDriver(score_impl="numpy", chunk_size=1,
                                      prefetch=prefetch)
            drv.search(q, n_chunks, loader, 1)      # warmup: jit compile
            t0 = time.monotonic()
            drv.search(q, n_chunks, loader, 1)
            times[prefetch] = time.monotonic() - t0
    finally:
        sharded_search.SCORE_BACKENDS["numpy"] = orig
    return times[False], times[True]


def run(n_docs: int = 60_000, n_q: int = 64, dim: int = 256, k: int = 100,
        chunk: int = 2_048, score_impl: str = "numpy",
        out_json: str = DEFAULT_JSON):
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(n_docs, dim)).astype(np.float32)
    q = rng.normal(size=(n_q, dim)).astype(np.float32)
    shape = f"q={n_q} n={n_docs} d={dim} k={k} chunk={chunk}"

    records, base, pipe_base, ref_ids = [], None, None, None
    for w in (1, 2, 4):
        # first round pays jit compiles (heap merge, ragged last chunk);
        # report the best of two steady-state rounds (2-core container —
        # single-round numbers are noisy)
        _cluster_round(corpus, q, w, k, chunk, score_impl)
        cluster_s, worker_s, merge_s, (vals, ids) = min(
            (_cluster_round(corpus, q, w, k, chunk, score_impl)
             for _ in range(2)), key=lambda r: r[0])
        # sanity: the shard count never changes the merged ranking
        if ref_ids is None:
            ref_ids = ids
        else:
            np.testing.assert_array_equal(ids, ref_ids)
        # steady-state pipelined model (search_async): round r's merge
        # overlaps round r+1's scoring, so per-round cost is the max of
        # the phases, not their sum — the old serialized model charged
        # the O(Q·k·W) merge to every round, which is exactly where the
        # W=4 efficiency went
        pipelined_s = max(worker_s, merge_s)
        base = base or cluster_s
        pipe_base = pipe_base or pipelined_s
        speedup = base / cluster_s
        eff = speedup / w
        pipe_speedup = pipe_base / pipelined_s
        pipe_eff = pipe_speedup / w
        emit(f"multinode_driver_{w}worker", cluster_s * 1e6,
             f"speedup={speedup:.2f}x eff={eff:.2f} "
             f"pipelined_eff={pipe_eff:.2f} merge={merge_s * 1e3:.1f}ms")
        records.append({"workers": w, "cluster_s": cluster_s,
                        "merge_s": merge_s, "speedup": speedup,
                        "scaling_efficiency": eff,
                        "pipelined_cluster_s": pipelined_s,
                        "pipelined_speedup": pipe_speedup,
                        "pipelined_scaling_efficiency": pipe_eff})

    sync_s, pipe_s = _pipeline_overlap()
    emit("multinode_chunk_pipeline", pipe_s * 1e6,
         f"sync={sync_s * 1e3:.1f}ms overlap={sync_s / pipe_s:.2f}x")

    rp_sync, rp_pipe = _round_pipeline(corpus, q, 2, k, chunk, score_impl)
    emit("multinode_round_pipeline", rp_pipe * 1e6,
         f"sync={rp_sync * 1e3:.1f}ms overlap={rp_sync / rp_pipe:.2f}x")

    payload = {"name": "bench_multinode", "shape": shape,
               "score_impl": score_impl, "scaling": records,
               "chunk_pipeline": {"sync_s": sync_s, "pipelined_s": pipe_s,
                                  "overlap": sync_s / pipe_s},
               "round_pipeline": {"sync_s": rp_sync,
                                  "pipelined_s": rp_pipe,
                                  "overlap": rp_sync / rp_pipe}}
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    run()
