"""Fault-tolerant search recovery under a mid-run worker kill.

A W=4 resilient ``SimulatedCluster`` streams search rounds over a
synthetic corpus; halfway through the run one worker is killed by the
``FaultInjector`` (crash on its first chunk of the kill round).  The
bench records:

  * steady-state round latency / query throughput *before* the kill;
  * the recovery round's latency (the survivors detect the death,
    rescore the orphaned shard, and merge) and whether its merged
    positions are **bitwise-equal** to the no-fault W=1 oracle with
    full coverage — the structural recovery guarantee;
  * steady-state latency / throughput *after* the kill, when the
    FairSharder has repartitioned the corpus over the 3 survivors.

Emits CSV rows and ``results/bench_faults.json`` (gated by
``benchmarks/run.py --check``: bitwise/coverage metrics are exact,
timing ratios get the usual noise tolerance).
"""

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.faults import Fault, FaultInjector
from repro.core.sharded_search import ShardedSearchDriver
from repro.launch.distributed import SimulatedCluster

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_faults.json")

W = 4
N_DOCS, DIM, N_Q, K = 4096, 64, 16, 10
CHUNK = 256
N_ROUNDS = 9
KILL_ROUND = 4


def _drivers(cluster, injector):
    return [ShardedSearchDriver(
        n_workers=W, worker_index=rank, sharder=cluster.sharder,
        gather=cluster.gather, score_impl="numpy", chunk_size=CHUNK,
        fault_injector=injector, round_deadline_s=0.5,
        retry_backoff_s=0.01)
        for rank in range(W)]


def run(out_json: str = DEFAULT_JSON) -> dict:
    rng = np.random.default_rng(0)
    docs = rng.normal(size=(N_DOCS, DIM)).astype(np.float32)
    q = rng.normal(size=(N_Q, DIM)).astype(np.float32)
    load = lambda lo, hi: docs[lo:hi]                     # noqa: E731

    # no-fault oracle: the recovery round must replay this bitwise
    oracle = ShardedSearchDriver(score_impl="numpy", chunk_size=CHUNK)
    _, ref_pos = oracle.search(q, N_DOCS, load, K)

    injector = FaultInjector(
        [Fault(kind="crash", worker=1, round=KILL_ROUND, phase="load")])
    cluster = SimulatedCluster(W, resilient=True)
    drivers = _drivers(cluster, injector)

    round_s, outs = [], []
    for _ in range(N_ROUNDS):
        t0 = time.monotonic()
        out = cluster.run(lambda rank: drivers[rank].search(
            q, N_DOCS, load, K))
        round_s.append(time.monotonic() - t0)
        outs.append(out[0])

    assert injector.fired, "kill never fired"
    assert cluster.health.is_dead(1)
    recovery = outs[KILL_ROUND]
    bitwise = float(np.array_equal(recovery[1], ref_pos))
    coverage = float(np.asarray(recovery.coverage).min())
    # every round — before, during, and after the kill — replays the
    # oracle ranking (recovery keeps results exact, survivors repartition)
    all_bitwise = float(all(np.array_equal(o[1], ref_pos) for o in outs))

    # round 0 pays warmup (thread spin-up, first EMA): steady-state
    # windows exclude it and the kill round
    pre = round_s[1:KILL_ROUND]
    post = round_s[KILL_ROUND + 1:]
    pre_s, post_s = float(np.mean(pre)), float(np.mean(post))
    rec_s = float(round_s[KILL_ROUND])
    pre_qps, post_qps = N_Q / pre_s, N_Q / post_s

    payload = {
        "config": {"workers": W, "n_docs": N_DOCS, "dim": DIM,
                   "n_queries": N_Q, "topk": K, "chunk_size": CHUNK,
                   "rounds": N_ROUNDS, "kill_round": KILL_ROUND},
        "rounds_s": round_s,
        "headline": {
            # structural (exact in the check gate)
            "recovery_bitwise": bitwise,
            "recovery_coverage": coverage,
            "all_rounds_bitwise": all_bitwise,
            # timing (tolerance-gated): how much slower the recovery
            # round is than steady state, and how much throughput the
            # 3-survivor cluster retains
            "recovery_latency_ratio": pre_s / rec_s,
            "post_fault_throughput_ratio": post_qps / pre_qps,
            "pre_kill_round_ms": pre_s * 1e3,
            "recovery_round_ms": rec_s * 1e3,
            "post_kill_round_ms": post_s * 1e3,
            "pre_kill_qps": pre_qps,
            "post_kill_qps": post_qps,
        },
    }
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)

    h = payload["headline"]
    emit("faults_pre_kill_round", pre_s * 1e6,
         f"W={W} steady state {pre_qps:.0f} q/s")
    emit("faults_recovery_round", rec_s * 1e6,
         f"bitwise={bitwise:.0f} coverage={coverage:.2f}")
    emit("faults_post_kill_round", post_s * 1e6,
         f"W={W - 1} survivors {post_qps:.0f} q/s "
         f"({h['post_fault_throughput_ratio']:.2f}x of pre-kill)")
    return payload


if __name__ == "__main__":
    run()
