"""Continuous-batching serve frontend: QPS vs p50/p99 latency curve.

The sequential baseline answers one request at a time against a
prepared device-resident corpus — the old ``launch.serve`` loop, steady
state, no coalescing.  The frontend runs the same single-query request
stream from C ∈ {1, 2, 4, 8} concurrent submitter threads through
``core.serving.ServeFrontend``: requests coalesce into micro-batches
(flush at ``max_batch`` or ``max_wait_ms``), encode/score amortize one
dispatch chain over the whole batch, and per-request rows demux back to
futures.  At C=1 the frontend pays the flush deadline for no
amortization (it should roughly tie the baseline); from C=4 up the
micro-batches beat the sequential baseline on QPS — the headline gate.

Everything is steady-state: the rung ladder (1..max_batch powers of
two) is warmed before any timed pass, exactly like ``launch.serve``'s
warm pass.  Results land in ``results/bench_serve.json`` for
``run.py --check`` (QPS speedup and p99 toleranced; the
completed/accepted fraction is structural — a dropped request is a bug,
not noise).
"""

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import emit

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_serve.json")

CONCURRENCIES = (1, 2, 4, 8)


def _make_env(n_docs: int, n_queries: int):
    import jax
    import jax.numpy as jnp

    from repro.core.collator import RetrievalCollator
    from repro.core.config import (DataArguments, EvaluationArguments,
                                   ModelArguments)
    from repro.core.evaluator import RetrievalEvaluator
    from repro.data.tokenizer import HashTokenizer
    from repro.models.retriever import BiEncoderRetriever
    from repro.models.transformer import LMConfig

    cfg = LMConfig(name="bench-serve", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=8192,
                   dtype=jnp.float32, pooling="mean", remat=False)
    retriever = BiEncoderRetriever.from_model_args(ModelArguments(), cfg)
    params = retriever.init_params(jax.random.key(0))
    coll = RetrievalCollator(DataArguments(vocab_size=8192),
                             HashTokenizer(8192))
    ev = RetrievalEvaluator(
        EvaluationArguments(topk=10, encode_batch_size=32,
                            metrics=("ndcg@10",)),
        retriever, coll, params)
    rng = np.random.default_rng(0)
    corpus = {f"d{i}": " ".join(f"w{rng.integers(8_000)}"
                                for _ in range(int(rng.integers(6, 48))))
              for i in range(n_docs)}
    queries = [" ".join(f"w{rng.integers(8_000)}"
                        for _ in range(int(rng.integers(4, 16))))
               for _ in range(n_queries)]
    return ev, corpus, queries


def _percentiles(lat_s):
    lat_ms = np.sort(np.asarray(lat_s)) * 1e3
    return (float(np.percentile(lat_ms, 50)),
            float(np.percentile(lat_ms, 99)))


def run(n_docs: int = 384, n_queries: int = 64, topk: int = 10,
        n_requests: int = 64, max_batch: int = 16,
        max_wait_ms: float = 2.0, out_json: str = DEFAULT_JSON):
    from repro.core.serving import EvaluatorServeBackend, ServeFrontend

    ev, corpus, queries = _make_env(n_docs, n_queries)
    # one backend for everything: corpus prepared once, frontends below
    # reuse it (ServeFrontend.close() drains the driver's reduce thread,
    # which recreates lazily on the next round)
    backend = EvaluatorServeBackend(ev, corpus)
    reqs = [queries[i % len(queries)] for i in range(n_requests)]

    # warm the rung ladder: every power-of-two micro-batch width a
    # coalesced flush can produce, cycling through ALL query texts at
    # each width so every length bucket compiles too
    w = 1
    while w <= max_batch:
        for off in range(0, len(queries), w):
            backend.begin([queries[(off + j) % len(queries)]
                           for j in range(w)], topk).result()
        w *= 2

    # -- sequential per-request baseline (no coalescing) ---------------------
    seq_lat = []
    t0 = time.monotonic()
    for text in reqs:
        t1 = time.monotonic()
        backend.begin([text], topk).result()
        seq_lat.append(time.monotonic() - t1)
    seq_wall = time.monotonic() - t0
    seq_qps = n_requests / seq_wall
    seq_p50, seq_p99 = _percentiles(seq_lat)
    emit("serve_sequential", seq_wall / n_requests * 1e6,
         f"qps={seq_qps:.1f} p50={seq_p50:.2f}ms p99={seq_p99:.2f}ms")

    # -- frontend QPS-vs-latency curve over submitter concurrency ------------
    curve = []
    for conc in CONCURRENCIES:
        fe = ServeFrontend(backend, topk=topk, max_batch=max_batch,
                           max_wait_ms=max_wait_ms, max_queue=256)
        lat = [0.0] * n_requests
        next_i = [0]
        lock = threading.Lock()

        def client():
            while True:
                with lock:
                    i = next_i[0]
                    if i >= n_requests:
                        return
                    next_i[0] += 1
                t1 = time.monotonic()
                fe.submit(reqs[i]).result()
                lat[i] = time.monotonic() - t1

        threads = [threading.Thread(target=client) for _ in range(conc)]
        t0 = time.monotonic()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.monotonic() - t0
        stats = dict(fe.stats)
        fe.close()
        qps = n_requests / wall
        p50, p99 = _percentiles(lat)
        emit(f"serve_frontend_c{conc}", wall / n_requests * 1e6,
             f"qps={qps:.1f} p50={p50:.2f}ms p99={p99:.2f}ms "
             f"batches={stats['batches']} vs_seq={qps / seq_qps:.2f}x")
        curve.append({"concurrency": conc, "qps": qps, "p50_ms": p50,
                      "p99_ms": p99, "qps_vs_sequential": qps / seq_qps,
                      "micro_batches": stats["batches"],
                      "max_batch_seen": stats["max_batch_seen"],
                      "accepted": stats["accepted"],
                      "completed": stats["completed"]})

    by_c = {r["concurrency"]: r for r in curve}
    # structural: every accepted request completed, at every concurrency
    completed_fraction = min(
        r["completed"] / r["accepted"] for r in curve)
    payload = {
        "name": "bench_serve",
        "shape": f"docs={n_docs} requests={n_requests} topk={topk} "
                 f"max_batch={max_batch} max_wait_ms={max_wait_ms}",
        "sequential": {"qps": seq_qps, "p50_ms": seq_p50,
                       "p99_ms": seq_p99},
        "curve": curve,
        "headline": {
            # micro-batching must beat the per-request baseline once
            # there is real concurrency to coalesce (the ISSUE gate)
            "qps_speedup_c4": by_c[4]["qps_vs_sequential"],
            "qps_speedup_c8": by_c[8]["qps_vs_sequential"],
            # a serial server would queue C=4 submitters ~4 deep: p99
            # must stay under that serialized bound (higher = better)
            "p99_headroom_c4": (4 * seq_p50) / by_c[4]["p99_ms"],
            "completed_fraction": completed_fraction,
        },
    }
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    run()
