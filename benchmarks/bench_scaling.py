"""Paper Table 2: multi-node inference scaling.

One physical core here, so nodes are *simulated*: the corpus is
fair-sharded across N virtual nodes and each node's wall time is measured
sequentially; reported "cluster time" = max(node times) + the O(Q*k)
merge.  Linear scaling shows up as cluster time ~ 1/N (the paper's
14:20 -> 7:12 -> 4:48 pattern).
"""

import time

import numpy as np

from benchmarks.common import emit
from repro.core.fair_sharding import FairSharder
from repro.core.result_heap import FastResultHeapq


def _encode_like(texts_embs: np.ndarray, lo: int, hi: int, q: np.ndarray,
                 heap: FastResultHeapq, chunk: int = 512):
    for off in range(lo, hi, chunk):
        embs = texts_embs[off: off + chunk]
        # stand-in for encoder cost: one GEMM comparable to a small tower
        _ = embs @ np.ones((embs.shape[1], embs.shape[1]), np.float32)
        heap.update(q @ embs.T,
                    np.arange(off, off + embs.shape[0], dtype=np.int32))


def run(n_docs: int = 60_000, n_q: int = 64, dim: int = 256, k: int = 100):
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(n_docs, dim)).astype(np.float32)
    q = rng.normal(size=(n_q, dim)).astype(np.float32)
    base = None
    results = {}
    for n_nodes in (1, 2, 3):
        sharder = FairSharder(n_nodes)
        bounds = sharder.bounds(n_docs)
        node_times, heaps = [], []
        for rank, (lo, hi) in enumerate(bounds):
            heap = FastResultHeapq(n_q, k)
            t0 = time.monotonic()
            _encode_like(corpus, lo, hi, q, heap)
            heap.finalize()
            node_times.append(time.monotonic() - t0)
            heaps.append(heap)
        t0 = time.monotonic()
        merged = heaps[0]
        for h in heaps[1:]:
            merged.merge(h)
        merge_t = time.monotonic() - t0
        cluster = max(node_times) + merge_t
        base = base or cluster
        emit(f"table2_inference_{n_nodes}node", cluster * 1e6,
             f"speedup={base / cluster:.2f}x merge={merge_t * 1e3:.1f}ms")
        results[n_nodes] = cluster
    return results


if __name__ == "__main__":
    run()
