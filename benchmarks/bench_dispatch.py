"""Dispatch overhead: per-chunk streaming vs the superchunk scan executor.

The paper's "no overhead" inference claim dies by a thousand dispatches:
streaming the corpus at ``encode_batch_size=32`` pays Python + jit-call
overhead once per 32-row chunk (two dispatches each on the ``jax`` path:
score matmul + heap merge).  The superchunk executor folds S chunks into
ONE jitted ``lax.scan`` with the (Q, k) state donated between steps, so a
512-chunk round costs ``ceil(512 / S)`` dispatches instead of 512.

This bench runs the *real* ``ShardedSearchDriver`` both ways on the same
corpus — per-chunk (``superchunk_size=1``, the pre-superchunk behavior),
a fixed S=64 superchunk, and the autotuned S — verifying identical
rankings, and records throughput + dispatches/round to
``results/bench_dispatch.json``.
"""

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.sharded_search import ShardedSearchDriver

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_dispatch.json")


def _round(corpus, q, k, chunk, superchunk_size, rounds: int = 3):
    """Best-of-``rounds`` steady-state search round; first call pays the
    jit compiles and is discarded."""
    drv = ShardedSearchDriver(score_impl="jax", heap_impl="jax",
                              chunk_size=chunk,
                              superchunk_size=superchunk_size)
    load = lambda lo, hi: corpus[lo:hi]               # noqa: E731
    out = drv.search(q, corpus.shape[0], load, k)     # warmup / compile
    best = float("inf")
    for _ in range(rounds):
        t0 = time.monotonic()
        out = drv.search(q, corpus.shape[0], load, k)
        best = min(best, time.monotonic() - t0)
    return best, drv.stats, out


def run(n_docs: int = 16_384, n_q: int = 32, dim: int = 128, k: int = 100,
        chunk: int = 32, fixed_s: int = 64, out_json: str = DEFAULT_JSON):
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(n_docs, dim)).astype(np.float32)
    q = rng.normal(size=(n_q, dim)).astype(np.float32)
    shape = f"q={n_q} n={n_docs} d={dim} k={k} chunk={chunk}"

    rows = {}
    ref_ids = None
    for name, s in (("per_chunk", 1), ("superchunk", fixed_s),
                    ("superchunk_auto", 0)):
        seconds, stats, (vals, ids) = _round(corpus, q, k, chunk, s)
        if ref_ids is None:
            ref_ids = ids
        else:         # the executor must never change the ranking
            np.testing.assert_array_equal(ids, ref_ids)
        rows[name] = {
            "seconds": seconds,
            "docs_per_s": n_docs / seconds,
            "dispatches": stats["dispatch_rounds"],
            "superchunk_size": stats["superchunk_size"],
            "executor": stats["executor"],
        }

    base = rows["per_chunk"]
    for name in ("superchunk", "superchunk_auto"):
        r = rows[name]
        r["speedup"] = base["seconds"] / r["seconds"]
        # per-chunk 'jax' streaming pays TWO dispatches per chunk
        # (score matmul + heap merge); the scan path pays one per
        # superchunk.  Count what actually hits the jit boundary.
        r["dispatch_reduction"] = 2 * base["dispatches"] / r["dispatches"]
        emit(f"dispatch_{name}_s{r['superchunk_size']}", r["seconds"] * 1e6,
             f"speedup={r['speedup']:.2f}x "
             f"dispatches={r['dispatches']} "
             f"(per_chunk={2 * base['dispatches']}) "
             f"reduction={r['dispatch_reduction']:.0f}x")
    emit("dispatch_per_chunk", base["seconds"] * 1e6,
         f"docs_per_s={base['docs_per_s']:.0f} "
         f"dispatches={2 * base['dispatches']}")

    payload = {"name": "bench_dispatch", "shape": shape,
               "score_impl": "jax", "heap_impl": "jax", "rows": rows,
               "headline": {
                   "speedup": rows["superchunk"]["speedup"],
                   "dispatch_reduction":
                       rows["superchunk"]["dispatch_reduction"]}}
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    run()
