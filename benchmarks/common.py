import os
import subprocess
import sys
import textwrap
import time


def time_call(fn, warmup=1, iters=5):
    for _ in range(warmup):
        fn()
    t0 = time.monotonic()
    for _ in range(iters):
        fn()
    return (time.monotonic() - t0) / iters * 1e6      # us/call


def peak_rss_of(snippet: str) -> float:
    """Run a python snippet in a subprocess, return peak RSS in MB.

    Reads VmHWM from /proc/self/status: unlike ru_maxrss (which Linux
    carries across exec, so children inherit the parent's peak), VmHWM
    tracks the post-exec address space only."""
    prog = textwrap.dedent(snippet) + textwrap.dedent("""
        peak = 0
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM"):
                    peak = int(line.split()[1])
        print("PEAK_RSS_KB", peak)
    """)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, check=True)
    for line in out.stdout.splitlines():
        if line.startswith("PEAK_RSS_KB"):
            return float(line.split()[1]) / 1024.0
    raise RuntimeError(out.stdout + out.stderr)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
