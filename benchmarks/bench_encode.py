"""Online-regime encode throughput: legacy per-batch padding vs the
bucketed pipeline (``core.encode_pipeline``).

The legacy loop pads every ``encode_batch_size`` batch to its own
longest length: a varied-length corpus produces a distinct ``(B, L)``
shape — and one XLA compile — per batch flavor, and every batch with one
long outlier pays the outlier's padding FLOPs for all rows.  The
pipeline tokenizes on a background thread pool, sorts by length into a
geometric bucket ladder (compiles bounded by the ladder, not the
corpus), and restores order on output.

Both paths run through the *real* ``RetrievalEvaluator._encode_texts``
on the same varied-length synthetic corpus with a fresh jit each
("online" = cold encoder, the regime the paper's no-overhead claim is
about), plus a steady-state pass with compiles amortized.  Embeddings
are verified row-identical (allclose) and throughput + compile counts
land in ``results/bench_encode.json`` for ``run.py --check``.
"""

import json
import os
import time

import numpy as np

from benchmarks.common import emit

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_encode.json")


def _make_corpus(n_docs: int, rng) -> list[str]:
    """Zipf-ish token lengths: mostly short, a long tail to max_len —
    the regime where per-batch pad-to-longest hurts most."""
    texts = []
    for _ in range(n_docs):
        u = rng.random()
        if u < 0.70:
            n_tok = int(rng.integers(4, 24))
        elif u < 0.95:
            n_tok = int(rng.integers(24, 64))
        else:
            n_tok = int(rng.integers(64, 160))
        texts.append(" ".join(f"w{rng.integers(20_000)}"
                              for _ in range(n_tok)))
    return texts


def _make_evaluator(buckets: int, batch: int):
    import jax.numpy as jnp

    from repro.core.collator import RetrievalCollator
    from repro.core.config import (DataArguments, EvaluationArguments,
                                   ModelArguments)
    from repro.core.evaluator import RetrievalEvaluator
    from repro.data.tokenizer import HashTokenizer
    from repro.models.retriever import BiEncoderRetriever
    from repro.models.transformer import LMConfig

    cfg = LMConfig(name="bench-enc", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=8192,
                   dtype=jnp.float32, pooling="mean", remat=False)
    retriever = BiEncoderRetriever.from_model_args(ModelArguments(), cfg)
    import jax
    params = retriever.init_params(jax.random.key(0))
    coll = RetrievalCollator(DataArguments(vocab_size=8192,
                                           passage_max_len=128),
                             HashTokenizer(8192))
    ev = RetrievalEvaluator(
        EvaluationArguments(encode_batch_size=batch,
                            encode_buckets=buckets,
                            metrics=("ndcg@10",)),
        retriever, coll, params)
    return ev


def _count_legacy_shapes(ev):
    """Wrap the legacy jit so every distinct (B, L) batch shape — i.e.
    every XLA compile the legacy loop triggers — is recorded."""
    shapes = set()
    inner = ev._encode_jit

    def counting(params, batch):
        shapes.add(batch["tokens"].shape)
        return inner(params, batch)

    ev._encode_jit = counting
    return shapes


def run(n_docs: int = 3072, batch: int = 32, out_json: str = DEFAULT_JSON):
    rng = np.random.default_rng(0)
    texts = _make_corpus(n_docs, rng)
    shape = f"n={n_docs} batch={batch} max_len=128 d=64"

    rows = {}
    ref = None
    for name, buckets in (("legacy", 0), ("bucketed", 6)):
        ev = _make_evaluator(buckets, batch)
        shapes = _count_legacy_shapes(ev) if buckets == 0 else None
        t0 = time.monotonic()
        embs = ev._encode_texts(texts, False)      # cold: pays compiles
        cold = time.monotonic() - t0
        pad0 = (ev.encode_pipeline.stats["tokens_padded"]
                if ev.encode_pipeline else 0)      # per-pass delta below
        t0 = time.monotonic()
        embs = ev._encode_texts(texts, False)      # steady state
        warm = time.monotonic() - t0
        if ref is None:
            ref = embs
        else:   # bucketing must be invisible: same rows, same order
            np.testing.assert_allclose(embs, ref, rtol=1e-4, atol=1e-5)
        pipe = ev.encode_pipeline
        rows[name] = {
            "cold_seconds": cold, "warm_seconds": warm,
            "cold_docs_per_s": n_docs / cold,
            "warm_docs_per_s": n_docs / warm,
            "compiles": (len(shapes) if shapes is not None
                         else pipe.stats["compiles"]),
            "ladder": (None if pipe is None
                       else list(pipe.ladder(128))),
            "padded_tokens": (None if pipe is None
                              else pipe.stats["tokens_padded"] - pad0),
        }

    legacy, bucketed = rows["legacy"], rows["bucketed"]
    headline = {
        "encode_speedup": legacy["cold_seconds"] / bucketed["cold_seconds"],
        "warm_speedup": legacy["warm_seconds"] / bucketed["warm_seconds"],
        "compile_reduction": legacy["compiles"] / bucketed["compiles"],
        "pipeline_compiles": bucketed["compiles"],
        "ladder_size": len(bucketed["ladder"]),
    }
    # the pipeline's whole point: compiles bounded by the ladder
    assert bucketed["compiles"] <= headline["ladder_size"], rows

    for name in ("legacy", "bucketed"):
        r = rows[name]
        emit(f"encode_{name}_cold", r["cold_seconds"] * 1e6,
             f"docs_per_s={r['cold_docs_per_s']:.0f} "
             f"compiles={r['compiles']}")
        emit(f"encode_{name}_warm", r["warm_seconds"] * 1e6,
             f"docs_per_s={r['warm_docs_per_s']:.0f}")
    emit("encode_pipeline_speedup", 0.0,
         f"cold={headline['encode_speedup']:.2f}x "
         f"warm={headline['warm_speedup']:.2f}x "
         f"compiles {legacy['compiles']} -> {bucketed['compiles']}")

    payload = {"name": "bench_encode", "shape": shape, "rows": rows,
               "headline": headline}
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    run()
