"""Paper Table 1 memory bench + the dataset-view concat variant.

Two claims, both measured as subprocess peak RSS (VmHWM) minus that
variant's *import floor* (python+numpy+allocator baseline, measured
separately — on this container jemalloc's arena floor is ~400 MB, far
above the workload, so raw peaks would be meaningless):

* **table1** — naive in-RAM loading vs Trove's mmap'd
  ``MaterializedQRel`` (the paper's 2.6x factor at benchmark scale).
* **concat_view** — a combined TWO-dataset eval corpus.  Naively that
  is both corpora json-loaded into one dict (O(N_a + N_b) resident);
  through ``ConcatView(TableView(a), TableView(b))`` the union is
  streamed chunk-by-chunk with mmap page eviction behind the scan
  (``open_slice`` -> ``advise_dontneed``), so the union never exists in
  RAM and peak RSS stays ≈ a single part's streaming scan (flat), not
  the sum of parts.

Gate metrics (``results/bench_memory.json``, checked by
``benchmarks/run.py --check``):

* ``table1.ratio`` — naive/trove net MB (higher = better).
* ``concat_view.saving`` — naive union load / concat streaming.
* ``concat_view.flatness`` — streamed payload MB / concat net MB: a
  broken eviction path keeps every touched page resident and flatness
  collapses to ~1.
* ``concat_view.vs_max_parts`` — ``(max part + C) / (concat + C)`` with
  a C=32 MB cushion: both sides of a healthy run are flat few-MB scans
  (ratio ≈ 1 with the cushion damping allocator noise), while a
  regression that makes the combined scan accumulate the union payload
  drags the ratio far below the gate floor.
"""

import json
import os
import tempfile

from benchmarks.common import emit, peak_rss_of

N_DOCS = 150_000
N_QUERIES = 8_000
DOC_LEN = 80
PART_DOCS = 75_000
PART_QUERIES = 4_000
CUSHION_MB = 32.0

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_memory.json")

_GEN = f"""
import os
from repro.data.synthetic import make_retrieval_dataset
d = {{dir!r}}
if not os.path.exists(os.path.join(d, "queries.jsonl")):
    make_retrieval_dataset(d, n_queries={N_QUERIES}, n_docs={N_DOCS},
                           n_topics=512, doc_len={DOC_LEN})
for i in range(2):
    p = os.path.join(d, f"part{{{{i}}}}")
    if not os.path.exists(os.path.join(p, "queries.jsonl")):
        make_retrieval_dataset(p, n_queries={PART_QUERIES},
                               n_docs={PART_DOCS}, n_topics=512,
                               doc_len={DOC_LEN}, seed=10 + i,
                               id_prefix=f"p{{{{i}}}}-")
"""

_NAIVE_IMPORTS = "import json\nd = {dir!r}\n"

_NAIVE = """
# naive: load every record into python dicts (what ad-hoc scripts do)
queries, corpus, qrels = {}, {}, {}
with open(d + "/queries.jsonl") as f:
    for line in f:
        r = json.loads(line); queries[r["_id"]] = r["text"]
with open(d + "/corpus.jsonl") as f:
    for line in f:
        r = json.loads(line); corpus[r["_id"]] = r["text"]
with open(d + "/qrels/train.tsv") as f:
    for line in f:
        q, doc, s = line.split("\\t")
        qrels.setdefault(q, {})[doc] = float(s)
inst = [(queries[q], [corpus[doc] for doc in docs])
        for q, docs in qrels.items()]
print("instances", len(inst))
"""

_TROVE_IMPORTS = """
from repro.core.config import DataArguments, MaterializedQRelConfig
from repro.core.datasets import BinaryDataset
d = {dir!r}
"""

_TROVE = """
cfg = MaterializedQRelConfig(qrel_path=d + "/qrels/train.tsv",
                             query_path=d + "/queries.jsonl",
                             corpus_path=d + "/corpus.jsonl", min_score=1)
ds = BinaryDataset(DataArguments(group_size=2), lambda t: t,
                   lambda t, title="": t, cfg, cfg, cache_root=d + "/cache")
# touch every training instance once (on-the-fly materialization)
n = 0
for i in range(len(ds)):
    n += len(ds[i]["passages"])
print("instances", len(ds), n)
"""

# naive combined eval corpus: both parts json-loaded into ONE dict
_UNION_NAIVE = """
corpus = {}
for i in range(2):
    with open(d + f"/part{i}/corpus.jsonl") as f:
        for line in f:
            r = json.loads(line); corpus[r["_id"]] = r["text"]
texts = list(corpus.values())
print("union docs", len(corpus), sum(len(t) for t in texts[:8]))
"""

_VIEW_IMPORTS = """
from repro.core.config import MaterializedQRelConfig
from repro.core.materialized_qrel import MaterializedQRel
from repro.data.views import ConcatView, row_text
d = {dir!r}
def corpus_view(i):
    p = d + f"/part{{i}}"
    return MaterializedQRel(MaterializedQRelConfig(
        qrel_path=p + "/qrels/train.tsv", query_path=p + "/queries.jsonl",
        corpus_path=p + "/corpus.jsonl"),
        cache_root=d + "/cache").corpus_view()
def stream(view):
    # the evaluator's chunk loop: materialize one chunk of texts, score,
    # drop it; open_slice evicts the consumed mmap pages behind the scan
    n = 0
    for off, rows in view.open_slice(0, len(view), 1024):
        n += sum(len(row_text(r)) for r in rows)
    return n
"""

_PART_STREAM = "print('part bytes', stream(corpus_view({part})))\n"

_CONCAT_STREAM = \
    "print('union bytes', stream(ConcatView(corpus_view(0)," \
    " corpus_view(1))))\n"


def run(out_dir=None, out_json=DEFAULT_JSON):
    d = out_dir or os.path.join(tempfile.gettempdir(), "trove_bench_mem")
    os.makedirs(d, exist_ok=True)
    gen = _GEN.format(dir=d)
    peak_rss_of(gen)                                  # generate once
    # warm Trove's table caches so build cost isn't in the measured runs
    peak_rss_of(_TROVE_IMPORTS.format(dir=d) + _TROVE)
    peak_rss_of(_VIEW_IMPORTS.format(dir=d) + _CONCAT_STREAM)

    naive_floor = peak_rss_of(_NAIVE_IMPORTS.format(dir=d))
    trove_floor = peak_rss_of(_TROVE_IMPORTS.format(dir=d))
    naive = peak_rss_of(_NAIVE_IMPORTS.format(dir=d) + _NAIVE)
    trove = peak_rss_of(_TROVE_IMPORTS.format(dir=d) + _TROVE)
    n_net = max(naive - naive_floor, 1e-3)
    t_net = max(trove - trove_floor, 1e-3)
    emit("table1_memory_naive_mb", n_net * 1000,
         f"{n_net:.0f}MB (floor {naive_floor:.0f}MB)")
    emit("table1_memory_trove_mb", t_net * 1000,
         f"{t_net:.0f}MB (floor {trove_floor:.0f}MB)")
    emit("table1_memory_ratio", 0.0, f"{n_net / t_net:.2f}x reduction")

    view_floor = peak_rss_of(_VIEW_IMPORTS.format(dir=d))
    union_naive = peak_rss_of(
        _NAIVE_IMPORTS.format(dir=d) + _UNION_NAIVE)
    parts = [peak_rss_of(_VIEW_IMPORTS.format(dir=d)
                         + _PART_STREAM.format(part=i))
             for i in range(2)]
    concat = peak_rss_of(_VIEW_IMPORTS.format(dir=d) + _CONCAT_STREAM)
    u_net = max(union_naive - naive_floor, 1e-3)
    p_nets = [max(p - view_floor, 1e-3) for p in parts]
    c_net = max(concat - view_floor, 1e-3)
    payload_mb = sum(
        os.path.getsize(os.path.join(d, f"part{i}", "corpus.jsonl"))
        for i in range(2)) / 1e6
    saving = u_net / c_net
    flatness = payload_mb / c_net
    vs_max_parts = (max(p_nets) + CUSHION_MB) / (c_net + CUSHION_MB)
    emit("concat_union_naive_mb", u_net * 1000, f"{u_net:.0f}MB")
    emit("concat_part_stream_mb", max(p_nets) * 1000,
         f"{max(p_nets):.0f}MB max of parts (floor {view_floor:.0f}MB)")
    emit("concat_view_stream_mb", c_net * 1000,
         f"{c_net:.0f}MB for {payload_mb:.0f}MB streamed payload")
    emit("concat_view_saving", 0.0,
         f"{saving:.1f}x vs naive union; flatness {flatness:.1f}x; "
         f"vs max parts {vs_max_parts:.2f}")

    payload = {
        "config": {"n_docs": N_DOCS, "n_queries": N_QUERIES,
                   "doc_len": DOC_LEN, "part_docs": PART_DOCS,
                   "part_queries": PART_QUERIES,
                   "cushion_mb": CUSHION_MB},
        "table1": {"naive_mb": round(n_net, 2),
                   "trove_mb": round(t_net, 2),
                   "ratio": round(n_net / t_net, 3)},
        "concat_view": {"union_naive_mb": round(u_net, 2),
                        "part_stream_mb": [round(p, 2) for p in p_nets],
                        "concat_stream_mb": round(c_net, 2),
                        "payload_mb": round(payload_mb, 2),
                        "saving": round(saving, 3),
                        "flatness": round(flatness, 3),
                        "vs_max_parts": round(vs_max_parts, 3)},
    }
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
    return payload


if __name__ == "__main__":
    run()
