"""Paper Table 1: data-preparation memory — naive in-RAM loading vs
Trove's mmap'd MaterializedQRel.

Each variant runs in its own subprocess; we report peak RSS minus that
variant's *import floor* (python+numpy+allocator baseline, measured
separately — on this container jemalloc's arena floor is ~400 MB, far
above the workload, so raw peaks would be meaningless).  The dataset is
a scaled MS-MARCO-like synthetic corpus; the paper's 2.6x factor is the
target ratio at benchmark scale.
"""

import os
import tempfile

from benchmarks.common import emit, peak_rss_of

N_DOCS = 150_000
N_QUERIES = 8_000
DOC_LEN = 80

_GEN = f"""
import os
from repro.data.synthetic import make_retrieval_dataset
d = {{dir!r}}
if not os.path.exists(os.path.join(d, "queries.jsonl")):
    make_retrieval_dataset(d, n_queries={N_QUERIES}, n_docs={N_DOCS},
                           n_topics=512, doc_len={DOC_LEN})
"""

_NAIVE_IMPORTS = "import json\nd = {dir!r}\n"

_NAIVE = """
# naive: load every record into python dicts (what ad-hoc scripts do)
queries, corpus, qrels = {}, {}, {}
with open(d + "/queries.jsonl") as f:
    for line in f:
        r = json.loads(line); queries[r["_id"]] = r["text"]
with open(d + "/corpus.jsonl") as f:
    for line in f:
        r = json.loads(line); corpus[r["_id"]] = r["text"]
with open(d + "/qrels/train.tsv") as f:
    for line in f:
        q, doc, s = line.split("\\t")
        qrels.setdefault(q, {})[doc] = float(s)
inst = [(queries[q], [corpus[doc] for doc in docs])
        for q, docs in qrels.items()]
print("instances", len(inst))
"""

_TROVE_IMPORTS = """
from repro.core.config import DataArguments, MaterializedQRelConfig
from repro.core.datasets import BinaryDataset
d = {dir!r}
"""

_TROVE = """
cfg = MaterializedQRelConfig(qrel_path=d + "/qrels/train.tsv",
                             query_path=d + "/queries.jsonl",
                             corpus_path=d + "/corpus.jsonl", min_score=1)
ds = BinaryDataset(DataArguments(group_size=2), lambda t: t,
                   lambda t, title="": t, cfg, cfg, cache_root=d + "/cache")
# touch every training instance once (on-the-fly materialization)
n = 0
for i in range(len(ds)):
    n += len(ds[i]["passages"])
print("instances", len(ds), n)
"""


def run(out_dir=None):
    d = out_dir or os.path.join(tempfile.gettempdir(), "trove_bench_mem")
    os.makedirs(d, exist_ok=True)
    gen = _GEN.format(dir=d)
    peak_rss_of(gen)                                  # generate once
    # warm Trove's table cache so build cost isn't in the measured run
    peak_rss_of(_TROVE_IMPORTS.format(dir=d) + _TROVE)
    naive_floor = peak_rss_of(_NAIVE_IMPORTS.format(dir=d))
    trove_floor = peak_rss_of(_TROVE_IMPORTS.format(dir=d))
    naive = peak_rss_of(_NAIVE_IMPORTS.format(dir=d) + _NAIVE)
    trove = peak_rss_of(_TROVE_IMPORTS.format(dir=d) + _TROVE)
    n_net = max(naive - naive_floor, 1e-3)
    t_net = max(trove - trove_floor, 1e-3)
    emit("table1_memory_naive_mb", n_net * 1000,
         f"{n_net:.0f}MB (floor {naive_floor:.0f}MB)")
    emit("table1_memory_trove_mb", t_net * 1000,
         f"{t_net:.0f}MB (floor {trove_floor:.0f}MB)")
    emit("table1_memory_ratio", 0.0, f"{n_net / t_net:.2f}x reduction")
    return {"naive_mb": n_net, "trove_mb": t_net}


if __name__ == "__main__":
    run()
