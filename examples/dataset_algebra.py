"""Dataset algebra (paper §3.2): compose lazy views — filter / map /
select / concat / interleave — and feed them straight into the streaming
evaluator, then run the multi-dataset eval suite over two corpora whose
union is never materialized.

    PYTHONPATH=src python examples/dataset_algebra.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import (DataArguments, EvaluationArguments, HashTokenizer,
                   MaterializedQRelConfig, RetrievalCollator,
                   RetrievalEvaluator, BiEncoderRetriever)
from repro.core.evaluator import format_metrics_table
from repro.core.materialized_qrel import MaterializedQRel
from repro.data.synthetic import make_retrieval_dataset
from repro.data.views import ConcatView, as_view
from repro.models.encoder import DefaultEncoder
from repro.models.transformer import LMConfig

work = tempfile.mkdtemp(prefix="trove_algebra_")
scenarios = {}
for i in range(2):
    d = os.path.join(work, f"d{i}")
    make_retrieval_dataset(d, n_queries=16, n_docs=96, n_topics=8,
                           seed=7 + i, id_prefix=f"d{i}-")
    m = MaterializedQRel(MaterializedQRelConfig(
        qrel_path=f"{d}/qrels/train.tsv", query_path=f"{d}/queries.jsonl",
        corpus_path=f"{d}/corpus.jsonl"), cache_root=f"{work}/cache")
    scenarios[f"d{i}"] = {"queries": m.queries_view(),
                          "corpus": m.corpus_view(),
                          "qrels": m.qrels_dict()}

# --- view algebra: every combinator is lazy; rows are read per chunk ---
c0, c1 = scenarios["d0"]["corpus"], scenarios["d1"]["corpus"]
on_topic = c0.filter(lambda r: "topic0" in r["text"])       # predicate
titled = on_topic.map(lambda r: {**r, "title": "D0"})       # transform
first_ten = c0.select(range(10))                            # positions
both = c0.concat(c1)                                        # == c0 + c1
mixed = c0.interleave(c1)                                   # round-robin
print(f"c0={len(c0)} on_topic={len(on_topic)} titled={len(titled)} "
      f"first_ten={len(first_ten)} both={len(both)} mixed={len(mixed)}")
assert 0 < len(on_topic) < len(c0)
assert [r["_id"] for r in mixed.rows(0, 4)] == \
       ["d0-doc0", "d1-doc0", "d0-doc1", "d1-doc1"]
# a plain {id: text} dict coerces too; chunked streaming is uniform:
for off, rows in as_view({"a": "x"}).open_slice(0, 1, 8):
    assert rows[0] == {"_id": "a", "text": "x"}

# --- one tiny retriever, evaluated per-dataset AND on the lazy union ---
data_args = DataArguments(vocab_size=512, query_max_len=16,
                          passage_max_len=48)
cfg = LMConfig(name="algebra", n_layers=2, d_model=48, n_heads=4,
               n_kv_heads=2, head_dim=12, d_ff=96, vocab_size=512,
               dtype=jnp.float32, pooling="mean", remat=False)
model = BiEncoderRetriever(DefaultEncoder(cfg), "infonce")
evaluator = RetrievalEvaluator(
    EvaluationArguments(topk=10, metrics=("ndcg@10", "recall@10")),
    model, RetrievalCollator(data_args, HashTokenizer(512)),
    model.init_params(jax.random.key(0)))

results = evaluator.evaluate_suite(scenarios, out_dir=f"{work}/results")
print(format_metrics_table(results), end="")

# the combined row came from a ConcatView — same evaluator, union corpus
union = ConcatView(scenarios["d0"]["corpus"], scenarios["d1"]["corpus"])
assert len(union) == len(c0) + len(c1)
assert "combined" in results
print(f"tables in {work}/results; dataset-algebra OK")
