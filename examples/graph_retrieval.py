"""Graph retrieval: unsupervised GraphSAGE (the arch pool's GNN) trained
with the real neighbor sampler, embeddings served through the Trove
evaluator path (FastResultHeapq + fused score+top-k kernel).

    PYTHONPATH=src python examples/graph_retrieval.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.result_heap import FastResultHeapq
from repro.data.graph import CSRGraph, NeighborSampler, make_random_graph
from repro.kernels import ops as kops
from repro.models import gnn
from repro.models.losses import InfoNCELoss
from repro.training.optimizer import OptimizerConfig, make_optimizer

N, DEG, F, COMMUNITIES = 400, 12, 16, 8
rng = np.random.default_rng(0)
src, dst, comm = make_random_graph(N, DEG, n_communities=COMMUNITIES)
graph = CSRGraph.from_edges(src, dst, N)
# features: noisy community indicator
x = (np.eye(COMMUNITIES)[comm] @ rng.normal(size=(COMMUNITIES, F)) * 0.5
     + rng.normal(size=(N, F)) * 0.5).astype(np.float32)

cfg = gnn.SAGEConfig(name="example", d_feat=F, d_hidden=32,
                     fanouts=(8, 4))
params = gnn.init_params(cfg, jax.random.key(0))
sampler = NeighborSampler(graph, cfg.fanouts, seed=0)
loss_fn = InfoNCELoss()
opt_init, opt_update = make_optimizer(
    OptimizerConfig(name="adamw", learning_rate=3e-3))
opt = opt_init(params)


@jax.jit
def step(params, opt, t, a0, a1, a2, p0, p1, p2):
    def loss(p):
        za = gnn.forward_minibatch(cfg, p, a0, a1, a2)
        zp = gnn.forward_minibatch(cfg, p, p0, p1, p2)
        scores = jnp.einsum("qd,pd->qp", za, zp) / 0.1
        return loss_fn(scores, jnp.arange(za.shape[0], dtype=jnp.int32))

    l, g = jax.value_and_grad(loss)(params)
    params, opt = opt_update(g, opt, params, t)
    return params, opt, l


for t in range(60):
    batch = rng.integers(0, N, 32)
    pos = sampler.positive_pairs(batch)          # co-occurring neighbors
    a = sampler.sample_block(x, batch)
    p = sampler.sample_block(x, pos)
    params, opt, l = step(params, opt, jnp.asarray(t), *a, *p)
    if t % 20 == 0:
        print(f"step {t:3d} loss {float(l):.3f}")

# full-graph embeddings -> node retrieval with the fused Pallas kernel
z = np.asarray(gnn.forward_full(cfg, params, jnp.asarray(x),
                                jnp.asarray(src), jnp.asarray(dst)))
k = 10
vals, ids = kops.fused_score_topk(jnp.asarray(z[:64]), jnp.asarray(z), k)
ids = np.asarray(ids)
# quality: retrieved neighbors should share the query's community
same = np.mean(comm[ids[:, 1:]] == comm[:64, None])
rand = 1.0 / COMMUNITIES
print(f"community purity of top-{k}: {same:.2f} (random {rand:.2f})")
assert same > rand * 1.5, "graph retrieval should beat random"
print("graph retrieval OK")
