"""Round-trip hard-negative mining (paper §3.5 unified interface):
train -> mine_hard_negatives() -> retrain on mined negatives -> evaluate.

    PYTHONPATH=src python examples/hard_negative_mining.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro import (BinaryDataset, DataArguments, EvaluationArguments,
                   HashTokenizer, MaterializedQRelConfig, ModelArguments,
                   RetrievalCollator, RetrievalEvaluator,
                   RetrievalTrainingArguments, BiEncoderRetriever,
                   RetrievalTrainer)
from repro.data.synthetic import make_retrieval_dataset
from repro.models.transformer import LMConfig

work = tempfile.mkdtemp(prefix="trove_mining_")
queries, corpus, qrels = make_retrieval_dataset(
    work, n_queries=48, n_docs=256, n_topics=12)
data_args = DataArguments(group_size=2, vocab_size=512, query_max_len=16,
                          passage_max_len=48)
tok = HashTokenizer(512)
cfg = LMConfig(name="mining", n_layers=2, d_model=48, n_heads=4,
               n_kv_heads=2, head_dim=12, d_ff=96, vocab_size=512,
               dtype=jnp.float32, pooling="mean", remat=False)
retr = BiEncoderRetriever.from_model_args(
    ModelArguments(temperature=0.05), cfg)
coll = RetrievalCollator(data_args, tok)
pos = MaterializedQRelConfig(min_score=1,
                             qrel_path=f"{work}/qrels/train.tsv",
                             query_path=f"{work}/queries.jsonl",
                             corpus_path=f"{work}/corpus.jsonl")


def train(neg_cfg, out, steps=50):
    ds = BinaryDataset(data_args, retr.format_query, retr.format_passage,
                       pos, neg_cfg, cache_root=f"{work}/cache")
    tr = RetrievalTrainer(
        retr, RetrievalTrainingArguments(
            output_dir=out, max_steps=steps, learning_rate=3e-3,
            warmup_steps=5, per_device_batch_size=16, log_every=25,
            checkpoint_every=100), coll, ds)
    return tr.train()


# stage 1: train with random negatives
state = train(pos, f"{work}/stage1")
ev_args = EvaluationArguments(topk=10, metrics=("ndcg@10", "recall@10"))
ev = RetrievalEvaluator(ev_args, retr, coll, state["params"])
before = ev.evaluate(queries, corpus, qrels)
print("stage 1 (random negatives):", before)

# stage 2: mine hard negatives with the SAME evaluator interface
mined_path = f"{work}/mined_neg.tsv"
mined = ev.mine_hard_negatives(queries, corpus, qrels, depth=8,
                               output_path=mined_path)
print(f"mined {len(mined)} hard negatives -> {mined_path}")

# stage 3: retrain with mined negatives (paper Fig. 3's neg config)
neg = MaterializedQRelConfig(group_random_k=2, qrel_path=mined_path,
                             query_path=f"{work}/queries.jsonl",
                             corpus_path=f"{work}/corpus.jsonl")
state2 = train(neg, f"{work}/stage2", steps=80)
ev2 = RetrievalEvaluator(ev_args, retr, coll, state2["params"])
after = ev2.evaluate(queries, corpus, qrels)
print("stage 2 (mined hard negatives):", after)
print(f"ndcg@10: {before['ndcg@10']:.3f} -> {after['ndcg@10']:.3f}")
