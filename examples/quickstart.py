"""Quickstart (paper Fig. 3): train a dense retriever with mined hard
negatives in ~40 lines, then evaluate — runnable on CPU in ~2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro import (BinaryDataset, DataArguments, EvaluationArguments,
                   HashTokenizer, MaterializedQRelConfig, ModelArguments,
                   RetrievalCollator, RetrievalEvaluator,
                   RetrievalTrainingArguments, BiEncoderRetriever,
                   RetrievalTrainer)
from repro.data.synthetic import make_retrieval_dataset
from repro.models.transformer import LMConfig

work = tempfile.mkdtemp(prefix="trove_quickstart_")
queries, corpus, qrels = make_retrieval_dataset(
    work, n_queries=48, n_docs=192, n_topics=12)

# --- the paper's workflow: config objects -> dataset -> retriever -> trainer
train_args = RetrievalTrainingArguments(
    output_dir=os.path.join(work, "run"), max_steps=60,
    learning_rate=3e-3, per_device_batch_size=16, warmup_steps=5,
    checkpoint_every=30, log_every=10)
model_args = ModelArguments(temperature=0.05)
data_args = DataArguments(group_size=2, vocab_size=512,
                          query_max_len=16, passage_max_len=48)

tokenizer = HashTokenizer(data_args.vocab_size)
encoder_cfg = LMConfig(name="quickstart", n_layers=2, d_model=48,
                       n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96,
                       vocab_size=512, dtype=jnp.float32, pooling="mean",
                       remat=False)
model = BiEncoderRetriever.from_model_args(model_args, encoder_cfg)
collator = RetrievalCollator(data_args, tokenizer)

pos = MaterializedQRelConfig(min_score=1,
                             qrel_path=f"{work}/qrels/train.tsv",
                             query_path=f"{work}/queries.jsonl",
                             corpus_path=f"{work}/corpus.jsonl")
neg = MaterializedQRelConfig(group_random_k=2,
                             qrel_path=f"{work}/qrels/train.tsv",
                             query_path=f"{work}/queries.jsonl",
                             corpus_path=f"{work}/corpus.jsonl")
dataset = BinaryDataset(data_args, model.format_query,
                        model.format_passage, pos, neg,
                        cache_root=f"{work}/cache")

trainer = RetrievalTrainer(model, train_args, collator, dataset)
state = trainer.train()
print("train logs:", *trainer.logs, sep="\n  ")

evaluator = RetrievalEvaluator(
    EvaluationArguments(topk=10, metrics=("ndcg@10", "recall@10")),
    model, collator, state["params"])
metrics = evaluator.evaluate(queries, corpus, qrels)
print("final metrics:", metrics)
assert metrics["ndcg@10"] > 0.25, "expected better-than-random retrieval"
print("quickstart OK")
