"""Paper §4.1 demo: mix multi-level synthetic data, annotated positives
and mined negatives — each source processed differently on the fly — and
train list-wise with a *custom* Wasserstein loss registered via _alias
(the SyCL experiment the paper showcases).

    PYTHONPATH=src python examples/multilevel_training.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro import (DataArguments, GradedBiEncoderRetriever, HashTokenizer,
                   MaterializedQRelConfig, MultiLevelDataset,
                   RetrievalCollator, RetrievalLoss,
                   RetrievalTrainingArguments, IRMetrics, RetrievalTrainer)
from repro.data.synthetic import (make_retrieval_dataset,
                                  make_synthetic_multilevel)
from repro.models.encoder import DefaultEncoder
from repro.models.transformer import LMConfig


# --- paper §4.1: user-defined loss, selected via --loss=ws ---------------
class WSLoss(RetrievalLoss):
    _alias = "ws_example"

    def forward(self, scores, labels):  # paper's sketch keeps forward()
        from repro.models.losses import WassersteinLoss
        return WassersteinLoss()(scores, labels)

    __call__ = forward


work = tempfile.mkdtemp(prefix="trove_multilevel_")
queries, corpus, qrels = make_retrieval_dataset(
    work, n_queries=48, n_docs=192, n_topics=12, graded=True)
syn_corpus, syn_qrels = make_synthetic_multilevel(work, queries, 192)

# three sources, three different on-the-fly treatments (paper Fig. 1B):
syn = MaterializedQRelConfig(                      # synthetic levels 0..3
    qrel_path=syn_qrels, query_path=f"{work}/queries.jsonl",
    corpus_path=syn_corpus,
    query_subset_from=f"{work}/qrels/train.tsv")
pos = MaterializedQRelConfig(                      # annotated positives -> 3
    min_score=1, new_label=3,
    qrel_path=f"{work}/qrels/train.tsv",
    query_path=f"{work}/queries.jsonl", corpus_path=f"{work}/corpus.jsonl")
neg = MaterializedQRelConfig(                      # 2 random negatives -> 1
    group_random_k=2, new_label=1,
    qrel_path=f"{work}/qrels/train.tsv",
    query_path=f"{work}/queries.jsonl", corpus_path=f"{work}/corpus.jsonl")

data_args = DataArguments(group_size=6, vocab_size=512, query_max_len=16,
                          passage_max_len=48)
encoder_cfg = LMConfig(name="multilevel", n_layers=2, d_model=48,
                       n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96,
                       vocab_size=512, dtype=jnp.float32, pooling="mean",
                       remat=False)
retriever = GradedBiEncoderRetriever(DefaultEncoder(encoder_cfg),
                                     "ws_example", temperature=0.05)
dataset = MultiLevelDataset(data_args, retriever.format_query,
                            retriever.format_passage, [syn, pos, neg],
                            cache_root=f"{work}/cache")
collator = RetrievalCollator(data_args, HashTokenizer(512))

trainer = RetrievalTrainer(
    retriever,
    RetrievalTrainingArguments(output_dir=f"{work}/run", max_steps=60,
                               learning_rate=3e-3, warmup_steps=5,
                               per_device_batch_size=16, log_every=10,
                               checkpoint_every=50),
    collator, dataset,
    dev_dataset=[dataset[i] for i in range(16)],
    compute_metrics=IRMetrics(("ndcg@10", "mrr@10")))
trainer.train()
print("logs:", *trainer.logs, sep="\n  ")
final = trainer.logs[-1]
assert final["loss"] < trainer.logs[0]["loss"]
print(f"graded training OK (ndcg@10 during training: "
      f"{final.get('ndcg@10'):.3f})")
